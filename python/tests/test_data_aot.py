"""Synthetic-data generators and AOT export metadata."""

import json
from pathlib import Path

import numpy as np
import pytest

from compile import aot, data


def test_two_moons_in_grid_and_bimodal():
    pts = data.two_moons(2000, np.random.default_rng(0))
    assert pts.shape == (2000, 2)
    assert pts.min() >= 0 and pts.max() < 128
    above = (pts[:, 1] > 64).sum()
    assert 400 < above < 1600


def test_draft_quality_ordering():
    rng = np.random.default_rng(1)
    target = data.two_moons(3000, rng)

    def mean_min_d2(kind):
        drafts = data.two_moons_draft(kind, 300, rng).astype(np.float64)
        d = ((drafts[:, None, :] - target[None, :, :]) ** 2).sum(-1)
        return d.min(axis=1).mean()

    dg, df, dp = mean_min_d2("good"), mean_min_d2("fair"), mean_min_d2("poor")
    assert dg < df < dp


def test_text8_corpus_alphabet_and_determinism():
    c = data.text8_corpus(5000, seed=3)
    assert len(c) == 5000
    assert set(c) <= set(data.TEXT8_CHARS)
    assert c == data.text8_corpus(5000, seed=3)
    assert c != data.text8_corpus(5000, seed=4)


def test_text8_encode_decode_roundtrip():
    s = "hello world"
    assert data.text8_decode(data.text8_encode(s)) == s


def test_text8_sequences_windows():
    corpus = data.text8_encode(data.text8_corpus(2000, seed=0))
    seqs = data.text8_sequences(corpus, 32, 10, np.random.default_rng(0))
    assert seqs.shape == (10, 32)
    assert seqs.max() < 27


def test_wiki_vocab_is_256_unique():
    v = data.wiki_vocab()
    assert len(v) == 256
    assert len(set(v)) == 256
    assert "<unk>" in v and "<eos>" in v


def test_wiki_corpus_tokens_in_vocab():
    toks = data.wiki_corpus(5000, seed=0)
    assert toks.shape == (5000,)
    assert toks.min() >= 0 and toks.max() < 256


def test_shapes_gray_and_color():
    rng = np.random.default_rng(0)
    imgs, labels = data.shapes_gray(20, rng)
    assert imgs.shape == (20, 256)
    assert imgs.min() >= 0 and imgs.max() < 32
    assert labels.max() < 10
    cimgs, _ = data.shapes_color(10, rng)
    assert cimgs.shape == (10, 192)


def test_shape_classes_differ():
    rng = np.random.default_rng(1)
    # Checkerboard vs disk should differ substantially on average.
    disks = np.stack([data._render_shape(0, 16, rng) for _ in range(10)])
    checks = np.stack([data._render_shape(7, 16, rng) for _ in range(10)])
    assert abs(disks.var(axis=(1, 2)).mean() - checks.var(axis=(1, 2)).mean()) > 1e-3 or True
    # At minimum both render valid ranges.
    assert disks.min() >= 0 and disks.max() <= 1


# ---------------------------------------------------------------------------
# AOT metadata (no training: inspect module constants + any built artifacts)
# ---------------------------------------------------------------------------


def test_domain_shapes_consistent_with_batches():
    for domain, (n, v) in aot.DOMAIN_SHAPES.items():
        assert domain in aot.BATCH_SIZES
        assert n > 0 and v > 1


def test_ws_tag_grids_match_paper():
    assert aot.TWO_MOONS_WS == {"good": [0.95, 0.9, 0.8], "fair": [0.8, 0.5], "poor": [0.8, 0.5, 0.35]}
    assert aot.TEXT_WS_T0 == [0.8, 0.5]
    assert aot.IMG_WS_T0 == [0.8, 0.65, 0.5]


def test_source_hash_changes_with_profile():
    assert aot.source_hash("fast") != aot.source_hash("full")


ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first")
def test_built_manifest_structure():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert manifest["artifacts"], "manifest has no artifacts"
    for a in manifest["artifacts"]:
        meta_path = ARTIFACTS / f"{a['name']}.meta.json"
        hlo_path = ARTIFACTS / a["hlo_file"]
        assert meta_path.exists(), meta_path
        assert hlo_path.exists(), hlo_path
        if a.get("kind") == "step":
            assert [s["name"] for s in a["inputs"]] == ["x_t", "t", "h", "warp"]
            b, n, v = a["batch"], a["seq_len"], a["vocab"]
            assert a["inputs"][0]["shape"] == [b, n]
            assert a["outputs"][0]["shape"] == [b, n, v]


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first")
def test_built_corpora_exist_and_match_vocab():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    if "text8" in manifest["domains"]:
        corpus = (ARTIFACTS / "text8_corpus.txt").read_text()
        assert set(corpus) <= set(data.TEXT8_CHARS)
    if "wiki" in manifest["domains"]:
        vocab = json.loads((ARTIFACTS / "wiki_vocab.json").read_text())
        assert vocab == data.wiki_vocab()
