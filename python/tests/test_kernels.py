"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, attention_vmem_bytes, _pick_block_q
from compile.kernels.dfm_update import dfm_update, dfm_update_vmem_bytes, _pick_block_n
from compile.kernels.ref import attention_ref, dfm_update_ref

SETTINGS = dict(max_examples=24, deadline=None)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    n=st.sampled_from([1, 2, 4, 8, 16, 48, 64]),
    dh=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, n, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (jnp.asarray(rng.normal(size=(b, h, n, dh)).astype(np.float32)) for _ in range(3))
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.normal(size=(2, 2, 16, 8)), dtype=dtype) for _ in range(3))
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2
    )


def test_attention_block_q_must_divide():
    q = jnp.zeros((1, 1, 6, 4), jnp.float32)
    with pytest.raises(ValueError):
        attention(q, q, q, block_q=4)


def test_attention_shape_mismatch_rejected():
    q = jnp.zeros((1, 1, 8, 4), jnp.float32)
    k = jnp.zeros((1, 1, 4, 4), jnp.float32)
    with pytest.raises(ValueError):
        attention(q, k, q)


def test_attention_softmax_rowsums():
    # Output rows are convex combos of V rows: max(out) <= max(v).
    rng = np.random.default_rng(1)
    q, k = (jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32)) for _ in range(2))
    v = jnp.ones((1, 1, 8, 4), jnp.float32)
    out = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.ones_like(out), rtol=1e-5)


def test_pick_block_q_divides():
    for n in [1, 2, 3, 6, 17, 64, 96, 256]:
        bq = _pick_block_q(n)
        assert n % bq == 0 and bq <= 64


def test_attention_vmem_estimate_within_budget():
    # DESIGN.md §Perf: served shapes fit far under a 16 MiB VMEM budget.
    assert attention_vmem_bytes(256, 32) < 4 * 1024 * 1024
    assert attention_vmem_bytes(64, 32) < 1024 * 1024


# ---------------------------------------------------------------------------
# dfm_update
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    n=st.sampled_from([1, 2, 4, 8, 32, 64]),
    v=st.sampled_from([2, 5, 27, 32, 128]),
    t=st.floats(0.0, 0.99),
    h=st.floats(0.001, 0.2),
    warp=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_dfm_update_matches_ref(b, n, v, t, h, warp, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, n, v)).astype(np.float32) * 3)
    x = jnp.asarray(rng.integers(0, v, size=(b, n)).astype(np.int32))
    out = dfm_update(logits, x, t, h, warp)
    ref = dfm_update_ref(logits, x, jnp.float32(t), jnp.float32(h), jnp.float32(warp))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    t=st.floats(0.0, 0.999),
    h=st.floats(0.0001, 1.0),
    warp=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_dfm_update_rows_are_distributions(t, h, warp, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 4, 11)).astype(np.float32) * 5)
    x = jnp.asarray(rng.integers(0, 11, size=(2, 4)).astype(np.int32))
    probs = np.asarray(dfm_update(logits, x, t, h, warp))
    assert (probs >= 0).all()
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)


def test_dfm_update_final_step_full_commit():
    # coef = h*warp/(1-t) capped at 1: with h = 1-t and warp=1 the output IS
    # softmax(logits) — the final Euler step lands exactly on p1.
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(1, 3, 7)).astype(np.float32))
    x = jnp.asarray(rng.integers(0, 7, size=(1, 3)).astype(np.int32))
    probs = np.asarray(dfm_update(logits, x, 0.9, 0.1, 1.0))
    p1 = np.asarray(jax.nn.softmax(logits, axis=-1))
    np.testing.assert_allclose(probs, p1, rtol=1e-5, atol=1e-6)


def test_dfm_update_zero_step_is_delta():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(1, 2, 5)).astype(np.float32))
    x = jnp.asarray([[1, 4]], dtype=jnp.int32)
    probs = np.asarray(dfm_update(logits, x, 0.5, 0.0, 1.0))
    expected = np.zeros((1, 2, 5), np.float32)
    expected[0, 0, 1] = 1.0
    expected[0, 1, 4] = 1.0
    np.testing.assert_allclose(probs, expected, atol=1e-6)


def test_dfm_update_pole_guard():
    # t >= 1 must not produce NaN/inf.
    logits = jnp.zeros((1, 2, 4), jnp.float32)
    x = jnp.zeros((1, 2), jnp.int32)
    probs = np.asarray(dfm_update(logits, x, 1.0, 0.05, 1.0))
    assert np.isfinite(probs).all()


def test_dfm_update_literal_warp_scales_velocity():
    # warp = 1-t0 < 1 moves less mass than warp = 1.
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(1, 4, 9)).astype(np.float32) * 2)
    x = jnp.asarray(rng.integers(0, 9, size=(1, 4)).astype(np.int32))
    full = np.asarray(dfm_update(logits, x, 0.85, 0.05, 1.0))
    part = np.asarray(dfm_update(logits, x, 0.85, 0.05, 0.2))
    delta = np.eye(9, dtype=np.float32)[np.asarray(x)]
    # Distance from the current-state delta: literal < exact.
    assert np.abs(part - delta).sum() < np.abs(full - delta).sum()


def test_pick_block_n_divides():
    for n in [1, 2, 3, 30, 192, 256]:
        bn = _pick_block_n(n)
        assert n % bn == 0


def test_dfm_update_vmem_estimate():
    assert dfm_update_vmem_bytes(256, 256) < 2 * 1024 * 1024
