"""Probability-path properties (cold + warm) and the NFE guarantee."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import paths

SETTINGS = dict(max_examples=30, deadline=None)


def test_kappa_boundaries():
    assert float(paths.kappa(jnp.float32(0.0))) == 0.0
    assert float(paths.kappa(jnp.float32(1.0))) == 1.0
    assert float(paths.kappa(jnp.float32(0.8), 0.8)) == 0.0
    assert float(paths.kappa(jnp.float32(1.0), 0.8)) == 1.0


@settings(**SETTINGS)
@given(t0=st.floats(0.0, 0.95), t=st.floats(0.0, 1.0))
def test_kappa_in_unit_interval(t0, t):
    k = float(paths.kappa(jnp.float32(t), t0))
    assert 0.0 <= k <= 1.0


def test_warm_path_reduces_to_cold_at_t0_zero():
    t = jnp.linspace(0.0, 1.0, 11)
    np.testing.assert_allclose(np.asarray(paths.kappa(t, 0.0)), np.asarray(t), atol=1e-6)


def test_sample_t_range():
    key = jax.random.PRNGKey(0)
    t = np.asarray(paths.sample_t(key, 10_000, t0=0.8))
    assert (t >= 0.8 - 1e-6).all() and (t <= 1.0).all()
    assert abs(t.mean() - 0.9) < 0.005


def test_interpolate_boundary_marginals():
    key = jax.random.PRNGKey(1)
    b, n = 2048, 8
    x_src = jnp.zeros((b, n), jnp.int32)
    x_1 = jnp.ones((b, n), jnp.int32)
    # At t = t0 the sample is pure source; at t = 1 pure target.
    at_t0 = paths.interpolate(key, x_src, x_1, jnp.full((b,), 0.8), t0=0.8)
    assert (np.asarray(at_t0) == 0).all()
    at_1 = paths.interpolate(key, x_src, x_1, jnp.ones((b,)), t0=0.8)
    assert (np.asarray(at_1) == 1).all()


@settings(**SETTINGS)
@given(t0=st.floats(0.0, 0.9), frac=st.floats(0.05, 0.95))
def test_interpolate_mixing_fraction(t0, frac):
    key = jax.random.PRNGKey(42)
    t_val = t0 + frac * (1.0 - t0)
    b, n = 512, 32
    x_src = jnp.zeros((b, n), jnp.int32)
    x_1 = jnp.ones((b, n), jnp.int32)
    x_t = np.asarray(paths.interpolate(key, x_src, x_1, jnp.full((b,), t_val), t0=t0))
    measured = x_t.mean()
    expected = float(paths.kappa(jnp.float32(t_val), t0))
    assert abs(measured - expected) < 0.02, (measured, expected)


def test_interpolate_shape_mismatch():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        paths.interpolate(key, jnp.zeros((2, 3), jnp.int32), jnp.zeros((2, 4), jnp.int32), jnp.zeros((2,)))


def test_uniform_noise_covers_vocab():
    key = jax.random.PRNGKey(3)
    x = np.asarray(paths.uniform_noise(key, (64, 64), 27))
    assert x.min() >= 0 and x.max() <= 26
    assert len(np.unique(x)) == 27


def test_mask_noise():
    x = np.asarray(paths.mask_noise((3, 4), 27))
    assert (x == 27).all()


# The NFE guarantee (mirrored by rust core::schedule — same pinned values).
@pytest.mark.parametrize(
    "steps,t0,expected",
    [(20, 0.95, 1), (20, 0.9, 2), (20, 0.8, 4), (20, 0.5, 10), (20, 0.35, 13),
     (1024, 0.8, 205), (1024, 0.5, 512), (128, 0.0, 128)],
)
def test_nfe_guarantee_table(steps, t0, expected):
    assert paths.nfe(steps, t0) == expected


@pytest.mark.parametrize("steps", [1, 2, 3, 5, 7, 13, 20, 49, 128, 1024, 65536])
def test_nfe_float_boundary_cases(steps):
    # t0 = 1 - k/steps computed in float must give exactly k evaluations —
    # the integer result, despite the product drifting a few ulps off k.
    # Mirrors `boundary_t0_matches_integer_arithmetic` in
    # rust/src/core/schedule.rs (same epsilon).
    h = 1.0 / steps
    assert paths.nfe(steps, 0.0) == steps
    assert paths.nfe(steps, 1.0 - h) == 1
    if steps >= 2:
        assert paths.nfe(steps, h) == steps - 1
    assert paths.nfe(steps, 1.0 - 1e-9) == 1
    for k in range(1, min(steps, 64) + 1):
        assert paths.nfe(steps, 1.0 - k / steps) == k, (steps, k)


def test_nfe_rejects_bad_t0():
    with pytest.raises(ValueError):
        paths.nfe(10, 1.0)
    with pytest.raises(ValueError):
        paths.nfe(10, -0.1)
