"""Training loop (loss decreases; Fig 2 equivalence) and refinement pairing."""

import jax
import numpy as np
import pytest

from compile import data, refine, train
from compile.models import mlp


def tiny_dataset(n=512, seed=0):
    return data.two_moons(n, np.random.default_rng(seed))


def test_cold_dfm_loss_decreases():
    dataset = tiny_dataset()
    params = mlp.init(jax.random.PRNGKey(0), vocab=128, hidden=32)
    res = train.train_dfm(
        lambda p, x, t: mlp.apply(p, x, t),
        params,
        train.pairs_noise_data(dataset, 128, batch=128),
        steps=120,
        lr=1e-3,
        t0=0.0,
        log_every=0,
    )
    assert res.loss_end < res.loss_start, (res.loss_start, res.loss_end)


def test_warm_dfm_loss_decreases_and_uses_t0():
    dataset = tiny_dataset()
    drafts = data.two_moons_draft("fair", 512, np.random.default_rng(1))
    idx = refine.nearest_neighbor(drafts, dataset, k=1)[:, 0]
    params = mlp.init(jax.random.PRNGKey(1), vocab=128, hidden=32)
    res = train.train_dfm(
        lambda p, x, t: mlp.apply(p, x, t),
        params,
        train.pairs_from_arrays(drafts, dataset[idx], batch=128),
        steps=120,
        lr=1e-3,
        t0=0.8,
        log_every=0,
    )
    assert res.loss_end < res.loss_start


def test_pairs_from_arrays_alignment():
    x_src = np.arange(20).reshape(10, 2).astype(np.int32)
    x_1 = x_src + 100
    pair_fn = train.pairs_from_arrays(x_src, x_1, batch=6)
    a, b = pair_fn(jax.random.PRNGKey(0))
    # Row-aligned coupling: b == a + 100 elementwise.
    assert (np.asarray(b) - np.asarray(a) == 100).all()


def test_pairs_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        train.pairs_from_arrays(np.zeros((4, 2)), np.zeros((5, 2)), batch=2)


def test_lstm_training_decreases_loss():
    from compile.models import lstm

    corpus = data.text8_encode(data.text8_corpus(20_000, seed=0))
    seqs = data.text8_sequences(corpus, 16, 256, np.random.default_rng(0))
    params = lstm.init(jax.random.PRNGKey(0), vocab=27, dim=24)
    res = train.train_lstm(params, seqs, steps=80, lr=3e-3, batch=32, log_every=0)
    assert res.loss_end < res.loss_start
    # Better than uniform (ln 27 ≈ 3.3).
    assert res.loss_end < 3.2


# ---------------------------------------------------------------------------
# refinement
# ---------------------------------------------------------------------------


def test_nearest_neighbor_exact():
    dataset = np.asarray([[0, 0], [10, 10], [20, 20]], np.float32)
    drafts = np.asarray([[1, 1], [19, 18]], np.float32)
    idx = refine.nearest_neighbor(drafts, dataset, k=1)
    assert idx[:, 0].tolist() == [0, 2]
    idx2 = refine.nearest_neighbor(drafts, dataset, k=2)
    assert set(idx2[0].tolist()) == {0, 1}


def test_knn_pairs_counts_and_membership():
    rng = np.random.default_rng(0)
    dataset = rng.integers(0, 128, size=(100, 2)).astype(np.int32)
    drafts = rng.integers(0, 128, size=(10, 2)).astype(np.int32)
    x_src, x_1 = refine.knn_pairs(drafts, dataset, k=3, k_inject=2, rng=rng)
    assert x_src.shape == (10 * 5, 2)
    # Every target row is an actual dataset row.
    ds_set = {tuple(r) for r in dataset.tolist()}
    assert all(tuple(r) in ds_set for r in x_1.tolist())
    # Source rows repeat the drafts.
    d_set = {tuple(r) for r in drafts.tolist()}
    assert all(tuple(r) in d_set for r in x_src.tolist())


def test_inject_real_fraction():
    rng = np.random.default_rng(1)
    x_src = np.zeros((100, 2), np.int32)
    x_1 = np.ones((100, 2), np.int32)
    dataset = np.full((50, 2), 7, np.int32)
    s2, t2 = refine.inject_real(x_src, x_1, dataset, 0.3, rng)
    injected = (s2 == 7).all(axis=1).sum()
    assert injected == 30
    # Injected rows pair (real, real).
    mask = (s2 == 7).all(axis=1)
    assert (t2[mask] == 7).all()


def test_ngram_lm_probabilities():
    stream = np.asarray([0, 1, 0, 1, 0, 1, 2] * 100, np.int32)
    lm = refine.NgramLM(order=2, vocab=5).fit(stream)
    p = lm.cond_probs((0,))
    assert abs(p.sum() - 1.0) < 1e-9
    assert p[1] > 0.8  # 0 -> 1 dominates


def test_oracle_refine_improves_and_bounds_edits():
    stream = np.asarray([0, 1, 2, 3] * 500, np.int32)
    lm = refine.NgramLM(order=3, vocab=8).fit(stream)
    rng = np.random.default_rng(2)
    draft = rng.integers(0, 8, size=64).astype(np.int32)
    refined = refine.oracle_refine(draft, lm, rng, max_edit_frac=0.3)
    edits = (refined != draft).sum()
    assert edits <= int(64 * 0.3) + 1
    assert lm.token_logprobs(refined).mean() >= lm.token_logprobs(draft).mean()


def test_refine_text_batch_shapes():
    stream = np.asarray([0, 1] * 300, np.int32)
    lm = refine.NgramLM(order=2, vocab=4).fit(stream)
    drafts = np.random.default_rng(3).integers(0, 4, size=(5, 20)).astype(np.int32)
    refined = refine.refine_text_batch(drafts, lm, seed=0)
    assert refined.shape == drafts.shape
