"""Model shape/behaviour tests (MLP, DiT, LSTM, PCA) + pallas/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nn
from compile.models import dit, lstm, mlp, pca


def test_mlp_shapes():
    params = mlp.init(jax.random.PRNGKey(0), vocab=128, hidden=64, n_tokens=2)
    x = jnp.asarray([[3, 100], [0, 127]], jnp.int32)
    t = jnp.asarray([0.1, 0.9], jnp.float32)
    logits = mlp.apply(params, x, t)
    assert logits.shape == (2, 2, 128)
    assert np.isfinite(np.asarray(logits)).all()


def test_mlp_time_conditioning_matters():
    params = mlp.init(jax.random.PRNGKey(1), vocab=32, hidden=32)
    x = jnp.asarray([[1, 2]], jnp.int32)
    l0 = mlp.apply(params, x, jnp.asarray([0.1]))
    l1 = mlp.apply(params, x, jnp.asarray([0.9]))
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_dit_shapes_and_finite():
    params = dit.init(jax.random.PRNGKey(0), vocab=27, seq_len=16, dim=32, heads=2, blocks=2)
    x = jnp.zeros((3, 16), jnp.int32)
    t = jnp.full((3,), 0.5)
    logits = dit.apply(params, x, t, heads=2)
    assert logits.shape == (3, 16, 27)
    assert np.isfinite(np.asarray(logits)).all()


def test_dit_pallas_matches_ref_path():
    # The AOT export uses the Pallas attention; training uses the reference.
    # They must agree numerically.
    params = dit.init(jax.random.PRNGKey(2), vocab=27, seq_len=16, dim=32, heads=2, blocks=2)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 27, (2, 16)), jnp.int32)
    t = jnp.asarray([0.3, 0.7])
    a = dit.apply(params, x, t, use_pallas=False, heads=2)
    b = dit.apply(params, x, t, use_pallas=True, heads=2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_dit_adaln_zero_init_means_near_identity_blocks():
    # With adaLN-Zero, gates start at 0 so token mixing is initially off:
    # permuting *other* positions' tokens must not change position 0's
    # logits at init.
    params = dit.init(jax.random.PRNGKey(3), vocab=11, seq_len=8, dim=16, heads=2, blocks=2)
    x1 = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32) % 11
    x2 = jnp.asarray([[1, 8, 7, 6, 5, 4, 3, 2]], jnp.int32) % 11
    t = jnp.asarray([0.5])
    l1 = dit.apply(params, x1, t, heads=2)
    l2 = dit.apply(params, x2, t, heads=2)
    np.testing.assert_allclose(np.asarray(l1)[0, 0], np.asarray(l2)[0, 0], atol=1e-5)


def test_dit_rejects_bad_heads():
    with pytest.raises(ValueError):
        dit.init(jax.random.PRNGKey(0), vocab=5, seq_len=4, dim=30, heads=4)


def test_lstm_teacher_forcing_shapes():
    params = lstm.init(jax.random.PRNGKey(0), vocab=27, dim=32)
    toks = jnp.zeros((4, 12), jnp.int32)
    logits = lstm.apply_seq(params, toks)
    assert logits.shape == (4, 12, 27)


def test_lstm_causality():
    # Changing a later token must not affect earlier logits.
    params = lstm.init(jax.random.PRNGKey(1), vocab=11, dim=16)
    a = jnp.asarray([[1, 2, 3, 4, 5]], jnp.int32)
    b = jnp.asarray([[1, 2, 3, 9, 9]], jnp.int32)
    la = lstm.apply_seq(params, a)
    lb = lstm.apply_seq(params, b)
    np.testing.assert_allclose(np.asarray(la)[:, :3], np.asarray(lb)[:, :3], atol=1e-6)
    # Position 4 differs (conditioned on position 3).
    assert not np.allclose(np.asarray(la)[:, 4], np.asarray(lb)[:, 4])


def test_lstm_sample_deterministic_given_noise():
    params = lstm.init(jax.random.PRNGKey(2), vocab=9, dim=16)
    g = jax.random.gumbel(jax.random.PRNGKey(3), (2, 6, 9))
    t1 = lstm.sample(params, g)
    t2 = lstm.sample(params, g)
    assert (np.asarray(t1) == np.asarray(t2)).all()
    assert t1.shape == (2, 6)
    assert np.asarray(t1).min() >= 0 and np.asarray(t1).max() < 9


def test_pca_fit_sample_roundtrip():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 32, size=(1, 64))
    imgs = np.clip(base + rng.normal(scale=2.0, size=(200, 64)), 0, 31).astype(np.int32)
    params = pca.fit(imgs, k=8)
    z = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    out = np.asarray(pca.sample(params, z, 32))
    assert out.shape == (16, 64)
    assert out.min() >= 0 and out.max() < 32
    # Samples should hug the dataset mean (low-variance data).
    assert np.abs(out.mean(0) - imgs.mean(0)).mean() < 4.0


def test_amsgrad_descends_quadratic():
    opt = nn.AmsGrad(lr=0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 1e-2


def test_cross_entropy_perfect_prediction():
    logits = jnp.asarray([[[10.0, -10.0], [-10.0, 10.0]]])
    targets = jnp.asarray([[0, 1]], jnp.int32)
    assert float(nn.cross_entropy(logits, targets)) < 1e-4


def test_count_params():
    params = mlp.init(jax.random.PRNGKey(0), vocab=16, hidden=8, n_tokens=2)
    n = nn.count_params(params)
    assert n > 16 * 8  # at least the embedding
