"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used by the pytest/hypothesis
suite to validate the Pallas kernels (``attention.py``, ``dfm_update.py``)
across shape and dtype sweeps. They are also usable directly by the L2 model
code (training uses the reference attention; the AOT inference export swaps
in the Pallas kernel, and the test suite asserts the two are allclose).
"""

from __future__ import annotations

import jax.nn
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head scaled-dot-product attention (no masking).

    Args:
      q, k, v: ``[B, H, N, Dh]`` arrays (any float dtype).

    Returns:
      ``[B, H, N, Dh]`` attention output in the input dtype.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    probs = jax.nn.softmax(scores * scale, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def dfm_update_ref(
    logits: jnp.ndarray,
    x_t: jnp.ndarray,
    t: jnp.ndarray,
    h: jnp.ndarray,
    warp: jnp.ndarray,
) -> jnp.ndarray:
    """Fused DFM Euler-step transition probabilities (reference).

    Implements the inference update of the paper's Fig. 3: from denoiser
    logits compute ``p1 = softmax(logits)``, the CTMC velocity
    ``u = warp * (p1 - onehot(x_t)) / (1 - t)`` and the per-token transition
    distribution ``P = onehot(x_t) + h * u``, clipped to be non-negative and
    renormalized.

    ``warp`` is the paper's literal time-warping factor ``(1 - t0)`` for
    WS-DFM (Fig. 3 right), and ``1`` for cold DFM / the exact normalized
    warm path — see DESIGN.md §1. The Rust coordinator owns this choice.

    Args:
      logits: ``[B, N, V]`` float array of denoiser outputs.
      x_t:    ``[B, N]`` int32 current tokens.
      t:      scalar float, current time in ``[t0, 1)``.
      h:      scalar float, Euler step size.
      warp:   scalar float time-warp factor.

    Returns:
      ``[B, N, V]`` float32 transition probabilities (rows sum to 1).
    """
    v = logits.shape[-1]
    p1 = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    delta = jax.nn.one_hot(x_t, v, dtype=jnp.float32)
    # Guard the 1/(1-t) pole; the sampler never calls with t >= 1 but the
    # kernel must stay finite for any input.
    inv = 1.0 / jnp.maximum(1.0 - t, 1e-6)
    coef = jnp.minimum(h * warp * inv, 1.0)  # never overshoot past p1
    probs = delta + coef * (p1 - delta)
    probs = jnp.clip(probs, 0.0, None)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return probs
