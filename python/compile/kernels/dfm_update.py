"""Pallas fused DFM Euler-update kernel (Layer 1).

The per-step tail of the sampling hot path: softmax over denoiser logits,
CTMC velocity with (optional) warm-start time-warping, Euler transition
probabilities, clip + renormalize — all in one pass over the ``[B, N, V]``
logit tensor so the intermediate ``p1``/``delta``/``u`` tensors never hit
HBM. This kernel is bandwidth-bound; fusing it removes three full
HBM round-trips per sampler step (see EXPERIMENTS.md §Perf).

TPU mapping: grid over (batch, n-block); each step streams one
``(BLOCK_N, V)`` logit tile plus the matching ``(BLOCK_N,)`` token ids
through VMEM. For the largest served shape (N=256, V=256, f32) a 32-row
block is 32·256·4 ≈ 32 KiB — trivially VMEM-resident, so the schedule is a
single linear sweep over HBM.

Scalars (t, h, warp) are passed as ``[1]`` f32 arrays broadcast to every
grid step. ``warp`` carries the warm-start semantics: the Rust coordinator
passes ``1.0`` for cold DFM / the exact normalized warm path and ``1 - t0``
for the paper's literal Fig. 3 rule, so a single compiled artifact serves
every update-rule variant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dfm_update_kernel(t_ref, h_ref, warp_ref, logits_ref, x_ref, o_ref):
    """One (batch, n-block) grid cell.

    Block shapes: logits_ref/o_ref ``[BLOCK_N, V]``; x_ref ``[BLOCK_N]``;
    t/h/warp are ``[1]`` scalar refs.
    """
    logits = logits_ref[...].astype(jnp.float32)
    x = x_ref[...]
    t = t_ref[0]
    h = h_ref[0]
    warp = warp_ref[0]

    # Stable softmax along V.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p1 = e / jnp.sum(e, axis=-1, keepdims=True)

    bn, v = logits.shape
    delta = (jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1) == x[:, None]).astype(jnp.float32)

    # Guard the 1/(1-t) pole; the sampler never calls with t >= 1 but the
    # kernel must stay finite for any input. `coef` is capped at 1 so the
    # final step (h = 1 - t) lands exactly on p1 and never overshoots.
    inv = 1.0 / jnp.maximum(1.0 - t, 1e-6)
    coef = jnp.minimum(h * warp * inv, 1.0)

    probs = delta + coef * (p1 - delta)
    probs = jnp.maximum(probs, 0.0)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[...] = probs


def _pick_block_n(n: int) -> int:
    for cand in (32, 16, 8, 4, 2, 1):
        if cand <= n and n % cand == 0:
            return cand
    return n


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dfm_update(
    logits: jnp.ndarray,
    x_t: jnp.ndarray,
    t: jnp.ndarray,
    h: jnp.ndarray,
    warp: jnp.ndarray,
    *,
    block_n: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused DFM Euler-step transition probabilities via Pallas.

    See ``ref.dfm_update_ref`` for exact semantics.

    Args:
      logits: ``[B, N, V]`` denoiser logits.
      x_t: ``[B, N]`` int32 current tokens.
      t, h, warp: scalar f32 (0-d arrays or python floats). ``warp = 1`` is
        the cold/exact rule; ``warp = 1 - t0`` is the paper-literal warm rule.
      block_n: token-block size (must divide N).
      interpret: interpret mode (required on CPU PJRT).

    Returns:
      ``[B, N, V]`` f32 transition probabilities (rows sum to 1).
    """
    b, n, v = logits.shape
    if x_t.shape != (b, n):
        raise ValueError(f"x_t shape {x_t.shape} != {(b, n)}")
    bn = block_n if block_n is not None else _pick_block_n(n)
    if n % bn != 0:
        raise ValueError(f"block_n={bn} must divide N={n}")

    t1 = jnp.asarray(t, jnp.float32).reshape(1)
    h1 = jnp.asarray(h, jnp.float32).reshape(1)
    w1 = jnp.asarray(warp, jnp.float32).reshape(1)

    grid = (b, n // bn)
    return pl.pallas_call(
        _dfm_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((None, bn, v), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((None, bn, v), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, v), jnp.float32),
        interpret=interpret,
    )(t1, h1, w1, logits, x_t.astype(jnp.int32))


def dfm_update_vmem_bytes(n: int, v: int, block_n: int | None = None) -> int:
    """Estimated per-grid-step VMEM working set (for DESIGN.md §Perf)."""
    bn = block_n if block_n is not None else _pick_block_n(n)
    # logits tile + probs tile (f32) + token ids (i32) + p1/delta temporaries.
    return 4 * (2 * bn * v + bn + 2 * bn * v)
