"""Pallas fused multi-head attention kernel (Layer 1).

The DiT denoiser's hot spot. The kernel fuses QK^T → softmax → PV per
(batch, head, q-block) grid cell so the score matrix never round-trips
through HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): each grid step streams one
``(BLOCK_Q, Dh)`` query tile plus the full ``(N, Dh)`` key/value panels
through VMEM; with the default shapes (N ≤ 256, Dh ≤ 64, f32) the working
set is ≤ 1 MiB, far under the ~16 MiB VMEM budget, and the two matmuls are
MXU-shaped (contraction dims Dh and N are multiples of 8). On this image the
kernel always runs with ``interpret=True`` — real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; interpret mode lowers
to plain HLO ops so the same artifact runs on the Rust CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch*head, q-block) grid cell.

    Block shapes: q_ref/o_ref ``[BLOCK_Q, Dh]``; k_ref/v_ref ``[N, Dh]``.
    """
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    # [BLOCK_Q, N] score tile lives entirely in VMEM/registers.
    scores = jnp.dot(q, k.T) * scale
    # Numerically stable softmax along the key axis.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v).astype(o_ref.dtype)


def _pick_block_q(n: int) -> int:
    """Largest power-of-two q-block ≤ 64 that divides N (N itself if tiny)."""
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if cand <= n and n % cand == 0:
            return cand
    return n


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused multi-head attention via Pallas.

    Args:
      q, k, v: ``[B, H, N, Dh]`` arrays.
      block_q: query tile size (must divide N); default picks automatically.
      interpret: run the kernel in interpret mode (required on CPU PJRT).

    Returns:
      ``[B, H, N, Dh]`` attention output, same dtype as ``q``.
    """
    b, h, n, dh = q.shape
    if k.shape != (b, h, n, dh) or v.shape != (b, h, n, dh):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    bq = block_q if block_q is not None else _pick_block_q(n)
    if n % bq != 0:
        raise ValueError(f"block_q={bq} must divide N={n}")
    scale = 1.0 / (dh**0.5)

    # Collapse (B, H) into one grid axis; q additionally tiles over N.
    qf = q.reshape(b * h, n, dh)
    kf = k.reshape(b * h, n, dh)
    vf = v.reshape(b * h, n, dh)

    grid = (b * h, n // bq)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, n, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, n, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, n, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, n, dh)


def attention_vmem_bytes(n: int, dh: int, block_q: int | None = None, dtype_bytes: int = 4) -> int:
    """Estimated per-grid-step VMEM working set (for DESIGN.md §Perf).

    q-tile + k + v + score tile + output tile.
    """
    bq = block_q if block_q is not None else _pick_block_q(n)
    return dtype_bytes * (bq * dh + 2 * n * dh + bq * n + bq * dh)
