# Layer-2 model zoo: denoisers (MLP, DiT-tiny) and draft generators
# (LSTM LM, PCA-Gaussian sampler). All pure-jax, parameters as dict pytrees.
