"""Two-moons DFM denoiser: embedding + 4-layer MLP (paper §4.1, verbatim).

The state is two tokens (x, y grid coordinates), each over a vocabulary of
V=128 bins. Each token is embedded to R^h via a table, the two embeddings are
concatenated together with a time embedding, and a 4-layer MLP (hidden h=128)
produces logits ``[B, 2, V]`` — the denoiser posterior over x_1 tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn


def init(key: jax.Array, vocab: int = 128, hidden: int = 128, n_tokens: int = 2) -> nn.Params:
    ks = jax.random.split(key, 8)
    d_in = n_tokens * hidden + hidden  # token embs + time emb
    return {
        "embed": nn.embedding_init(ks[0], vocab, hidden),
        "time_proj": nn.dense_init(ks[1], hidden, hidden),
        "l1": nn.dense_init(ks[2], d_in, hidden),
        "l2": nn.dense_init(ks[3], hidden, hidden),
        "l3": nn.dense_init(ks[4], hidden, hidden),
        "l4": nn.dense_init(ks[5], hidden, n_tokens * vocab, scale=0.02),
    }


def apply(params: nn.Params, x_t: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Denoiser forward.

    Args:
      params: pytree from :func:`init`.
      x_t: ``[B, 2]`` int32 tokens.
      t: ``[B]`` f32 times.

    Returns:
      logits ``[B, 2, V]``.
    """
    vocab = int(params["embed"].shape[0])
    hidden = int(params["embed"].shape[1])
    b, n = x_t.shape
    emb = params["embed"][x_t]  # [B, N, h]
    phi = emb.reshape(b, n * hidden)
    temb = nn.gelu(nn.dense(params["time_proj"], nn.time_embedding(t, hidden)))
    z = jnp.concatenate([phi, temb], axis=-1)
    z = nn.gelu(nn.dense(params["l1"], z))
    z = nn.gelu(nn.dense(params["l2"], z))
    z = nn.gelu(nn.dense(params["l3"], z))
    logits = nn.dense(params["l4"], z)
    return logits.reshape(b, n, vocab)
