"""LSTM draft language model (the paper's lightweight text draft model).

A single-layer LSTM LM (the paper uses 2x512 for Text-8 and 1x1024 for
Wikitext; we scale to the CPU build budget). Two entrypoints:

* :func:`apply_seq` — teacher-forced next-token logits for training.
* :func:`sample`   — full-sequence ancestral sampling as ONE jax function
  (``lax.scan`` over positions) so the whole draft generation lowers to a
  single HLO artifact. Randomness enters via a Gumbel-noise *input* tensor —
  the Rust coordinator owns the RNG, keeping the artifact deterministic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn


def init(key: jax.Array, vocab: int, dim: int = 128) -> nn.Params:
    ks = jax.random.split(key, 4)
    return {
        "embed": nn.embedding_init(ks[0], vocab, dim),
        # Single fused gate matrix: [x, h] -> 4*dim (i, f, g, o).
        "gates": nn.dense_init(ks[1], 2 * dim, 4 * dim),
        "head": nn.dense_init(ks[2], dim, vocab, scale=0.02),
    }


def _cell(params: nn.Params, x_emb: jnp.ndarray, state: tuple[jnp.ndarray, jnp.ndarray]):
    """One LSTM step. x_emb ``[B, D]``; state = (h, c) each ``[B, D]``."""
    h, c = state
    z = nn.dense(params["gates"], jnp.concatenate([x_emb, h], axis=-1))
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def apply_seq(params: nn.Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced logits: tokens ``[B, N]`` -> next-token logits ``[B, N, V]``.

    Position i's logits predict token i (conditioned on tokens < i); the
    first position is predicted from the zero state with a BOS-less
    convention (embedding of token 0 is not consumed — we shift internally).
    """
    b, n = tokens.shape
    dim = params["embed"].shape[1]
    emb = params["embed"][tokens]  # [B, N, D]
    # Shift right: input at step i is emb[i-1], zeros at i=0.
    inp = jnp.concatenate([jnp.zeros((b, 1, dim), jnp.float32), emb[:, :-1, :]], axis=1)

    def step(carry, x):
        h, c = _cell(params, x, carry)
        return (h, c), h

    init_state = (jnp.zeros((b, dim), jnp.float32), jnp.zeros((b, dim), jnp.float32))
    _, hs = jax.lax.scan(step, init_state, inp.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)  # [B, N, D]
    return nn.dense(params["head"], hs)


def sample(params: nn.Params, gumbel: jnp.ndarray, temperature: float = 1.0) -> jnp.ndarray:
    """Ancestral sampling with externally-supplied Gumbel noise.

    Args:
      params: LSTM parameters.
      gumbel: ``[B, N, V]`` f32 Gumbel(0,1) noise (one per position/vocab).
      temperature: softmax temperature (static).

    Returns:
      ``[B, N]`` int32 sampled tokens.
    """
    b, n, vocab = gumbel.shape
    dim = params["embed"].shape[1]

    def step(carry, g):
        h, c, prev_emb = carry
        h, c = _cell(params, prev_emb, (h, c))
        logits = nn.dense(params["head"], h) / temperature  # [B, V]
        tok = jnp.argmax(logits + g, axis=-1).astype(jnp.int32)  # Gumbel-max
        return (h, c, params["embed"][tok]), tok

    init_state = (
        jnp.zeros((b, dim), jnp.float32),
        jnp.zeros((b, dim), jnp.float32),
        jnp.zeros((b, dim), jnp.float32),
    )
    _, toks = jax.lax.scan(step, init_state, gumbel.transpose(1, 0, 2))
    return toks.transpose(1, 0)  # [B, N]
