"""DiT-tiny: transformer denoiser for text and image token sequences.

Scaled-down version of the paper's generator (DiT, Peebles & Xie 2022; the
paper uses 12 layers / 12 heads / d=768 — we use 2 blocks / 4 heads / d=128
to fit the single-CPU build budget, DESIGN.md §2). Structure per block is
DiT-faithful: adaLN-Zero conditioning on the time embedding (scale/shift/gate
for both the attention and MLP branches), pre-LN, GELU MLP with 4x widening.

The attention inner product runs through either the pure-jnp reference
(training: fastest to trace/differentiate) or the Pallas fused kernel
(AOT inference export — the kernel lowers into the served HLO). The test
suite asserts both paths are allclose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn
from ..kernels.attention import attention as attention_pallas
from ..kernels.ref import attention_ref


def init(
    key: jax.Array,
    vocab: int,
    seq_len: int,
    dim: int = 128,
    heads: int = 4,
    blocks: int = 2,
    mlp_ratio: int = 4,
) -> nn.Params:
    if dim % heads != 0:
        raise ValueError(f"dim={dim} must be divisible by heads={heads}")
    ks = iter(jax.random.split(key, 6 + 8 * blocks))
    params = {
        "embed": nn.embedding_init(next(ks), vocab, dim),
        "pos": nn.embedding_init(next(ks), seq_len, dim),
        "time1": nn.dense_init(next(ks), dim, dim),
        "time2": nn.dense_init(next(ks), dim, dim),
        "head_ln": nn.layer_norm_init(dim),
        "head": nn.dense_init(next(ks), dim, vocab, scale=0.02),
        "blocks": [],
    }
    for _ in range(blocks):
        blk = {
            "ln1": nn.layer_norm_init(dim),
            "qkv": nn.dense_init(next(ks), dim, 3 * dim),
            "proj": nn.dense_init(next(ks), dim, dim, scale=0.02),
            "ln2": nn.layer_norm_init(dim),
            "mlp1": nn.dense_init(next(ks), dim, mlp_ratio * dim),
            "mlp2": nn.dense_init(next(ks), mlp_ratio * dim, dim, scale=0.02),
            # adaLN-Zero: 6 modulation vectors (shift/scale/gate x 2 branches),
            # zero-initialized so each block starts as identity.
            "ada": {
                "w": jnp.zeros((dim, 6 * dim), jnp.float32),
                "b": jnp.zeros((6 * dim,), jnp.float32),
            },
        }
        params["blocks"].append(blk)
    return params


def _modulate(x: jnp.ndarray, shift: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def apply(
    params: nn.Params,
    x_t: jnp.ndarray,
    t: jnp.ndarray,
    *,
    use_pallas: bool = False,
    heads: int = 4,
) -> jnp.ndarray:
    """Denoiser forward: ``[B, N]`` int32 tokens + ``[B]`` times -> ``[B, N, V]`` logits."""
    b, n = x_t.shape
    dim = params["embed"].shape[1]
    dh = dim // heads

    z = params["embed"][x_t] + params["pos"][None, :n, :]
    temb = nn.dense(params["time2"], nn.gelu(nn.dense(params["time1"], nn.time_embedding(t, dim))))

    attn_fn = attention_pallas if use_pallas else attention_ref
    for blk in params["blocks"]:
        mod = nn.dense(blk["ada"], nn.gelu(temb))  # [B, 6*dim]
        s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)

        hx = _modulate(nn.layer_norm(blk["ln1"], z), s1, sc1)
        qkv = nn.dense(blk["qkv"], hx)  # [B, N, 3*dim]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
        o = attn_fn(q, k, v)  # [B, H, N, dh]
        o = o.transpose(0, 2, 1, 3).reshape(b, n, dim)
        z = z + g1[:, None, :] * nn.dense(blk["proj"], o)

        hx = _modulate(nn.layer_norm(blk["ln2"], z), s2, sc2)
        hx = nn.dense(blk["mlp2"], nn.gelu(nn.dense(blk["mlp1"], hx)))
        z = z + g2[:, None, :] * hx

    z = nn.layer_norm(params["head_ln"], z)
    return nn.dense(params["head"], z)
