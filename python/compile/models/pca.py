"""PCA-Gaussian image draft sampler (the DC-GAN substitute, DESIGN.md §2).

The paper uses a DC-GAN as the lightweight image draft model. GAN training
is not feasible in this build's single-CPU budget, so we substitute the
closest classical lightweight generative model: a PCA-Gaussian fitted to the
training images. Samples are ``quantize(mean + U diag(s) z)`` with
``z ~ N(0, I_k)`` — blurry, low-quality, but data-shaped drafts, which is
precisely the role the DC-GAN plays (quality is *supposed* to be poor;
WS-DFM refines it).

The sampler is exported as one HLO artifact with the Gaussian noise ``z`` as
an input tensor (Rust owns the RNG).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def fit(images: np.ndarray, k: int = 24) -> dict:
    """Fit the PCA-Gaussian to quantized token images.

    Args:
      images: ``[M, N]`` uint8/int tokens (flattened pixels, values < vocab).
      k: number of principal components.

    Returns:
      params dict with f32 arrays: mean ``[N]``, comps ``[k, N]``,
      scales ``[k]`` (singular values / sqrt(M)).
    """
    x = images.astype(np.float32)
    mean = x.mean(axis=0)
    xc = x - mean
    # Economy SVD of the centered data.
    u, s, vt = np.linalg.svd(xc, full_matrices=False)
    k = min(k, vt.shape[0])
    return {
        "mean": jnp.asarray(mean),
        "comps": jnp.asarray(vt[:k]),
        "scales": jnp.asarray(s[:k] / np.sqrt(max(1, x.shape[0]))),
    }


def sample(params: dict, z: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Draft images from latent noise.

    Args:
      params: from :func:`fit`.
      z: ``[B, k]`` f32 standard-normal latents (input tensor; Rust RNG).
      vocab: token vocabulary size (e.g. 32 for 5-bit pixels).

    Returns:
      ``[B, N]`` int32 token images in ``[0, vocab)``.
    """
    x = params["mean"][None, :] + (z * params["scales"][None, :]) @ params["comps"]
    x = jnp.clip(jnp.round(x), 0, vocab - 1)
    return x.astype(jnp.int32)
