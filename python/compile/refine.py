"""Refinement / pairing strategies (build-time).

WS-DFM training needs a coupling ``Q(x_t0, x_1) = P_t0(x_t0) * P_refine(x_1 | x_t0)``
(paper §3). Strategies implemented here:

* :func:`nearest_neighbor` — map each draft to its nearest dataset sample
  (used for two-moons and, with ``k > 1`` plus random injection, for images —
  the paper's §4.3 recipe with k = k' = 5).
* :class:`NgramLM` + :func:`oracle_refine` — the LLM-refinement substitute
  for text (DESIGN.md §2): hill-climb the draft under a held-out n-gram LM,
  resampling only the lowest-likelihood positions, bounded edit budget —
  mirroring the paper's prompt "more natural ... but not too different".
* :func:`inject_real` — mix ``x_1 ~ P_1`` pairs into the training set so the
  coupling's right marginal approaches ``P_1`` (paper footnote 2).
"""

from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Nearest-neighbor refinement (two moons, images)
# ---------------------------------------------------------------------------


def nearest_neighbor(drafts: np.ndarray, dataset: np.ndarray, k: int = 1) -> np.ndarray:
    """For each draft row, the ``k`` nearest dataset rows (squared L2).

    Args:
      drafts: ``[M, D]`` numeric array.
      dataset: ``[R, D]`` numeric array.
      k: neighbors per draft.

    Returns:
      ``[M, k]`` int64 indices into ``dataset``.
    """
    d = drafts.astype(np.float32)
    ds = dataset.astype(np.float32)
    # Chunked distance computation to bound memory.
    out = np.empty((d.shape[0], k), np.int64)
    ds_sq = (ds * ds).sum(axis=1)
    chunk = max(1, 2_000_000 // max(1, ds.shape[0]))
    for lo in range(0, d.shape[0], chunk):
        hi = min(lo + chunk, d.shape[0])
        dist = ds_sq[None, :] - 2.0 * d[lo:hi] @ ds.T  # + |d|^2 (constant per row)
        out[lo:hi] = np.argpartition(dist, k - 1, axis=1)[:, :k]
    return out


def knn_pairs(
    drafts: np.ndarray, dataset: np.ndarray, k: int, k_inject: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """The paper's §4.3 image pairing: k-NN refinement + k' random injections.

    Returns ``(x_src, x_1)`` with ``M * (k + k_inject)`` rows each.
    """
    idx = nearest_neighbor(drafts, dataset, k=k)  # [M, k]
    src = [np.repeat(drafts, k, axis=0)]
    tgt = [dataset[idx.reshape(-1)]]
    if k_inject > 0:
        rnd = rng.integers(0, dataset.shape[0], size=drafts.shape[0] * k_inject)
        src.append(np.repeat(drafts, k_inject, axis=0))
        tgt.append(dataset[rnd])
    return np.concatenate(src, axis=0), np.concatenate(tgt, axis=0)


def inject_real(
    x_src: np.ndarray, x_1: np.ndarray, dataset: np.ndarray, frac: float, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Replace a fraction of pairs with (real, real) samples so the coupling's
    right marginal mixes toward P_1 (paper footnote 2)."""
    n = x_src.shape[0]
    m = int(n * frac)
    if m == 0:
        return x_src, x_1
    rows = rng.choice(n, size=m, replace=False)
    real = dataset[rng.integers(0, dataset.shape[0], size=m)]
    x_src = x_src.copy()
    x_1 = x_1.copy()
    x_src[rows] = real
    x_1[rows] = real
    return x_src, x_1


# ---------------------------------------------------------------------------
# Oracle text refiner (LLM substitute)
# ---------------------------------------------------------------------------


class NgramLM:
    """Add-smoothed n-gram LM over int token sequences (the refiner oracle).

    Deliberately simple — the *evaluator* LM lives in Rust
    (``eval/ngram.rs``, Kneser-Ney); this one only guides refinement and is
    trained on the build-time corpus.
    """

    def __init__(self, order: int, vocab: int, alpha: float = 0.1):
        if order < 2:
            raise ValueError("order must be >= 2")
        self.order = order
        self.vocab = vocab
        self.alpha = alpha
        self.counts: dict[tuple[int, ...], np.ndarray] = {}
        self.backoff: np.ndarray = np.zeros(vocab, np.float64)

    def fit(self, stream: np.ndarray) -> "NgramLM":
        o = self.order
        for i in range(len(stream)):
            tok = int(stream[i])
            self.backoff[tok] += 1
            if i >= o - 1:
                ctx = tuple(int(c) for c in stream[i - o + 1 : i])
                row = self.counts.get(ctx)
                if row is None:
                    row = np.zeros(self.vocab, np.float32)
                    self.counts[ctx] = row
                row[tok] += 1
        self.backoff = (self.backoff + 1.0) / (self.backoff.sum() + self.vocab)
        return self

    def cond_probs(self, ctx: tuple[int, ...]) -> np.ndarray:
        """P(. | ctx) with add-alpha smoothing, backing off to unigram."""
        row = self.counts.get(ctx)
        if row is None:
            return self.backoff
        p = (row.astype(np.float64) + self.alpha * self.backoff) / (row.sum() + self.alpha)
        return p / p.sum()

    def token_logprobs(self, seq: np.ndarray) -> np.ndarray:
        """Per-position log P(seq[i] | seq[i-o+1:i])."""
        o = self.order
        out = np.empty(len(seq), np.float64)
        for i in range(len(seq)):
            ctx = tuple(int(c) for c in seq[max(0, i - o + 1) : i])
            if len(ctx) < o - 1:
                p = self.backoff
            else:
                p = self.cond_probs(ctx)
            out[i] = np.log(max(p[int(seq[i])], 1e-12))
        return out


def oracle_refine(
    draft: np.ndarray,
    lm: NgramLM,
    rng: np.random.Generator,
    max_edit_frac: float = 0.35,
    passes: int = 2,
) -> np.ndarray:
    """Refine a draft sequence under the oracle LM, bounded edit distance.

    Greedy coordinate ascent: repeatedly pick the position with the lowest
    conditional log-probability and resample it from the LM conditional
    (argmax with mild noise), stopping after ``max_edit_frac * len`` edits.
    This mirrors the paper's LLM prompt: improve naturalness, stay close.
    """
    seq = draft.astype(np.int64).copy()
    budget = max(1, int(len(seq) * max_edit_frac))
    edited: set[int] = set()
    o = lm.order
    for _ in range(passes):
        lp = lm.token_logprobs(seq)
        order_idx = np.argsort(lp)  # worst first
        for pos in order_idx:
            if len(edited) >= budget:
                break
            pos = int(pos)
            if pos in edited or pos < o - 1:
                continue
            ctx = tuple(int(c) for c in seq[pos - o + 1 : pos])
            p = lm.cond_probs(ctx)
            # Gumbel-max with low temperature: near-greedy but diverse.
            g = rng.gumbel(size=p.shape)
            new_tok = int(np.argmax(np.log(p + 1e-12) / 0.7 + g))
            if np.log(max(p[new_tok], 1e-12)) > lp[pos]:
                seq[pos] = new_tok
                edited.add(pos)
        if len(edited) >= budget:
            break
    return seq.astype(np.int32)


def refine_text_batch(
    drafts: np.ndarray, lm: NgramLM, seed: int, max_edit_frac: float = 0.35
) -> np.ndarray:
    """Vector wrapper: refine each row of ``[M, N]`` drafts."""
    rng = np.random.default_rng(seed)
    return np.stack([oracle_refine(d, lm, rng, max_edit_frac) for d in drafts])
