"""Minimal neural-net toolkit shared by the Layer-2 models.

The image vendors no flax/optax, so parameter initialization, the layers the
models need, and the Adam/AMSGrad optimizer are implemented here directly on
jax pytrees (nested dicts of ``jnp.ndarray``). Everything is deliberately
small and explicit — these models are trained for minutes on one CPU core at
build time (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp.ndarray


# ---------------------------------------------------------------------------
# Initializers / layers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, d_in: int, d_out: int, scale: float | None = None) -> Params:
    """LeCun-normal dense layer parameters."""
    s = scale if scale is not None else 1.0 / (d_in**0.5)
    return {
        "w": jax.random.normal(key, (d_in, d_out), jnp.float32) * s,
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def embedding_init(key: jax.Array, vocab: int, dim: int, scale: float = 0.02) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, dim), jnp.float32) * scale


def layer_norm_init(dim: int) -> Params:
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def time_embedding(t: jnp.ndarray, dim: int, max_period: float = 1e4) -> jnp.ndarray:
    """Sinusoidal time features for ``t in [0, 1]``: ``[B] -> [B, dim]``."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t[:, None] * freqs[None, :] * max_period  # spread t over many scales
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if dim % 2 == 1:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean token CE. logits ``[..., V]``, targets int ``[...]``."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# AMSGrad (the paper trains with AMSGrad — Reddi et al. 2018)
# ---------------------------------------------------------------------------


class AmsGrad:
    """AMSGrad optimizer over an arbitrary pytree of f32 arrays."""

    def __init__(self, lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps

    def init(self, params: Params) -> Params:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "vhat": jax.tree.map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}

    def update(self, grads: Params, state: Params, params: Params) -> tuple[Params, Params]:
        step = state["step"] + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        vhat = jax.tree.map(jnp.maximum, state["vhat"], v)
        # Bias correction on the first moment only (AMSGrad convention).
        corr = 1.0 - b1 ** step.astype(jnp.float32)
        new_params = jax.tree.map(
            lambda p, m_, vh: p - self.lr * (m_ / corr) / (jnp.sqrt(vh) + self.eps),
            params,
            m,
            vhat,
        )
        return new_params, {"m": m, "v": v, "vhat": vhat, "step": step}


def make_train_step(
    loss_fn: Callable[[Params, jax.Array], jnp.ndarray], opt: AmsGrad
) -> Callable[[Params, Params, jax.Array], tuple[Params, Params, jnp.ndarray]]:
    """Jitted (params, opt_state, key) -> (params', opt_state', loss)."""

    @jax.jit
    def step(params, opt_state, key):
        loss, grads = jax.value_and_grad(loss_fn)(params, key)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
