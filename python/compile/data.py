"""Synthetic datasets (build-time side).

Offline substitutes for the paper's datasets (DESIGN.md §2):

* **two moons** — the paper's §4.1 synthetic task, verbatim: points on a
  128x128 integer grid (N=2 tokens, V=128), plus the three *contrived draft
  models* (pretty good / fair / poor) as progressively noisier corruptions
  of the target.
* **synth-text8** — character-level English-like corpus (V=27: a-z + space)
  generated from a word lexicon + simple sentence grammar; stands in for
  Text-8.
* **synth-wiki** — word-level article corpus over a 256-word vocabulary with
  wiki-ish section structure; stands in for Wikitext-103.
* **synth-shapes** — procedural 16x16 gray / 8x8 color images with 10 shape
  classes, 5-bit pixel quantization (V=32); stands in for CIFAR-10.

`make artifacts` materializes the corpora/datasets into ``artifacts/`` so the
Rust side (evaluators, benches) consumes the *same* data the models were
trained on. All generation is deterministic given the seed.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Two moons (paper §4.1): grid 128x128, N=2 tokens, V=128
# ---------------------------------------------------------------------------

TWO_MOONS_GRID = 128

# Draft-model corruption constants. Shared (by value) with
# rust/src/draft/mixture.rs; the cross-language consistency test compares
# histograms. "pretty good" = small jitter; "fair" = moderate jitter + some
# uniform outliers; "poor" = heavy jitter + many outliers (paper Fig. 4 c-e).
DRAFT_SPECS = {
    "good": {"jitter": 3.0, "uniform_frac": 0.02},
    "fair": {"jitter": 8.0, "uniform_frac": 0.15},
    "poor": {"jitter": 16.0, "uniform_frac": 0.40},
}


def two_moons(n: int, rng: np.random.Generator, noise: float = 0.06) -> np.ndarray:
    """Target samples: ``[n, 2]`` int32 tokens on the 128^2 grid."""
    half = n // 2
    theta = rng.uniform(0.0, np.pi, size=n)
    x = np.empty((n, 2), np.float64)
    # Upper moon.
    x[:half, 0] = np.cos(theta[:half])
    x[:half, 1] = np.sin(theta[:half])
    # Lower moon, shifted.
    x[half:, 0] = 1.0 - np.cos(theta[half:])
    x[half:, 1] = 0.5 - np.sin(theta[half:])
    x += rng.normal(scale=noise, size=x.shape)
    return quantize_moons(x)


def quantize_moons(x: np.ndarray) -> np.ndarray:
    """Map raw moon coordinates into ``[0, 128)^2`` integer tokens."""
    g = TWO_MOONS_GRID
    # Raw range is roughly x in [-1.25, 2.25], y in [-0.75, 1.25].
    xs = (x[:, 0] + 1.25) / 3.5
    ys = (x[:, 1] + 0.75) / 2.0
    pts = np.stack([xs, ys], axis=1)
    return np.clip(np.floor(pts * g), 0, g - 1).astype(np.int32)


def two_moons_draft(kind: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Contrived lightweight draft model samples (paper Fig. 4 c-e).

    Target samples corrupted by grid-space Gaussian jitter plus a uniform
    outlier mixture — quality degrades good -> fair -> poor.
    """
    spec = DRAFT_SPECS[kind]
    pts = two_moons(n, rng).astype(np.float64)
    pts += rng.normal(scale=spec["jitter"], size=pts.shape)
    uni = rng.uniform(0, TWO_MOONS_GRID, size=pts.shape)
    mask = rng.uniform(size=(n, 1)) < spec["uniform_frac"]
    pts = np.where(mask, uni, pts)
    return np.clip(np.round(pts), 0, TWO_MOONS_GRID - 1).astype(np.int32)


# ---------------------------------------------------------------------------
# synth-text8: character-level corpus, V = 27 (a-z, space)
# ---------------------------------------------------------------------------

TEXT8_VOCAB = 27  # 'a'..'z' + ' '
TEXT8_CHARS = "abcdefghijklmnopqrstuvwxyz "

# Word lexicon by part of speech. Deliberately compact but structured enough
# that a character LM has real regularities to learn (articles, suffixes,
# agreement-ish templates).
_DET = ["the", "a", "one", "this", "that", "each", "some", "every"]
_ADJ = [
    "small", "large", "old", "young", "red", "blue", "green", "dark", "bright",
    "quiet", "loud", "early", "late", "famous", "local", "ancient", "modern",
    "cold", "warm", "heavy", "light", "rapid", "slow", "simple", "complex",
]
_NOUN = [
    "city", "river", "mountain", "forest", "village", "castle", "bridge",
    "library", "museum", "station", "garden", "island", "valley", "harbor",
    "temple", "market", "road", "tower", "school", "house", "king", "queen",
    "writer", "painter", "soldier", "farmer", "merchant", "scholar", "child",
    "bird", "horse", "wolf", "fish", "tree", "stone", "book", "song", "war",
    "storm", "winter", "summer", "country", "empire", "army", "ship", "train",
]
_VERB = [
    "was", "became", "remained", "stood", "moved", "crossed", "entered",
    "left", "reached", "followed", "carried", "built", "destroyed", "found",
    "lost", "defended", "visited", "described", "painted", "wrote", "sang",
    "ruled", "served", "joined", "formed", "covered", "crossed", "opened",
]
_ADV = ["quickly", "slowly", "often", "rarely", "finally", "suddenly", "quietly", "nearly"]
_PREP = ["in", "on", "near", "under", "over", "beyond", "across", "through", "behind"]
_CONJ = ["and", "but", "while", "because", "although", "before", "after"]
_NUM = ["one", "two", "three", "four", "five", "six", "seven", "eight", "nine", "zero"]


def _np_word(rng: np.random.Generator) -> list[str]:
    """Noun phrase: DET (ADJ)? NOUN."""
    out = [_DET[rng.integers(len(_DET))]]
    if rng.uniform() < 0.6:
        out.append(_ADJ[rng.integers(len(_ADJ))])
    out.append(_NOUN[rng.integers(len(_NOUN))])
    return out


def _sentence(rng: np.random.Generator) -> list[str]:
    """One clause, optionally coordinated (text8-style: no punctuation)."""
    words = _np_word(rng)
    words.append(_VERB[rng.integers(len(_VERB))])
    if rng.uniform() < 0.4:
        words.append(_ADV[rng.integers(len(_ADV))])
    if rng.uniform() < 0.8:
        words.append(_PREP[rng.integers(len(_PREP))])
        words += _np_word(rng)
    if rng.uniform() < 0.15:  # spelled-out year, like text8 number style
        words += ["in", _NUM[rng.integers(len(_NUM))], _NUM[rng.integers(len(_NUM))],
                  _NUM[rng.integers(len(_NUM))], _NUM[rng.integers(len(_NUM))]]
    if rng.uniform() < 0.3:
        words.append(_CONJ[rng.integers(len(_CONJ))])
        words += _np_word(rng)
        words.append(_VERB[rng.integers(len(_VERB))])
    return words


def text8_corpus(n_chars: int, seed: int) -> str:
    """Generate a lowercase a-z+space corpus of exactly ``n_chars`` chars."""
    rng = np.random.default_rng(seed)
    parts: list[str] = []
    total = 0
    while total < n_chars + 64:
        s = " ".join(_sentence(rng))
        parts.append(s)
        total += len(s) + 1
    text = " ".join(parts)[:n_chars]
    assert set(text) <= set(TEXT8_CHARS)
    return text


def text8_encode(text: str) -> np.ndarray:
    """chars -> int32 tokens (a=0..z=25, space=26)."""
    lut = {c: i for i, c in enumerate(TEXT8_CHARS)}
    return np.asarray([lut[c] for c in text], np.int32)


def text8_decode(tokens: np.ndarray) -> str:
    return "".join(TEXT8_CHARS[int(t)] for t in tokens)


def text8_sequences(corpus_tokens: np.ndarray, seq_len: int, n: int, rng: np.random.Generator) -> np.ndarray:
    """Random contiguous windows ``[n, seq_len]`` from the token stream."""
    hi = len(corpus_tokens) - seq_len
    starts = rng.integers(0, hi, size=n)
    return np.stack([corpus_tokens[s : s + seq_len] for s in starts]).astype(np.int32)


# ---------------------------------------------------------------------------
# synth-wiki: word-level corpus, V = 256
# ---------------------------------------------------------------------------

_WIKI_TOPICS = [
    "battle", "album", "species", "hurricane", "railway", "cathedral",
    "election", "dynasty", "expedition", "festival",
]
_WIKI_SECTIONS = ["history", "background", "description", "legacy", "reception", "career"]
_WIKI_FILLER = [
    "it", "he", "she", "they", "which", "first", "second", "later", "early",
    "north", "south", "east", "west", "century", "period", "region", "work",
    "record", "group", "member", "leader", "during", "between", "against",
    "within", "without", "several", "many", "few", "most", "best", "known",
    "called", "named", "made", "held", "given", "taken", "seen", "used",
]


def wiki_vocab() -> list[str]:
    """The synth-wiki vocabulary: exactly 256 word types (incl. specials)."""
    vocab = ["<unk>", "<eos>", "==", "==="]
    pool = _WIKI_TOPICS + _WIKI_SECTIONS + _WIKI_FILLER + _DET + _ADJ + _NOUN + _VERB + _ADV + _PREP + _CONJ + _NUM
    for w in pool:
        if w not in vocab:
            vocab.append(w)
    i = 0
    while len(vocab) < 256:  # pad with numerals like wiki years
        tok = str(1800 + i)
        if tok not in vocab:
            vocab.append(tok)
        i += 1
    return vocab[:256]


def wiki_corpus(n_tokens: int, seed: int) -> np.ndarray:
    """Word-level token stream ``[n_tokens]`` int32 with section structure."""
    vocab = wiki_vocab()
    lut = {w: i for i, w in enumerate(vocab)}
    rng = np.random.default_rng(seed)
    out: list[int] = []

    def emit(words: list[str]) -> None:
        for w in words:
            out.append(lut.get(w, 0))

    while len(out) < n_tokens:
        topic = _WIKI_TOPICS[rng.integers(len(_WIKI_TOPICS))]
        emit(["==", "the", topic, str(1800 + int(rng.integers(0, 200))), "=="])
        for _ in range(int(rng.integers(2, 5))):
            emit(["===", _WIKI_SECTIONS[rng.integers(len(_WIKI_SECTIONS))], "==="])
            for _ in range(int(rng.integers(2, 6))):
                emit(_sentence(rng))
                if rng.uniform() < 0.3:
                    emit([_WIKI_FILLER[rng.integers(len(_WIKI_FILLER))] for _ in range(int(rng.integers(2, 6)))])
                out.append(lut["<eos>"])
    return np.asarray(out[:n_tokens], np.int32)


# ---------------------------------------------------------------------------
# synth-shapes: procedural images, V = 32 (5-bit)
# ---------------------------------------------------------------------------

IMG_VOCAB = 32
GRAY_SIDE = 16  # 16x16 gray  -> N = 256 tokens
COLOR_SIDE = 8  # 8x8x3 color -> N = 192 tokens
N_CLASSES = 10


def _render_shape(cls: int, side: int, rng: np.random.Generator) -> np.ndarray:
    """Render one [side, side] float image in [0,1] for class `cls`."""
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64)
    yy = (yy + 0.5) / side
    xx = (xx + 0.5) / side
    cx, cy = rng.uniform(0.3, 0.7, size=2)
    r = rng.uniform(0.15, 0.35)
    bg = rng.uniform(0.05, 0.3)
    fg = rng.uniform(0.6, 0.95)
    img = np.full((side, side), bg)
    d2 = (xx - cx) ** 2 + (yy - cy) ** 2
    if cls == 0:  # disk
        img = np.where(d2 < r * r, fg, img)
    elif cls == 1:  # square
        img = np.where(np.maximum(np.abs(xx - cx), np.abs(yy - cy)) < r, fg, img)
    elif cls == 2:  # ring
        img = np.where((d2 < r * r) & (d2 > (0.55 * r) ** 2), fg, img)
    elif cls == 3:  # horizontal stripes
        k = rng.integers(2, 5)
        img = np.where(np.sin(yy * np.pi * 2 * k) > 0, fg, bg)
    elif cls == 4:  # vertical stripes
        k = rng.integers(2, 5)
        img = np.where(np.sin(xx * np.pi * 2 * k) > 0, fg, bg)
    elif cls == 5:  # diagonal gradient
        img = bg + (fg - bg) * (xx + yy) / 2.0
    elif cls == 6:  # cross
        w = 0.4 * r
        img = np.where((np.abs(xx - cx) < w) | (np.abs(yy - cy) < w), fg, img)
    elif cls == 7:  # checkerboard
        k = int(rng.integers(2, 4))
        img = np.where(((np.floor(xx * k) + np.floor(yy * k)) % 2) > 0.5, fg, bg)
    elif cls == 8:  # diamond
        img = np.where(np.abs(xx - cx) + np.abs(yy - cy) < r, fg, img)
    else:  # radial gradient
        img = bg + (fg - bg) * np.clip(1.0 - np.sqrt(d2) / 0.7, 0, 1)
    img += rng.normal(scale=0.03, size=img.shape)
    return np.clip(img, 0.0, 1.0)


def shapes_gray(n: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """``([n, 256] int32 tokens, [n] labels)`` gray 16x16 images."""
    imgs = np.empty((n, GRAY_SIDE * GRAY_SIDE), np.int32)
    labels = np.empty((n,), np.int32)
    for i in range(n):
        cls = int(rng.integers(N_CLASSES))
        img = _render_shape(cls, GRAY_SIDE, rng)
        imgs[i] = np.clip(np.floor(img * IMG_VOCAB), 0, IMG_VOCAB - 1).reshape(-1)
        labels[i] = cls
    return imgs, labels


def shapes_color(n: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """``([n, 192] int32 tokens, [n] labels)`` color 8x8x3 images (channel-last)."""
    imgs = np.empty((n, COLOR_SIDE * COLOR_SIDE * 3), np.int32)
    labels = np.empty((n,), np.int32)
    for i in range(n):
        cls = int(rng.integers(N_CLASSES))
        base = _render_shape(cls, COLOR_SIDE, rng)
        tint = rng.uniform(0.4, 1.0, size=3)
        img = np.stack([np.clip(base * t + rng.normal(scale=0.02, size=base.shape), 0, 1) for t in tint], axis=-1)
        imgs[i] = np.clip(np.floor(img * IMG_VOCAB), 0, IMG_VOCAB - 1).reshape(-1)
        labels[i] = cls
    return imgs, labels
