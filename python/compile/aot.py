"""AOT build pipeline: train every model, lower to HLO text, emit artifacts.

This is the single build-time entrypoint (``make artifacts``). It:

1. generates the synthetic datasets/corpora and writes them into
   ``artifacts/`` (the Rust evaluators consume the same data),
2. trains the cold DFM denoiser per domain, the draft models (LSTM / PCA),
   and the WS-DFM fine-tunes per (draft, t0) configuration,
3. lowers each *inference* entrypoint (fused denoise+update step; draft
   samplers) to HLO **text** per compiled batch size — text, not serialized
   protos: jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
   rejects, while the text parser reassigns ids (see /opt/xla-example),
4. writes one ``<name>.meta.json`` per artifact plus a global
   ``manifest.json`` the Rust runtime indexes.

Model weights are baked into the HLO as constants (closure capture at
lowering time), so the served artifact is fully self-contained — the request
path transfers only tokens and three scalars per step.

Build profiles: ``--profile fast`` (default; minutes on one CPU core) and
``--profile full`` (4x training budgets). A content hash over the python
sources + profile short-circuits rebuilds.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, nn, refine, train
from .kernels.dfm_update import dfm_update
from .models import dit, lstm as lstm_model, mlp, pca

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Build configuration
# ---------------------------------------------------------------------------


@dataclass
class Profile:
    name: str
    mult: float  # multiplies training step counts

    def steps(self, base: int) -> int:
        return max(10, int(base * self.mult))


PROFILES = {"fast": Profile("fast", 1.0), "full": Profile("full", 4.0)}

# Paper Table 1 WS configurations: draft kind -> t0 list.
TWO_MOONS_WS = {
    "good": [0.95, 0.9, 0.8],
    "fair": [0.8, 0.5],
    "poor": [0.8, 0.5, 0.35],
}
TEXT_WS_T0 = [0.8, 0.5]       # Tables 2 & 3
IMG_WS_T0 = [0.8, 0.65, 0.5]  # Table 4

BATCH_SIZES = {
    "two_moons": [1, 64, 1024],
    "text8": [1, 8, 32],
    "wiki": [1, 8, 16],
    "img_gray": [1, 8, 16],
    "img_color": [1, 8],
}

DOMAIN_SHAPES = {
    # (seq_len, vocab)
    "two_moons": (2, 128),
    "text8": (64, 27),
    "wiki": (32, 256),
    "img_gray": (256, 32),
    "img_color": (192, 32),
}


# ---------------------------------------------------------------------------
# HLO export
# ---------------------------------------------------------------------------


FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit — must match ``core::rng::fnv1a64`` in Rust."""
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange gotcha)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


@dataclass
class Emitter:
    out_dir: Path
    artifacts: list[dict] = field(default_factory=list)

    def emit(self, name: str, lowered, inputs: list[dict], outputs: list[dict], extra: dict | None = None) -> None:
        hlo = to_hlo_text(lowered)
        hlo_file = f"{name}.hlo.txt"
        (self.out_dir / hlo_file).write_text(hlo)
        meta = {
            "name": name,
            "hlo_file": hlo_file,
            "inputs": inputs,
            "outputs": outputs,
            "hlo_bytes": len(hlo),
            # Versioned artifact contract: FNV-1a 64 over the HLO bytes,
            # the same hash `wsfm verify-artifacts` recomputes.
            "content_hash": f"{fnv1a64(hlo.encode()):016x}",
        }
        if extra:
            meta.update(extra)
        (self.out_dir / f"{name}.meta.json").write_text(json.dumps(meta, indent=1))
        self.artifacts.append(meta)
        print(f"  emitted {name} ({len(hlo) / 1e6:.2f} MB hlo)", flush=True)


def spec(shape: list[int], dtype: str, name: str) -> dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def export_step_artifacts(em: Emitter, domain: str, tag: str, apply_fn, params, extra: dict) -> list[str]:
    """Lower the fused denoise+update step for every compiled batch size.

    Step signature (uniform across every domain/t0 — the Rust runtime
    depends on this): ``(x_t i32[B,N], t f32[], h f32[], warp f32[]) ->
    (probs f32[B,N,V],)``.
    """
    n, v = DOMAIN_SHAPES[domain]
    names = []

    def step(x_t, t, h, warp):
        tb = jnp.full((x_t.shape[0],), t, jnp.float32)
        logits = apply_fn(params, x_t, tb)
        return (dfm_update(logits, x_t, t, h, warp, interpret=True),)

    for b in BATCH_SIZES[domain]:
        name = f"{domain}_{tag}_step_b{b}"
        lowered = jax.jit(step).lower(
            SDS((b, n), jnp.int32), SDS((), jnp.float32), SDS((), jnp.float32), SDS((), jnp.float32)
        )
        em.emit(
            name,
            lowered,
            inputs=[
                spec([b, n], "s32", "x_t"),
                spec([], "f32", "t"),
                spec([], "f32", "h"),
                spec([], "f32", "warp"),
            ],
            outputs=[spec([b, n, v], "f32", "probs")],
            extra={"domain": domain, "kind": "step", "tag": tag, "batch": b, "seq_len": n, "vocab": v, **extra},
        )
        names.append(name)
    return names


def export_lstm_draft(em: Emitter, domain: str, params, temperature: float) -> list[str]:
    n, v = DOMAIN_SHAPES[domain]
    names = []
    for b in BATCH_SIZES[domain]:
        name = f"{domain}_draft_lstm_b{b}"
        lowered = jax.jit(
            lambda g: (lstm_model.sample(params, g, temperature=temperature),)
        ).lower(SDS((b, n, v), jnp.float32))
        em.emit(
            name,
            lowered,
            inputs=[spec([b, n, v], "f32", "gumbel")],
            outputs=[spec([b, n], "s32", "tokens")],
            extra={"domain": domain, "kind": "draft", "draft": "lstm", "batch": b, "seq_len": n, "vocab": v},
        )
        names.append(name)
    return names


def export_pca_draft(em: Emitter, domain: str, pca_params, k: int) -> list[str]:
    n, v = DOMAIN_SHAPES[domain]
    names = []
    for b in BATCH_SIZES[domain]:
        name = f"{domain}_draft_pca_b{b}"
        lowered = jax.jit(lambda z: (pca.sample(pca_params, z, v),)).lower(SDS((b, k), jnp.float32))
        em.emit(
            name,
            lowered,
            inputs=[spec([b, k], "f32", "z")],
            outputs=[spec([b, n], "s32", "tokens")],
            extra={"domain": domain, "kind": "draft", "draft": "pca", "batch": b, "seq_len": n, "vocab": v, "latent_dim": k},
        )
        names.append(name)
    return names


# ---------------------------------------------------------------------------
# Domain builders
# ---------------------------------------------------------------------------


def build_two_moons(em: Emitter, prof: Profile, seed: int = 0) -> dict:
    print("[two_moons] building", flush=True)
    rng = np.random.default_rng(seed)
    n_tok, vocab = DOMAIN_SHAPES["two_moons"]
    dataset = data.two_moons(8192, rng)

    key = jax.random.PRNGKey(seed)
    params = mlp.init(key, vocab=vocab, hidden=128, n_tokens=n_tok)
    apply_fn = lambda p, x, t: mlp.apply(p, x, t)

    cold = train.train_dfm(
        apply_fn, params, train.pairs_noise_data(dataset, vocab, batch=256),
        steps=prof.steps(800), lr=3e-4, t0=0.0, seed=seed, name="tm-cold",
    )
    export_step_artifacts(em, "two_moons", "cold", apply_fn, cold.params,
                          {"t0": 0.0, "train_loss": [cold.loss_start, cold.loss_end]})

    ws_tags: dict[str, list[dict]] = {}
    for kind, t0s in TWO_MOONS_WS.items():
        drafts = data.two_moons_draft(kind, 4096, rng)
        # Paper §4.3 recipe: k-NN refinement plus random real injections so
        # the coupling's right marginal approaches P1 (footnote 2). Pure
        # NN-1 projection barely improves the marginal (measured SKL 1.47
        # for the fair draft vs 0.37 with k=5 + 10 injections) and WS-DFM
        # converges to the coupling marginal, so injection is load-bearing.
        k_inject = {"good": 10, "fair": 10, "poor": 20}[kind]
        x_src, x_1 = refine.knn_pairs(drafts, dataset, k=5, k_inject=k_inject, rng=rng)
        for t0 in t0s:
            tag = f"ws_{kind}_t{int(round(t0 * 100)):03d}"
            ws = train.train_dfm(
                apply_fn, cold.params, train.pairs_from_arrays(x_src, x_1, batch=256),
                steps=prof.steps(1200), lr=2e-4, t0=t0, seed=seed + 1, name=f"tm-{tag}",
            )
            export_step_artifacts(em, "two_moons", tag, apply_fn, ws.params,
                                  {"t0": t0, "draft": kind, "train_loss": [ws.loss_start, ws.loss_end]})
            ws_tags.setdefault(kind, []).append({"t0": t0, "tag": tag})

    return {
        "seq_len": n_tok, "vocab": vocab, "grid": data.TWO_MOONS_GRID,
        "draft_specs": data.DRAFT_SPECS, "ws": ws_tags, "cold_steps": 20,
    }


def _build_text_domain(
    em: Emitter, prof: Profile, domain: str, corpus_tokens: np.ndarray,
    seed: int, lstm_dim: int, refiner_order: int, dit_cfg: dict,
) -> dict:
    n, vocab = DOMAIN_SHAPES[domain]
    seqs = data.text8_sequences(corpus_tokens, n, 4096, np.random.default_rng(seed))

    key = jax.random.PRNGKey(seed)
    params = dit.init(key, vocab=vocab, seq_len=n, **dit_cfg)
    heads = dit_cfg.get("heads", 4)
    train_apply = lambda p, x, t: dit.apply(p, x, t, use_pallas=False, heads=heads)
    serve_apply = lambda p, x, t: dit.apply(p, x, t, use_pallas=True, heads=heads)

    cold = train.train_dfm(
        train_apply, params, train.pairs_noise_data(seqs, vocab, batch=32),
        steps=prof.steps(400), lr=3e-4, t0=0.0, seed=seed, name=f"{domain}-cold",
    )
    export_step_artifacts(em, domain, "cold", serve_apply, cold.params,
                          {"t0": 0.0, "train_loss": [cold.loss_start, cold.loss_end]})

    # LSTM draft model.
    lstm_params = lstm_model.init(jax.random.PRNGKey(seed + 7), vocab=vocab, dim=lstm_dim)
    lres = train.train_lstm(lstm_params, seqs, steps=prof.steps(500), lr=2e-3, batch=32,
                            seed=seed, name=f"{domain}-lstm")
    export_lstm_draft(em, domain, lres.params, temperature=1.0)

    # Draft sampling + oracle refinement -> WS training pairs.
    n_pairs = 768 if prof.name == "fast" else 4096
    sample_b = 64
    gkey = jax.random.PRNGKey(seed + 11)
    sample_jit = jax.jit(lambda g: lstm_model.sample(lres.params, g))
    chunks = []
    for _ in range(0, n_pairs, sample_b):
        gkey, sub = jax.random.split(gkey)
        g = jax.random.gumbel(sub, (sample_b, n, vocab), jnp.float32)
        chunks.append(np.asarray(sample_jit(g)))
    drafts = np.concatenate(chunks)[:n_pairs]

    lm = refine.NgramLM(order=refiner_order, vocab=vocab).fit(corpus_tokens[:200_000])
    refined = refine.refine_text_batch(drafts, lm, seed=seed + 13)
    x_src, x_1 = refine.inject_real(drafts, refined, seqs, 0.15, np.random.default_rng(seed + 17))

    ws_tags = []
    for t0 in TEXT_WS_T0:
        tag = f"ws_t{int(round(t0 * 100)):03d}"
        ws = train.train_dfm(
            train_apply, cold.params, train.pairs_from_arrays(x_src, x_1, batch=32),
            steps=prof.steps(200), lr=3e-5, t0=t0, seed=seed + 1, name=f"{domain}-{tag}",
        )
        export_step_artifacts(em, domain, tag, serve_apply, ws.params,
                              {"t0": t0, "draft": "lstm", "train_loss": [ws.loss_start, ws.loss_end]})
        ws_tags.append({"t0": t0, "tag": tag})

    return {"seq_len": n, "vocab": vocab, "ws": ws_tags, "lstm_dim": lstm_dim,
            "lstm_params": nn.count_params(lres.params), "dit_params": nn.count_params(cold.params)}


def build_text8(em: Emitter, prof: Profile, seed: int = 1) -> dict:
    print("[text8] building", flush=True)
    n_chars = 400_000 if prof.name == "fast" else 2_000_000
    corpus = data.text8_corpus(n_chars, seed=seed)
    eval_corpus = data.text8_corpus(n_chars // 4, seed=seed + 1000)
    (em.out_dir / "text8_corpus.txt").write_text(corpus)
    (em.out_dir / "text8_eval.txt").write_text(eval_corpus)
    info = _build_text_domain(
        em, prof, "text8", data.text8_encode(corpus),
        seed=seed, lstm_dim=128, refiner_order=4,
        dit_cfg={"dim": 128, "heads": 4, "blocks": 2},
    )
    info.update({"charset": data.TEXT8_CHARS, "corpus_file": "text8_corpus.txt", "eval_file": "text8_eval.txt"})
    return info


def build_wiki(em: Emitter, prof: Profile, seed: int = 2) -> dict:
    print("[wiki] building", flush=True)
    n_tokens = 300_000 if prof.name == "fast" else 1_500_000
    corpus = data.wiki_corpus(n_tokens, seed=seed)
    eval_corpus = data.wiki_corpus(n_tokens // 4, seed=seed + 1000)
    corpus.astype(np.int32).tofile(em.out_dir / "wiki_corpus.bin")
    eval_corpus.astype(np.int32).tofile(em.out_dir / "wiki_eval.bin")
    (em.out_dir / "wiki_vocab.json").write_text(json.dumps(data.wiki_vocab()))
    info = _build_text_domain(
        em, prof, "wiki", corpus,
        seed=seed, lstm_dim=128, refiner_order=3,
        dit_cfg={"dim": 128, "heads": 4, "blocks": 2},
    )
    info.update({"vocab_file": "wiki_vocab.json", "corpus_file": "wiki_corpus.bin", "eval_file": "wiki_eval.bin"})
    return info


def _build_image_domain(em: Emitter, prof: Profile, domain: str, seed: int) -> dict:
    n, vocab = DOMAIN_SHAPES[domain]
    rng = np.random.default_rng(seed)
    n_train = 4096 if prof.name == "fast" else 16384
    if domain == "img_gray":
        dataset, labels = data.shapes_gray(n_train, rng)
        side, channels = data.GRAY_SIDE, 1
    else:
        dataset, labels = data.shapes_color(n_train, rng)
        side, channels = data.COLOR_SIDE, 3
    dataset.astype(np.uint8).tofile(em.out_dir / f"{domain}_train.bin")
    labels.astype(np.uint8).tofile(em.out_dir / f"{domain}_labels.bin")

    key = jax.random.PRNGKey(seed)
    params = dit.init(key, vocab=vocab, seq_len=n, dim=128, heads=4, blocks=2)
    train_apply = lambda p, x, t: dit.apply(p, x, t, use_pallas=False, heads=4)
    serve_apply = lambda p, x, t: dit.apply(p, x, t, use_pallas=True, heads=4)

    cold = train.train_dfm(
        train_apply, params, train.pairs_noise_data(dataset, vocab, batch=8),
        steps=prof.steps(300), lr=3e-4, t0=0.0, seed=seed, name=f"{domain}-cold",
    )
    export_step_artifacts(em, domain, "cold", serve_apply, cold.params,
                          {"t0": 0.0, "train_loss": [cold.loss_start, cold.loss_end]})

    # PCA-Gaussian draft (DC-GAN substitute, DESIGN.md §2).
    k = 24
    pca_params = pca.fit(dataset, k=k)
    export_pca_draft(em, domain, pca_params, k=k)

    # Draft sampling + paper §4.3 pairing: k-NN (k=5) + k'=5 random injections.
    n_draft = 256 if prof.name == "fast" else 1024
    z = rng.normal(size=(n_draft, k)).astype(np.float32)
    drafts = np.asarray(jax.jit(lambda zz: pca.sample(pca_params, zz, vocab))(z))
    x_src, x_1 = refine.knn_pairs(drafts, dataset, k=5, k_inject=5, rng=rng)

    # Figure 11 aux: the k-NN examples for the first few drafts.
    knn_idx = refine.nearest_neighbor(drafts[:8], dataset, k=5)
    (em.out_dir / f"fig11_knn_{domain}.json").write_text(json.dumps(knn_idx.tolist()))

    ws_tags = []
    for t0 in IMG_WS_T0:
        tag = f"ws_t{int(round(t0 * 100)):03d}"
        ws = train.train_dfm(
            train_apply, cold.params, train.pairs_from_arrays(x_src, x_1, batch=8),
            steps=prof.steps(150), lr=1e-4, t0=t0, seed=seed + 1, name=f"{domain}-{tag}",
        )
        export_step_artifacts(em, domain, tag, serve_apply, ws.params,
                              {"t0": t0, "draft": "pca", "train_loss": [ws.loss_start, ws.loss_end]})
        ws_tags.append({"t0": t0, "tag": tag})

    return {
        "seq_len": n, "vocab": vocab, "side": side, "channels": channels,
        "ws": ws_tags, "pca_k": k, "train_file": f"{domain}_train.bin",
        "labels_file": f"{domain}_labels.bin", "n_train": n_train,
    }


def build_img_gray(em: Emitter, prof: Profile, seed: int = 3) -> dict:
    print("[img_gray] building", flush=True)
    return _build_image_domain(em, prof, "img_gray", seed)


def build_img_color(em: Emitter, prof: Profile, seed: int = 4) -> dict:
    print("[img_color] building", flush=True)
    return _build_image_domain(em, prof, "img_color", seed)


BUILDERS = {
    "two_moons": build_two_moons,
    "text8": build_text8,
    "wiki": build_wiki,
    "img_gray": build_img_gray,
    "img_color": build_img_color,
}


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def source_hash(profile: str) -> str:
    h = hashlib.sha256()
    root = Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    h.update(profile.encode())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description="wsfm AOT artifact builder")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile", default=os.environ.get("WSFM_PROFILE", "fast"), choices=list(PROFILES))
    ap.add_argument("--domains", default="all", help="comma list or 'all'")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    domains = list(BUILDERS) if args.domains == "all" else args.domains.split(",")
    for d in domains:
        if d not in BUILDERS:
            raise SystemExit(f"unknown domain {d!r}; options: {list(BUILDERS)}")

    # Per-domain incremental builds: the manifest accumulates across runs and
    # a per-domain source hash (over all python sources + profile) decides
    # staleness, so `make artifacts` is a no-op when nothing changed.
    shash = source_hash(args.profile)
    hash_file = out_dir / ".build_hash.json"
    manifest_file = out_dir / "manifest.json"
    hashes: dict = json.loads(hash_file.read_text()) if hash_file.exists() else {}
    manifest: dict = (
        json.loads(manifest_file.read_text())
        if manifest_file.exists()
        else {"schema_version": 2, "batch_sizes": BATCH_SIZES, "domains": {}, "artifacts": []}
    )
    # Manifests written before the versioned contract upgrade in place.
    manifest["schema_version"] = 2

    todo = [d for d in domains if args.force or hashes.get(d) != shash or d not in manifest["domains"]]
    skipped = [d for d in domains if d not in todo]
    if skipped:
        print(f"up to date: {', '.join(skipped)}")
    if not todo:
        print("all requested domains up to date — nothing to build")
        return

    t_start = time.time()
    em = Emitter(out_dir=out_dir)
    for d in todo:
        t0 = time.time()
        info = BUILDERS[d](em, PROFILES[args.profile])
        manifest["domains"][d] = info
        hashes[d] = shash
        # Replace this domain's artifact entries, keep the others.
        manifest["artifacts"] = [a for a in manifest["artifacts"] if a.get("domain") != d]
        manifest["artifacts"] += [a for a in em.artifacts if a.get("domain") == d]
        manifest["profile"] = args.profile
        manifest_file.write_text(json.dumps(manifest, indent=1))
        hash_file.write_text(json.dumps(hashes, indent=1))
        print(f"[{d}] done in {time.time() - t0:.1f}s", flush=True)

    print(f"built {len(todo)} domains ({len(em.artifacts)} artifacts) in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
