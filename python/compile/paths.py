"""Probability paths for cold DFM and warm-start DFM (Layer 2).

Denoiser parameterization (DESIGN.md §1): per token i,

    P_t(x^i | x_src, x_1) = (1 - kappa(t)) * delta_{x_src^i} + kappa(t) * delta_{x_1^i}

with ``kappa(t) = t`` for the cold path on ``[0, 1]`` (x_src = pure noise)
and ``kappa(t) = (t - t0) / (1 - t0)`` for the warm path on ``[t0, 1]``
(x_src = draft samples). The warm path is the *normalized* convex version of
the paper's stated interpolation (whose coefficients do not sum to one — see
DESIGN.md §1); at ``t0 = 0`` it reduces exactly to the cold path, a property
the test suite pins down.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kappa(t: jnp.ndarray, t0: float | jnp.ndarray = 0.0) -> jnp.ndarray:
    """Mixing coefficient ``kappa(t) = (t - t0) / (1 - t0)``, clipped to [0, 1]."""
    t0 = jnp.asarray(t0, jnp.float32)
    k = (jnp.asarray(t, jnp.float32) - t0) / jnp.maximum(1.0 - t0, 1e-6)
    return jnp.clip(k, 0.0, 1.0)


def sample_t(key: jax.Array, batch: int, t0: float = 0.0) -> jnp.ndarray:
    """Per-example training times ``t ~ U(t0, 1)`` (paper Fig. 2, right)."""
    return t0 + (1.0 - t0) * jax.random.uniform(key, (batch,), jnp.float32)


def interpolate(
    key: jax.Array,
    x_src: jnp.ndarray,
    x_1: jnp.ndarray,
    t: jnp.ndarray,
    t0: float = 0.0,
) -> jnp.ndarray:
    """Sample ``x_t ~ P_t(. | x_src, x_1)`` token-wise.

    Args:
      key: PRNG key.
      x_src: ``[B, N]`` int tokens from the source (noise or draft) dist.
      x_1: ``[B, N]`` int tokens from the target (data or refined) dist.
      t: ``[B]`` per-example times.
      t0: warm-start time (python float; 0 = cold).

    Returns:
      ``[B, N]`` int32 interpolated tokens: each token independently equals
      ``x_1`` with probability ``kappa(t)`` else ``x_src``.
    """
    if x_src.shape != x_1.shape:
        raise ValueError(f"x_src {x_src.shape} != x_1 {x_1.shape}")
    k = kappa(t, t0)[:, None]  # [B, 1]
    u = jax.random.uniform(key, x_src.shape, jnp.float32)
    take_x1 = u < k
    return jnp.where(take_x1, x_1, x_src).astype(jnp.int32)


def uniform_noise(key: jax.Array, shape: tuple[int, ...], vocab: int) -> jnp.ndarray:
    """Pure-noise source: uniform over the vocabulary (paper Fig. 3 left)."""
    return jax.random.randint(key, shape, 0, vocab, jnp.int32)


def mask_noise(shape: tuple[int, ...], mask_token: int) -> jnp.ndarray:
    """Mask-delta source: every token is the special mask state."""
    return jnp.full(shape, mask_token, jnp.int32)


def nfe(steps_cold: int, t0: float) -> int:
    """The paper's guaranteed NFE: ``ceil(steps_cold * (1 - t0))``.

    This is the whole headline claim — the warm sampler takes exactly this
    many denoiser evaluations, a ``1/(1-t0)`` speed-up over ``steps_cold``.
    Mirrored by ``rust/src/core/schedule.rs`` and pinned by tests on both
    sides.

    Epsilon-robust: ``1 - t0`` carries one f64 rounding (~1e-16 relative),
    so the product's absolute error grows with ``steps_cold``. The combined
    absolute + relative epsilon snaps grid-boundary values (e.g.
    ``t0 = 1 - k/steps_cold`` computed in float) back to the integer the
    exact arithmetic would give; it must stay identical to ``nfe_eps`` in
    ``rust/src/core/schedule.rs`` (boundary cases pinned in
    ``rust/tests/cross_lang.rs`` and ``python/tests/test_paths.py``).
    Clamped to ``[1, steps_cold]``: warm never pays more than cold.
    """
    if not 0.0 <= t0 < 1.0:
        raise ValueError(f"t0 must be in [0, 1), got {t0}")
    import math

    eps = 1e-9 + steps_cold * 1e-12
    return min(max(steps_cold, 1), max(1, math.ceil(steps_cold * (1.0 - t0) - eps)))
