"""DFM / WS-DFM training loops (build-time, paper Fig. 2).

One generic trainer covers both algorithms; the only differences (paper
Fig. 2, red) are the source of the ``(x_src, x_1)`` pairs and the time range:

* **cold DFM**:   x_src ~ uniform noise,          t ~ U(0, 1)
* **WS-DFM**:     (x_src, x_1) = (draft, refined), t ~ U(t0, 1)

Loss is the J=1 denoiser cross-entropy of eq. (6): sample ``x_t`` from the
pinned path, predict ``x_1`` tokens. WS-DFM fine-tunes from the cold
checkpoint with a reduced learning rate (paper §4.2.1).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import nn, paths


@dataclass
class TrainResult:
    params: nn.Params
    losses: list[float] = field(default_factory=list)

    @property
    def loss_start(self) -> float:
        return float(np.mean(self.losses[: max(1, len(self.losses) // 10)]))

    @property
    def loss_end(self) -> float:
        return float(np.mean(self.losses[-max(1, len(self.losses) // 10) :]))


def make_dfm_loss(
    apply_fn: Callable[[nn.Params, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    pair_fn: Callable[[jax.Array], tuple[jnp.ndarray, jnp.ndarray]],
    t0: float,
):
    """Build the DFM loss closure.

    ``pair_fn(key) -> (x_src, x_1)`` supplies a batch of coupled pairs
    (noise+data for cold, draft+refined for warm); everything downstream is
    identical between the two algorithms.
    """

    def loss_fn(params: nn.Params, key: jax.Array) -> jnp.ndarray:
        k_pair, k_t, k_interp = jax.random.split(key, 3)
        x_src, x_1 = pair_fn(k_pair)
        t = paths.sample_t(k_t, x_src.shape[0], t0)
        x_t = paths.interpolate(k_interp, x_src, x_1, t, t0)
        logits = apply_fn(params, x_t, t)
        return nn.cross_entropy(logits, x_1)

    return loss_fn


def train_dfm(
    apply_fn,
    params: nn.Params,
    pair_fn,
    *,
    steps: int,
    lr: float,
    t0: float = 0.0,
    seed: int = 0,
    log_every: int = 50,
    name: str = "dfm",
) -> TrainResult:
    """Run the paper's Fig. 2 training loop (cold if t0=0, warm otherwise)."""
    opt = nn.AmsGrad(lr)
    opt_state = opt.init(params)
    step_fn = nn.make_train_step(make_dfm_loss(apply_fn, pair_fn, t0), opt)
    key = jax.random.PRNGKey(seed)
    losses: list[float] = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, sub)
        losses.append(float(loss))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"  [{name}] step {i:5d}/{steps} loss {float(loss):.4f}", flush=True)
    return TrainResult(params=params, losses=losses)


# ---------------------------------------------------------------------------
# Pair samplers
# ---------------------------------------------------------------------------


def pairs_from_arrays(x_src: np.ndarray, x_1: np.ndarray, batch: int):
    """Coupled pairs drawn row-aligned from fixed arrays (WS-DFM)."""
    if x_src.shape != x_1.shape:
        raise ValueError(f"pair shapes differ: {x_src.shape} vs {x_1.shape}")
    src = jnp.asarray(x_src, jnp.int32)
    tgt = jnp.asarray(x_1, jnp.int32)

    def pair_fn(key: jax.Array):
        idx = jax.random.randint(key, (batch,), 0, src.shape[0])
        return src[idx], tgt[idx]

    return pair_fn


def pairs_noise_data(data: np.ndarray, vocab: int, batch: int):
    """Independent coupling Q = P0 x P1 with P0 = uniform noise (cold DFM)."""
    tgt = jnp.asarray(data, jnp.int32)

    def pair_fn(key: jax.Array):
        k_idx, k_noise = jax.random.split(key)
        idx = jax.random.randint(k_idx, (batch,), 0, tgt.shape[0])
        x_1 = tgt[idx]
        x_src = paths.uniform_noise(k_noise, x_1.shape, vocab)
        return x_src, x_1

    return pair_fn


# ---------------------------------------------------------------------------
# LSTM draft-model training (next-token LM)
# ---------------------------------------------------------------------------


def train_lstm(
    params: nn.Params,
    sequences: np.ndarray,
    *,
    steps: int,
    lr: float,
    batch: int,
    seed: int = 0,
    log_every: int = 50,
    name: str = "lstm",
) -> TrainResult:
    """Standard teacher-forced LM training for the draft model."""
    from .models import lstm as lstm_model

    seqs = jnp.asarray(sequences, jnp.int32)

    def loss_fn(p: nn.Params, key: jax.Array) -> jnp.ndarray:
        idx = jax.random.randint(key, (batch,), 0, seqs.shape[0])
        toks = seqs[idx]
        logits = lstm_model.apply_seq(p, toks)
        return nn.cross_entropy(logits, toks)

    opt = nn.AmsGrad(lr)
    opt_state = opt.init(params)
    step_fn = nn.make_train_step(loss_fn, opt)
    key = jax.random.PRNGKey(seed)
    losses: list[float] = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, sub)
        losses.append(float(loss))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"  [{name}] step {i:5d}/{steps} loss {float(loss):.4f}", flush=True)
    return TrainResult(params=params, losses=losses)
