//! Probability utilities on the sampling hot path.
//!
//! Categorical sampling from the `[B, N, V]` transition-probability tensor
//! returned by the fused `dfm_update` artifact is the only per-token work
//! the coordinator does per Euler step, so it must be allocation-free and
//! branch-light (see EXPERIMENTS.md §Perf).

use crate::core::rng::Pcg64;

/// In-place softmax over a slice.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Sample one index from an (unnormalized, non-negative) weight row via
/// inverse-CDF. Robust to rows that don't sum exactly to 1.
#[inline]
pub fn categorical(weights: &[f32], rng: &mut Pcg64) -> usize {
    debug_assert!(!weights.is_empty());
    let total: f32 = weights.iter().sum();
    let mut target = rng.uniform_f32() * total;
    let mut last_nonzero = 0;
    for (i, &w) in weights.iter().enumerate() {
        if w > 0.0 {
            last_nonzero = i;
            if target < w {
                return i;
            }
            target -= w;
        }
    }
    last_nonzero // float round-off fell off the end
}

/// Sample every token of a `[B, N, V]` probs tensor into `out` (`[B * N]`).
///
/// This is THE hot loop: one pass over the probs buffer, no allocation.
pub fn categorical_batch(probs: &[f32], vocab: usize, out: &mut [i32], rng: &mut Pcg64) {
    debug_assert_eq!(probs.len(), out.len() * vocab);
    for (row_i, slot) in out.iter_mut().enumerate() {
        let row = &probs[row_i * vocab..(row_i + 1) * vocab];
        *slot = categorical(row, rng) as i32;
    }
}

/// Argmax over a row (used for greedy final-step decoding variants).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Shannon entropy (nats) of a normalized distribution.
pub fn entropy(p: &[f64]) -> f64 {
    p.iter().filter(|&&x| x > 0.0).map(|&x| -x * x.ln()).sum()
}

/// Shannon entropy in bits.
pub fn entropy_bits(p: &[f64]) -> f64 {
    entropy(p) / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1000.0, -1000.0];
        softmax(&mut xs);
        assert!((xs[0] - 1.0).abs() < 1e-6);
        assert!(xs[1] >= 0.0);
        softmax(&mut []); // no panic
    }

    #[test]
    fn categorical_degenerate() {
        let mut rng = Pcg64::new(0);
        let w = vec![0.0, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(categorical(&w, &mut rng), 2);
        }
    }

    #[test]
    fn categorical_frequencies_match() {
        let mut rng = Pcg64::new(1);
        let w = vec![0.1f32, 0.2, 0.7];
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[categorical(&w, &mut rng)] += 1;
        }
        for (i, &target) in [0.1, 0.2, 0.7].iter().enumerate() {
            let f = counts[i] as f64 / n as f64;
            assert!((f - target).abs() < 0.01, "idx {i}: {f} vs {target}");
        }
    }

    #[test]
    fn categorical_unnormalized_ok() {
        let mut rng = Pcg64::new(2);
        let w = vec![1.0f32, 3.0]; // sums to 4
        let n = 40_000;
        let ones = (0..n).filter(|_| categorical(&w, &mut rng) == 1).count();
        let f = ones as f64 / n as f64;
        assert!((f - 0.75).abs() < 0.01, "{f}");
    }

    #[test]
    fn categorical_batch_shapes() {
        let mut rng = Pcg64::new(3);
        let vocab = 4;
        let probs = vec![0.25f32; 2 * 3 * vocab];
        let mut out = vec![0i32; 6];
        categorical_batch(&probs, vocab, &mut out, &mut rng);
        assert!(out.iter().all(|&t| (0..4).contains(&t)));
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn entropy_known_values() {
        assert!((entropy_bits(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!(entropy(&[1.0, 0.0]).abs() < 1e-12);
        let u = vec![0.25; 4];
        assert!((entropy_bits(&u) - 2.0).abs() < 1e-12);
    }
}
