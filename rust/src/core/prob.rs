//! Probability utilities on the sampling hot path.
//!
//! Categorical sampling from the `[B, N, V]` transition-probability tensor
//! returned by the fused `dfm_update` artifact is the only per-token work
//! the coordinator does per Euler step, so it must be allocation-free,
//! branch-light, and — for the engine-resident loop — parallelizable with
//! a deterministic result (see EXPERIMENTS.md §Perf).
//!
//! Two sampling surfaces exist:
//!
//! * [`categorical`] / [`categorical_batch`] — draw from a caller-owned
//!   sequential [`Pcg64`]; RNG state threads through every row in order.
//! * [`categorical_batch_seeded`] / [`categorical_batch_par`] — every row
//!   of every step draws from its own stateless substream
//!   ([`Pcg64::substream`]), so rows are order- and thread-independent and
//!   the parallel path is bitwise-identical to the sequential one.
//!
//! Degenerate rows: a row with no strictly-positive finite weight (all
//! zeros, all NaN, or a non-finite total) carries no usable distribution.
//! Every sampler here deterministically returns [`DEGENERATE_TOKEN`] for
//! such rows instead of silently falling through — pinned by tests.

use crate::core::rng::Pcg64;
use crate::core::workers::WorkerPool;

/// The documented fallback index for degenerate weight rows.
pub const DEGENERATE_TOKEN: usize = 0;

/// Rows-per-chunk floor for the parallel path: below roughly this many
/// rows, scoped-spawn overhead beats the row work, so the pool runs the
/// batch inline (keeping small-batch sampling spawn- and alloc-free).
pub const PAR_MIN_ROWS: usize = 512;

/// In-place softmax over a slice.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Sample one index from an (unnormalized, non-negative) weight row in a
/// **single pass** via online replacement: element `i` (with positive
/// finite weight `w_i` and running total `S_i`) replaces the current
/// winner with probability `w_i / S_i`, which yields exactly
/// `P(i) = w_i / S_n`. Robust to rows that don't sum to 1; NaN and
/// non-positive weights are skipped; a fully degenerate row returns
/// [`DEGENERATE_TOKEN`].
///
/// Consumes one uniform draw per usable weight — for single-row use where
/// that cost is irrelevant. The batched hot path ([`categorical_batch`]
/// and friends) instead uses the one-draw-per-row inverse-CDF kernel
/// [`sample_row_icdf`].
#[inline]
pub fn categorical(weights: &[f32], rng: &mut Pcg64) -> usize {
    debug_assert!(!weights.is_empty());
    let mut total = 0.0f32;
    let mut winner = DEGENERATE_TOKEN;
    let mut found = false;
    for (i, &w) in weights.iter().enumerate() {
        if w > 0.0 && w.is_finite() {
            total += w;
            if !found || rng.uniform_f32() * total < w {
                winner = i;
                found = true;
            }
        }
    }
    winner
}

/// One-draw inverse-CDF over the positive finite weights of a row, given a
/// pre-drawn uniform `u ∈ [0, 1)`. Returns `None` for degenerate rows
/// (no positive finite weight, or a non-finite total).
///
/// This is THE per-row hot kernel: two linear passes over one or two cache
/// lines of weights, no allocation, exactly one uniform consumed (by the
/// caller). Float round-off that pushes the target past the end resolves
/// to the last usable index.
#[inline]
pub fn sample_row_icdf(weights: &[f32], u: f32) -> Option<usize> {
    let mut total = 0.0f32;
    for &w in weights {
        if w > 0.0 && w.is_finite() {
            total += w;
        }
    }
    if !(total > 0.0) || !total.is_finite() {
        return None;
    }
    let mut target = u * total;
    let mut last = DEGENERATE_TOKEN;
    let mut found = false;
    for (i, &w) in weights.iter().enumerate() {
        if w > 0.0 && w.is_finite() {
            last = i;
            found = true;
            if target < w {
                return Some(i);
            }
            target -= w;
        }
    }
    found.then_some(last)
}

/// Sample every token of a `[B, N, V]` probs tensor into `out` (`[B * N]`),
/// drawing one uniform per row from the shared sequential `rng`.
pub fn categorical_batch(probs: &[f32], vocab: usize, out: &mut [i32], rng: &mut Pcg64) {
    debug_assert_eq!(probs.len(), out.len() * vocab);
    for (row_i, slot) in out.iter_mut().enumerate() {
        let row = &probs[row_i * vocab..(row_i + 1) * vocab];
        *slot = sample_row_icdf(row, rng.uniform_f32()).unwrap_or(DEGENERATE_TOKEN) as i32;
    }
}

/// One row of the substream path: row `row_i` at absolute Euler step
/// `step` draws its uniform from `Pcg64::substream(seed, step, row_i)`.
/// `pub(crate)` so the step-level batch composer
/// ([`crate::coordinator::composer`]) can sample individual rows of a
/// composed batch with exactly the coordinates the unbatched loop uses —
/// that is what makes composed and per-bundle outputs bitwise-identical.
#[inline]
pub(crate) fn sample_row_seeded(row: &[f32], seed: u64, step: u64, row_i: u64) -> i32 {
    let u = Pcg64::substream(seed, step, row_i).uniform_f32();
    sample_row_icdf(row, u).unwrap_or(DEGENERATE_TOKEN) as i32
}

/// Sequential reference for the substream sampling path: row `r` at Euler
/// step `step` draws from `Pcg64::substream(seed, step, r)`. Bitwise-equal
/// to [`categorical_batch_par`] by construction (pinned by tests).
pub fn categorical_batch_seeded(probs: &[f32], vocab: usize, out: &mut [i32], seed: u64, step: u64) {
    debug_assert_eq!(probs.len(), out.len() * vocab);
    for (row_i, slot) in out.iter_mut().enumerate() {
        let row = &probs[row_i * vocab..(row_i + 1) * vocab];
        *slot = sample_row_seeded(row, seed, step, row_i as u64);
    }
}

/// Parallel categorical sampling across rows on a [`WorkerPool`].
///
/// Rows are statically chunked; each row's draw comes from its own
/// `(seed, step, row)` substream, so the result is bitwise-identical to
/// [`categorical_batch_seeded`] for any worker count. Batches smaller than
/// [`PAR_MIN_ROWS`] run inline on the calling thread (no spawn, no
/// allocation) — large `[B, N]` shapes use all cores.
pub fn categorical_batch_par(
    probs: &[f32],
    vocab: usize,
    out: &mut [i32],
    seed: u64,
    step: u64,
    pool: &WorkerPool,
) {
    debug_assert_eq!(probs.len(), out.len() * vocab);
    pool.par_chunks_mut(out, PAR_MIN_ROWS, |offset, chunk| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            let row_i = offset + j;
            let row = &probs[row_i * vocab..(row_i + 1) * vocab];
            *slot = sample_row_seeded(row, seed, step, row_i as u64);
        }
    });
}

/// Argmax over a row (used for greedy final-step decoding variants).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Shannon entropy (nats) of a normalized distribution.
pub fn entropy(p: &[f64]) -> f64 {
    p.iter().filter(|&&x| x > 0.0).map(|&x| -x * x.ln()).sum()
}

/// Shannon entropy in bits.
pub fn entropy_bits(p: &[f64]) -> f64 {
    entropy(p) / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1000.0, -1000.0];
        softmax(&mut xs);
        assert!((xs[0] - 1.0).abs() < 1e-6);
        assert!(xs[1] >= 0.0);
        softmax(&mut []); // no panic
    }

    #[test]
    fn categorical_degenerate() {
        let mut rng = Pcg64::new(0);
        let w = vec![0.0, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(categorical(&w, &mut rng), 2);
        }
    }

    #[test]
    fn degenerate_rows_hit_documented_fallback() {
        let mut rng = Pcg64::new(9);
        // All-zero, all-NaN, and negative rows fall back deterministically.
        assert_eq!(categorical(&[0.0, 0.0, 0.0], &mut rng), DEGENERATE_TOKEN);
        assert_eq!(categorical(&[f32::NAN, f32::NAN], &mut rng), DEGENERATE_TOKEN);
        assert_eq!(categorical(&[-1.0, -2.0], &mut rng), DEGENERATE_TOKEN);
        assert_eq!(sample_row_icdf(&[0.0, 0.0], 0.5), None);
        assert_eq!(sample_row_icdf(&[f32::NAN, f32::NAN], 0.5), None);
        // Non-finite weights are unusable and skipped like NaN: finite
        // mass still samples, an all-infinite row is degenerate.
        assert_eq!(sample_row_icdf(&[f32::INFINITY, 1.0], 0.5), Some(1));
        assert_eq!(sample_row_icdf(&[f32::INFINITY, f32::INFINITY], 0.5), None);
        // NaN alongside usable mass is skipped, never sampled.
        for _ in 0..200 {
            let k = categorical(&[f32::NAN, 1.0, 3.0], &mut rng);
            assert!(k == 1 || k == 2);
        }
        for i in 0..100 {
            let u = i as f32 / 100.0;
            let k = sample_row_icdf(&[f32::NAN, 1.0, 3.0], u).unwrap();
            assert!(k == 1 || k == 2);
        }
        // Batched samplers inherit the fallback.
        let probs = vec![0.0f32; 2 * 3];
        let mut out = vec![7i32; 2];
        categorical_batch(&probs, 3, &mut out, &mut rng);
        assert_eq!(out, vec![DEGENERATE_TOKEN as i32; 2]);
        categorical_batch_seeded(&probs, 3, &mut out, 1, 0);
        assert_eq!(out, vec![DEGENERATE_TOKEN as i32; 2]);
    }

    #[test]
    fn categorical_frequencies_match() {
        let mut rng = Pcg64::new(1);
        let w = vec![0.1f32, 0.2, 0.7];
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[categorical(&w, &mut rng)] += 1;
        }
        for (i, &target) in [0.1, 0.2, 0.7].iter().enumerate() {
            let f = counts[i] as f64 / n as f64;
            assert!((f - target).abs() < 0.01, "idx {i}: {f} vs {target}");
        }
    }

    #[test]
    fn icdf_kernel_frequencies_match() {
        // The batched kernel (one pre-drawn uniform) matches the weights.
        let mut rng = Pcg64::new(4);
        let w = vec![1.0f32, 3.0]; // unnormalized, sums to 4
        let n = 40_000;
        let ones = (0..n)
            .filter(|_| sample_row_icdf(&w, rng.uniform_f32()) == Some(1))
            .count();
        let f = ones as f64 / n as f64;
        assert!((f - 0.75).abs() < 0.01, "{f}");
        // u -> index is monotone and covers the support.
        assert_eq!(sample_row_icdf(&w, 0.0), Some(0));
        assert_eq!(sample_row_icdf(&w, 0.9999), Some(1));
    }

    #[test]
    fn categorical_unnormalized_ok() {
        let mut rng = Pcg64::new(2);
        let w = vec![1.0f32, 3.0]; // sums to 4
        let n = 40_000;
        let ones = (0..n).filter(|_| categorical(&w, &mut rng) == 1).count();
        let f = ones as f64 / n as f64;
        assert!((f - 0.75).abs() < 0.01, "{f}");
    }

    #[test]
    fn categorical_batch_shapes() {
        let mut rng = Pcg64::new(3);
        let vocab = 4;
        let probs = vec![0.25f32; 2 * 3 * vocab];
        let mut out = vec![0i32; 6];
        categorical_batch(&probs, vocab, &mut out, &mut rng);
        assert!(out.iter().all(|&t| (0..4).contains(&t)));
    }

    fn random_probs(rows: usize, vocab: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..rows * vocab).map(|_| rng.uniform_f32() + 0.01).collect()
    }

    #[test]
    fn parallel_path_is_bitwise_equal_to_sequential() {
        // Large enough that the pool actually splits into several chunks.
        let (rows, vocab) = (4096, 32);
        let probs = random_probs(rows, vocab, 11);
        let mut seq = vec![0i32; rows];
        let mut par = vec![0i32; rows];
        for step in [0u64, 1, 17] {
            categorical_batch_seeded(&probs, vocab, &mut seq, 42, step);
            for threads in [1, 2, 3, 8] {
                let pool = WorkerPool::new(threads);
                categorical_batch_par(&probs, vocab, &mut par, 42, step, &pool);
                assert_eq!(seq, par, "threads={threads} step={step}");
            }
        }
    }

    #[test]
    fn seeded_rows_are_order_independent_and_reproducible() {
        let (rows, vocab) = (64, 8);
        let probs = random_probs(rows, vocab, 5);
        let mut a = vec![0i32; rows];
        let mut b = vec![0i32; rows];
        categorical_batch_seeded(&probs, vocab, &mut a, 7, 3);
        categorical_batch_seeded(&probs, vocab, &mut b, 7, 3);
        assert_eq!(a, b);
        categorical_batch_seeded(&probs, vocab, &mut b, 8, 3);
        assert_ne!(a, b, "different run seed must change samples");
        // Different steps decorrelate too.
        categorical_batch_seeded(&probs, vocab, &mut b, 7, 4);
        assert_ne!(a, b);
    }

    #[test]
    fn seeded_batch_frequencies_match() {
        // Distributional sanity for the substream path: over many steps,
        // every row tracks the row's distribution.
        let vocab = 3;
        let w = [0.6f32, 0.3, 0.1];
        let rows = 32;
        let probs: Vec<f32> = (0..rows).flat_map(|_| w).collect();
        let mut out = vec![0i32; rows];
        let mut counts = [0usize; 3];
        let steps = 2000;
        for step in 0..steps {
            categorical_batch_seeded(&probs, vocab, &mut out, 123, step);
            for &t in &out {
                counts[t as usize] += 1;
            }
        }
        let n = (rows * steps as usize) as f64;
        for (i, &target) in w.iter().enumerate() {
            let f = counts[i] as f64 / n;
            assert!((f - target as f64).abs() < 0.01, "idx {i}: {f} vs {target}");
        }
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn entropy_known_values() {
        assert!((entropy_bits(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!(entropy(&[1.0, 0.0]).abs() < 1e-12);
        let u = vec![0.25; 4];
        assert!((entropy_bits(&u) - 2.0).abs() < 1e-12);
    }
}
