//! Numeric substrates: RNG, tensors, probability ops, time schedules.

pub mod prob;
pub mod rng;
pub mod schedule;
pub mod tensor;
