//! Numeric substrates: RNG, tensors, probability ops, time schedules, and
//! the scoped-thread worker pool behind the parallel sampling path.

pub mod prob;
pub mod rng;
pub mod schedule;
pub mod tensor;
pub mod workers;
