//! In-tree scoped-thread worker pool (DESIGN.md §2 — rayon is not an
//! allowed dependency).
//!
//! [`WorkerPool`] fans data-parallel row work out over `std::thread::scope`
//! threads. Scoped spawning keeps the implementation 100% safe (borrowed
//! slices cross into workers without `'static` gymnastics) at the cost of a
//! few tens of microseconds of spawn overhead per invocation — negligible
//! next to the row work it parallelizes and the engine step it hides behind
//! (quantified in `benches/hotpath.rs`). Small inputs run inline on the
//! calling thread, so the sampling hot path stays allocation- and
//! spawn-free for the batch shapes unit tests use.
//!
//! Work partitioning is static (contiguous chunks), so any row-indexed
//! computation whose per-row result depends only on the row index — like
//! the per-row RNG substreams of [`crate::core::prob`] — is deterministic
//! regardless of worker count.

use std::sync::OnceLock;

/// A fixed-width pool of scoped workers.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    /// A pool sized to the machine: `WSFM_WORKERS` if set, otherwise
    /// `available_parallelism`.
    pub fn with_default_parallelism() -> WorkerPool {
        let threads = std::env::var("WSFM_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        WorkerPool::new(threads)
    }

    /// The process-wide shared pool (sized once, on first use).
    pub fn shared() -> &'static WorkerPool {
        static SHARED: OnceLock<WorkerPool> = OnceLock::new();
        SHARED.get_or_init(WorkerPool::with_default_parallelism)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `data` into at most `threads` contiguous chunks of at least
    /// `min_chunk` items and run `f(offset, chunk)` on each, in parallel.
    ///
    /// `offset` is the chunk's start index within `data`, so `f` can
    /// recover absolute item indices. When one chunk suffices (small input
    /// or a 1-thread pool) `f` runs inline on the calling thread — no
    /// spawn, no allocation.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        // Floor division: splitting must never produce chunks below the
        // min_chunk floor (that is exactly the regime where spawn overhead
        // beats the work) — inputs under 2*min_chunk run inline.
        let max_chunks = self.threads.min(n / min_chunk).max(1);
        if max_chunks == 1 {
            f(0, data);
            return;
        }
        let chunk = (n + max_chunks - 1) / max_chunks;
        std::thread::scope(|scope| {
            let f = &f;
            for (i, part) in data.chunks_mut(chunk).enumerate() {
                let offset = i * chunk;
                scope.spawn(move || f(offset, part));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_item_exactly_once() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u32; 10_000];
        pool.par_chunks_mut(&mut data, 16, |offset, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x += (offset + j) as u32 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32 + 1, "item {i} visited wrong number of times");
        }
    }

    #[test]
    fn small_input_runs_inline_in_one_chunk() {
        let pool = WorkerPool::new(8);
        let calls = AtomicUsize::new(0);
        let caller = std::thread::current().id();
        let mut data = vec![0u8; 100];
        pool.par_chunks_mut(&mut data, 512, |offset, chunk| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!(offset, 0);
            assert_eq!(chunk.len(), 100);
            assert_eq!(std::thread::current().id(), caller);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn chunk_count_bounded_by_threads_and_min_chunk() {
        let pool = WorkerPool::new(3);
        let calls = AtomicUsize::new(0);
        let mut data = vec![0u8; 1000];
        pool.par_chunks_mut(&mut data, 10, |_, _| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        let c = calls.load(Ordering::SeqCst);
        assert!(c >= 2 && c <= 3, "chunks = {c}");

        // min_chunk dominates when items are scarce.
        let calls2 = AtomicUsize::new(0);
        let mut data2 = vec![0u8; 25];
        pool.par_chunks_mut(&mut data2, 10, |_, _| {
            calls2.fetch_add(1, Ordering::SeqCst);
        });
        assert!(calls2.load(Ordering::SeqCst) <= 3);
    }

    #[test]
    fn empty_and_single_thread_pools() {
        let pool = WorkerPool::new(0); // clamps to 1
        assert_eq!(pool.threads(), 1);
        let mut nothing: Vec<u8> = vec![];
        pool.par_chunks_mut(&mut nothing, 1, |_, _| panic!("no work expected"));
        assert!(WorkerPool::shared().threads() >= 1);
    }
}
