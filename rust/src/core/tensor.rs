//! Small dense tensors for the request path.
//!
//! Only what the coordinator needs: contiguous row-major storage for f32 /
//! i32 with shape tracking, views by leading index, and cheap reuse
//! (`TokenBatch` is the per-request generation state buffer).

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        TensorF32 { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, numel(shape), data.len());
        }
        Ok(TensorF32 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row `i` of a rank-2+ tensor (all trailing dims flattened).
    pub fn row(&self, i: usize) -> &[f32] {
        let stride = numel(&self.shape[1..]);
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let stride = numel(&self.shape[1..]);
        &mut self.data[i * stride..(i + 1) * stride]
    }
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        TensorI32 { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        if numel(shape) != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, numel(shape), data.len());
        }
        Ok(TensorI32 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn row(&self, i: usize) -> &[i32] {
        let stride = numel(&self.shape[1..]);
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        let stride = numel(&self.shape[1..]);
        &mut self.data[i * stride..(i + 1) * stride]
    }
}

/// A batch of token sequences `[B, N]` — the sampler's mutable state.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
}

impl TokenBatch {
    pub fn zeros(batch: usize, seq_len: usize) -> Self {
        TokenBatch { batch, seq_len, tokens: vec![0; batch * seq_len] }
    }

    pub fn from_rows(rows: &[Vec<i32>]) -> Result<Self> {
        if rows.is_empty() {
            bail!("empty token batch");
        }
        let n = rows[0].len();
        if rows.iter().any(|r| r.len() != n) {
            bail!("ragged rows in token batch");
        }
        let mut tokens = Vec::with_capacity(rows.len() * n);
        for r in rows {
            tokens.extend_from_slice(r);
        }
        Ok(TokenBatch { batch: rows.len(), seq_len: n, tokens })
    }

    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        &mut self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// Pad to `target_batch` rows by repeating the last row (batcher use:
    /// compiled executables have fixed B; padding rows are discarded on the
    /// way out and never leak into responses — property-tested).
    pub fn pad_to(&self, target_batch: usize) -> Result<TokenBatch> {
        if target_batch < self.batch {
            bail!("pad_to({target_batch}) smaller than batch {}", self.batch);
        }
        let mut tokens = self.tokens.clone();
        let last = self.row(self.batch - 1).to_vec();
        for _ in self.batch..target_batch {
            tokens.extend_from_slice(&last);
        }
        Ok(TokenBatch { batch: target_batch, seq_len: self.seq_len, tokens })
    }

    /// Keep only the first `n` rows (drop batch padding).
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.batch);
        self.tokens.truncate(n * self.seq_len);
        self.batch = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_shape_checks() {
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        let t = TensorF32::zeros(&[4, 2, 5]);
        assert_eq!(t.numel(), 40);
        assert_eq!(t.row(1).len(), 10);
    }

    #[test]
    fn i32_rows() {
        let t = TensorI32::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(t.row(0), &[1, 2, 3]);
        assert_eq!(t.row(1), &[4, 5, 6]);
    }

    #[test]
    fn token_batch_from_rows_and_pad() {
        let tb = TokenBatch::from_rows(&[vec![1, 2], vec![3, 4], vec![5, 6]]).unwrap();
        assert_eq!((tb.batch, tb.seq_len), (3, 2));
        let padded = tb.pad_to(5).unwrap();
        assert_eq!(padded.batch, 5);
        assert_eq!(padded.row(3), &[5, 6]);
        assert_eq!(padded.row(4), &[5, 6]);
        let mut back = padded.clone();
        back.truncate(3);
        assert_eq!(back, tb);
    }

    #[test]
    fn token_batch_ragged_rejected() {
        assert!(TokenBatch::from_rows(&[vec![1], vec![2, 3]]).is_err());
        assert!(TokenBatch::from_rows(&[]).is_err());
    }

    #[test]
    fn pad_smaller_rejected() {
        let tb = TokenBatch::zeros(4, 2);
        assert!(tb.pad_to(2).is_err());
    }
}
