//! Deterministic PRNG (PCG64) + distribution sampling.
//!
//! The coordinator owns all request-path randomness (the HLO artifacts are
//! pure functions; Gumbel/Gaussian noise is passed *into* them), so the RNG
//! must be fast, seedable, and splittable per request for reproducibility.

/// PCG-XSH-RR 64/32 with 64-bit output composed from two draws.
///
/// Small, fast, statistically solid for simulation workloads.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream selection — used to split per-request RNGs.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    /// Derive an independent generator (e.g. one per request id).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ splitmix64(tag);
        Pcg64::with_stream(seed, splitmix64(tag ^ 0x9e37_79b9_7f4a_7c15))
    }

    /// Stateless per-`(run, step, row)` substream — the determinism
    /// contract of the parallel sampling path (EXPERIMENTS.md §Perf).
    ///
    /// Every token row of every Euler step draws from its own generator,
    /// derived purely from the run seed and its coordinates. Results are
    /// therefore bitwise-identical regardless of worker count or whether
    /// rows are sampled sequentially or in parallel. Construction is a
    /// handful of integer multiplies — cheap enough to do per row.
    #[inline]
    pub fn substream(seed: u64, step: u64, row: u64) -> Pcg64 {
        let tag = splitmix64(splitmix64(step).wrapping_add(row));
        Pcg64::with_stream(seed ^ tag, tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1) (hot path: one 32-bit draw).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection, unbiased).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller (pairs cached not needed at our rates).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gumbel(0, 1) — used for the draft-model Gumbel-max sampling inputs.
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        let u = self.uniform().max(1e-300);
        -(-u.ln()).ln()
    }

    /// Fill a f32 buffer with Gumbel noise.
    pub fn fill_gumbel_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.gumbel() as f32;
        }
    }

    /// Fill a f32 buffer with standard normal noise.
    pub fn fill_normal_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Random permutation index (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — seed scrambler.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// FNV-1a offset basis (64-bit).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Chained FNV-1a over a byte slice. Unlike `std`'s `Hash`/`RandomState`
/// this is **stable across processes and runs** — it seeds the stateless
/// per-bundle RNG substreams of the pipelined coordinator, where the same
/// bundle must hash identically no matter which worker thread (or which
/// process restart) computes it. Start from [`FNV_OFFSET`] and chain
/// calls to fold multiple fields.
#[inline]
pub fn fnv1a64(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3); // FNV-1a 64-bit prime
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(Pcg64::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Pcg64::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut rng = Pcg64::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Pcg64::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let overlaps = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(overlaps, 0);
    }

    #[test]
    fn substreams_are_deterministic_and_distinct() {
        // Same coordinates -> same stream.
        let mut a = Pcg64::substream(7, 3, 11);
        let mut b = Pcg64::substream(7, 3, 11);
        for _ in 0..20 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Neighbouring coordinates -> decorrelated streams.
        for (s2, st2, r2) in [(8u64, 3u64, 11u64), (7, 4, 11), (7, 3, 12)] {
            let mut c = Pcg64::substream(7, 3, 11);
            let mut d = Pcg64::substream(s2, st2, r2);
            let overlaps = (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
            assert_eq!(overlaps, 0, "({s2},{st2},{r2})");
        }
        // (step, row) mixing is not additive: (s, r+1) != (s+1, r) streams.
        assert_ne!(
            Pcg64::substream(1, 2, 4).next_u64(),
            Pcg64::substream(1, 3, 3).next_u64()
        );
    }

    #[test]
    fn fnv1a64_is_stable_and_field_sensitive() {
        // Known-stable: hashing must never depend on process state.
        let h = fnv1a64(FNV_OFFSET, b"wsfm");
        assert_eq!(h, fnv1a64(FNV_OFFSET, b"wsfm"));
        assert_ne!(h, fnv1a64(FNV_OFFSET, b"wsfM"));
        // Chaining distinguishes field boundaries when a separator is fed.
        let ab_c = fnv1a64(fnv1a64(FNV_OFFSET, b"ab\0"), b"c\0");
        let a_bc = fnv1a64(fnv1a64(FNV_OFFSET, b"a\0"), b"bc\0");
        assert_ne!(ab_c, a_bc);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
