//! Time discretization and the paper's guaranteed-NFE arithmetic.
//!
//! The headline claim of WS-FM: starting at `t0` instead of 0 with the same
//! step size `h = 1/steps_cold` takes exactly `ceil(steps_cold * (1 - t0))`
//! denoiser evaluations — a guaranteed `1/(1-t0)` speed-up. This module is
//! the single source of truth for that arithmetic on the Rust side
//! (mirrors `python/compile/paths.py::nfe`; both pinned by tests).

use anyhow::{bail, Result};

/// Update-rule variant (DESIGN.md §1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarpMode {
    /// The paper's literal Fig. 3 rule: velocity scaled by `(1 - t0)`.
    Literal,
    /// The exact normalized-path rule (same as cold DFM's update).
    Exact,
}

impl WarpMode {
    pub fn warp_factor(self, t0: f64) -> f64 {
        match self {
            WarpMode::Literal => 1.0 - t0,
            WarpMode::Exact => 1.0,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "literal" => Ok(WarpMode::Literal),
            "exact" => Ok(WarpMode::Exact),
            _ => bail!("unknown warp mode {s:?} (literal|exact)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WarpMode::Literal => "literal",
            WarpMode::Exact => "exact",
        }
    }
}

/// An Euler integration schedule over `[t0, 1]` — or, for a cascade
/// segment ([`Schedule::segment`]), over a contiguous sub-window
/// `[t_start, t_end)` of that run's step grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub t0: f64,
    /// Step size, fixed to the cold run's `1/steps_cold` so warm runs use
    /// the *same* grid (that is what makes the NFE claim comparable).
    pub h: f64,
    /// The time points at which the denoiser is evaluated.
    pub times: Vec<f64>,
    /// Index of `times[0]` in the unsplit run's schedule — the absolute
    /// step coordinate the per-`(step, row)` RNG substreams key on, so a
    /// run split into segments samples exactly like the unsplit run.
    /// Always 0 for [`Schedule::new`].
    pub step_offset: usize,
    /// Whether this schedule's final step lands on `t = 1` (and is
    /// therefore clipped to `1 - t_last`). Interior cascade segments end
    /// on the grid instead, so every one of their steps is a full `h`.
    pub reaches_one: bool,
}

impl Schedule {
    /// Build the schedule for a run starting at `t0` with a cold-run
    /// resolution of `steps_cold`.
    pub fn new(steps_cold: usize, t0: f64) -> Result<Schedule> {
        if steps_cold == 0 {
            bail!("steps_cold must be positive");
        }
        if !(0.0..1.0).contains(&t0) {
            bail!("t0 must be in [0, 1), got {t0}");
        }
        let h = 1.0 / steps_cold as f64;
        let n = guaranteed_nfe(steps_cold, t0);
        // Evaluation times t0, t0+h, ... ; the final step uses a shortened
        // h' = 1 - t_last so the trajectory lands exactly on t = 1.
        let mut times: Vec<f64> = (0..n).map(|i| t0 + i as f64 * h).collect();
        // Float drift in `t0 + i·h` can push the final evaluation time to
        // within (or past) rounding distance of 1.0, which would make the
        // clipped final step ~0 or negative. Snap such an entry one grid
        // step back so `step_size` stays strictly positive. (With the
        // epsilon-robust `guaranteed_nfe` this is unreachable for t0 on or
        // near the cold grid; it guards adversarial off-grid values.)
        if let Some(last) = times.last_mut() {
            if *last > 1.0 - 1e-12 && *last > t0 {
                *last = (1.0 - h).max(t0);
            }
        }
        Ok(Schedule { t0, h, times, step_offset: 0, reaches_one: true })
    }

    /// The sub-schedule of `Schedule::new(steps_cold, run_t0)` covering
    /// the window `[t_start, t_end)` — the cascade-segment constructor.
    ///
    /// The segment executes exactly the unsplit run's evaluation times
    /// that fall inside the window ([`grid_index`] snaps both boundaries
    /// to the run grid, epsilon-robustly), with `step_offset` recording
    /// where they sit in the unsplit run. Consequently **any** partition
    /// of `[run_t0, 1]` into consecutive windows reproduces the unsplit
    /// schedule's times, step sizes, and total NFE exactly (pinned by the
    /// partition property test). `t_end >= 1` selects everything to the
    /// end of the run; a window containing no grid step yields an empty
    /// (0-NFE) schedule.
    pub fn segment(steps_cold: usize, run_t0: f64, t_start: f64, t_end: f64) -> Result<Schedule> {
        if !t_start.is_finite() || !t_end.is_finite() {
            bail!("segment window [{t_start}, {t_end}] must be finite");
        }
        let full = Schedule::new(steps_cold, run_t0)?;
        let a = grid_index(steps_cold, run_t0, t_start);
        let b = grid_index(steps_cold, run_t0, t_end).max(a);
        let n = full.nfe();
        let times = full.times[a..b].to_vec();
        let t0 = times.first().copied().unwrap_or_else(|| t_start.max(run_t0));
        Ok(Schedule { t0, h: full.h, times, step_offset: a, reaches_one: b == n })
    }

    /// Number of function evaluations (== `times.len()`).
    pub fn nfe(&self) -> usize {
        self.times.len()
    }

    /// The step size to use at step `i`. The final step of a run that
    /// reaches `t = 1` is clipped to land exactly on 1.0; every step of
    /// an interior segment is a full grid step.
    pub fn step_size(&self, i: usize) -> f64 {
        let t = self.times[i];
        if self.reaches_one && i + 1 == self.times.len() {
            1.0 - t
        } else {
            self.h
        }
    }
}

/// Snapping tolerance for `steps_cold * (1 - t0)` against the integer
/// grid. `1 - t0` carries one f64 rounding (~1e-16 relative), so the
/// product's absolute error grows with `steps_cold`: a fixed 1e-9 epsilon
/// stops absorbing it for fine grids, while a purely relative one
/// vanishes for coarse ones — so use both. Must stay identical to the
/// epsilon in `python/compile/paths.py::nfe` (pinned by the boundary
/// cases in `rust/tests/cross_lang.rs`).
fn nfe_eps(steps_cold: usize) -> f64 {
    1e-9 + steps_cold as f64 * 1e-12
}

/// Map a time boundary onto the unsplit run's evaluation-step grid: the
/// number of evaluation times of `Schedule::new(steps_cold, t0)` lying
/// strictly below `t`, clamped to `[0, nfe]`.
///
/// Epsilon-robust at grid points (same tolerance as [`guaranteed_nfe`]):
/// a boundary computed as `t0 + k·h` in f64 maps to exactly `k`, so
/// cascade-ladder boundaries snap deterministically and consecutive
/// segments tile the run without gaps or overlaps. `t >= 1` always maps
/// to the full NFE (the end of the run), even for `t0` hard against 1
/// where the product underflows the epsilon.
pub fn grid_index(steps_cold: usize, t0: f64, t: f64) -> usize {
    let n = guaranteed_nfe(steps_cold, t0);
    if t >= 1.0 {
        return n;
    }
    let x = (t - t0) * steps_cold as f64;
    let i = (x - nfe_eps(steps_cold)).ceil().max(0.0) as usize;
    i.min(n)
}

/// `ceil(steps_cold * (1 - t0))` — the paper's guaranteed NFE.
///
/// Epsilon-robust: for `t0` within float-rounding distance of the cold
/// grid (e.g. `t0 = 1 - k/steps_cold` computed in f64), the product is
/// snapped to the integer the exact arithmetic would give, so this agrees
/// bit-for-bit with the integer result of `python/compile/paths.py::nfe`
/// at every grid boundary. Clamped to `[1, steps_cold]`: warm never pays
/// more than cold, and every schedule performs at least one evaluation.
pub fn guaranteed_nfe(steps_cold: usize, t0: f64) -> usize {
    let x = steps_cold as f64 * (1.0 - t0);
    let n = (x - nfe_eps(steps_cold)).ceil().max(1.0) as usize;
    n.min(steps_cold.max(1))
}

/// The paper's guaranteed speed-up factor `1/(1-t0)`.
pub fn speedup_factor(t0: f64) -> f64 {
    1.0 / (1.0 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_schedule_has_full_steps() {
        let s = Schedule::new(20, 0.0).unwrap();
        assert_eq!(s.nfe(), 20);
        assert!((s.h - 0.05).abs() < 1e-12);
        assert!((s.times[0]).abs() < 1e-12);
        assert!((s.times[19] - 0.95).abs() < 1e-9);
    }

    #[test]
    fn paper_table1_nfe_values() {
        // Table 1: cold 20 steps; t0 = 0.95 -> 1, 0.9 -> 2, 0.8 -> 4,
        // 0.5 -> 10, 0.35 -> 13.
        assert_eq!(guaranteed_nfe(20, 0.95), 1);
        assert_eq!(guaranteed_nfe(20, 0.9), 2);
        assert_eq!(guaranteed_nfe(20, 0.8), 4);
        assert_eq!(guaranteed_nfe(20, 0.5), 10);
        assert_eq!(guaranteed_nfe(20, 0.35), 13);
    }

    #[test]
    fn paper_table2_nfe_values() {
        // Table 2: cold 1024 steps; t0 = 0.5 -> 512, t0 = 0.8 -> 205.
        assert_eq!(guaranteed_nfe(1024, 0.5), 512);
        assert_eq!(guaranteed_nfe(1024, 0.8), 205);
    }

    #[test]
    fn schedule_lands_on_one() {
        for (steps, t0) in [(20, 0.8), (1024, 0.8), (7, 0.33), (1, 0.0), (13, 0.95)] {
            let s = Schedule::new(steps, t0).unwrap();
            let mut t = s.times[0];
            for i in 0..s.nfe() {
                assert!((t - s.times[i]).abs() < 1e-9);
                t += s.step_size(i);
            }
            assert!((t - 1.0).abs() < 1e-9, "steps={steps} t0={t0} ended at {t}");
        }
    }

    #[test]
    fn warm_nfe_never_exceeds_cold() {
        for steps in [1usize, 5, 20, 100, 1024] {
            for &t0 in &[0.0, 0.1, 0.35, 0.5, 0.65, 0.8, 0.9, 0.95, 0.99] {
                let warm = guaranteed_nfe(steps, t0);
                assert!(warm <= steps);
                assert!(warm >= 1);
            }
        }
    }

    #[test]
    fn boundary_t0_matches_integer_arithmetic() {
        // The float-boundary regression (ISSUE 3): for t0 = 1 - k/steps
        // computed in f64, `steps * (1 - t0)` drifts a few ulps off the
        // integer k, and a naive ceil comes out one high or low. The
        // epsilon-robust formulation must recover exactly k — the value
        // python/compile/paths.py::nfe computes — at every boundary.
        for steps in [1usize, 2, 3, 5, 7, 13, 20, 49, 128, 1024, 65536] {
            let h = 1.0 / steps as f64;
            let mut cases = vec![(0.0, steps), (1.0 - h, 1)];
            if steps >= 2 {
                cases.push((h, steps - 1));
            }
            for &(t0, want) in &cases {
                assert_eq!(guaranteed_nfe(steps, t0), want, "steps={steps} t0={t0}");
                let s = Schedule::new(steps, t0).unwrap();
                assert_eq!(s.nfe(), want, "steps={steps} t0={t0}");
                // Every step size strictly positive; trajectory lands on 1.
                let mut t = s.times[0];
                for i in 0..s.nfe() {
                    assert!(s.step_size(i) > 0.0, "steps={steps} t0={t0} i={i}");
                    t += s.step_size(i);
                }
                assert!((t - 1.0).abs() < 1e-9, "steps={steps} t0={t0} ended at {t}");
            }
        }
    }

    #[test]
    fn t0_hard_against_one_is_single_positive_step() {
        for steps in [1usize, 20, 1024] {
            let t0 = 1.0 - 1e-9;
            assert_eq!(guaranteed_nfe(steps, t0), 1);
            let s = Schedule::new(steps, t0).unwrap();
            assert_eq!(s.nfe(), 1);
            assert!(s.step_size(0) > 0.0);
            assert!(s.times[0] < 1.0);
        }
    }

    #[test]
    fn off_grid_t0_never_produces_degenerate_final_step() {
        // Sweep off-grid t0 values (incl. milli-quantized ones, the
        // BundleKey round-trip) and require a strictly positive final
        // step everywhere.
        for steps in [7usize, 20, 100, 1024] {
            for milli in (0..1000).step_by(7) {
                let t0 = milli as f64 / 1000.0;
                let s = Schedule::new(steps, t0).unwrap();
                let last = s.nfe() - 1;
                assert!(
                    s.step_size(last) > 0.0,
                    "steps={steps} t0={t0} final step {}",
                    s.step_size(last)
                );
                assert!(s.nfe() <= steps);
            }
        }
    }

    #[test]
    fn warp_factors() {
        assert!((WarpMode::Literal.warp_factor(0.8) - 0.2).abs() < 1e-12);
        assert!((WarpMode::Exact.warp_factor(0.8) - 1.0).abs() < 1e-12);
        assert!(WarpMode::parse("literal").is_ok());
        assert!(WarpMode::parse("exact").is_ok());
        assert!(WarpMode::parse("bogus").is_err());
    }

    #[test]
    fn speedup_matches_paper() {
        assert!((speedup_factor(0.8) - 5.0).abs() < 1e-9);
        assert!((speedup_factor(0.5) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(Schedule::new(0, 0.0).is_err());
        assert!(Schedule::new(10, 1.0).is_err());
        assert!(Schedule::new(10, -0.1).is_err());
        assert!(Schedule::segment(10, 0.5, f64::NAN, 1.0).is_err());
        assert!(Schedule::segment(10, 0.5, 0.5, f64::INFINITY).is_err());
        assert!(Schedule::segment(0, 0.0, 0.0, 1.0).is_err());
    }

    #[test]
    fn grid_index_snaps_grid_points() {
        for steps in [1usize, 7, 20, 1024] {
            let h = 1.0 / steps as f64;
            for t0 in [0.0, h, 0.5, 1.0 - h] {
                if !(0.0..1.0).contains(&t0) {
                    continue;
                }
                let n = guaranteed_nfe(steps, t0);
                assert_eq!(grid_index(steps, t0, t0), 0, "steps={steps} t0={t0}");
                assert_eq!(grid_index(steps, t0, 1.0), n, "steps={steps} t0={t0}");
                for k in 0..=n {
                    // A boundary computed in f64 as the k-th grid time maps
                    // to exactly k (epsilon-robust).
                    let b = t0 + k as f64 * h;
                    let want = k.min(n);
                    assert_eq!(grid_index(steps, t0, b), want, "steps={steps} t0={t0} k={k}");
                }
                // Off-grid boundaries round up to the next step count.
                if n >= 2 {
                    assert_eq!(grid_index(steps, t0, t0 + 1.5 * h), 2);
                }
            }
        }
        // t0 hard against 1: the product underflows the epsilon, but t=1
        // still maps to the full (clamped-to-1) NFE.
        assert_eq!(grid_index(20, 1.0 - 1e-12, 1.0), guaranteed_nfe(20, 1.0 - 1e-12));
    }

    #[test]
    fn full_window_segment_equals_new() {
        for (steps, t0) in [(20usize, 0.0), (20, 0.8), (7, 0.33), (1024, 0.5), (1, 0.0)] {
            let full = Schedule::new(steps, t0).unwrap();
            let seg = Schedule::segment(steps, t0, t0, 1.0).unwrap();
            assert_eq!(seg, full, "steps={steps} t0={t0}");
            assert_eq!(seg.step_offset, 0);
            assert!(seg.reaches_one);
        }
    }

    #[test]
    fn interior_segments_keep_full_steps_and_offsets() {
        // [0.5, 1] over 10 cold steps = 5 evaluations; cut at 0.8 → the
        // first segment runs steps {0,1,2} with full-h steps (it ends on
        // the grid), the second runs {3,4} and clips its final step.
        let a = Schedule::segment(10, 0.5, 0.5, 0.8).unwrap();
        let b = Schedule::segment(10, 0.5, 0.8, 1.0).unwrap();
        assert_eq!(a.nfe(), 3);
        assert_eq!(a.step_offset, 0);
        assert!(!a.reaches_one);
        for i in 0..a.nfe() {
            assert!((a.step_size(i) - 0.1).abs() < 1e-12, "interior steps are full h");
        }
        assert_eq!(b.nfe(), 2);
        assert_eq!(b.step_offset, 3);
        assert!(b.reaches_one);
        // The second segment resumes exactly where the first ended.
        let end_a = a.times.last().unwrap() + a.step_size(a.nfe() - 1);
        assert!((end_a - b.times[0]).abs() < 1e-9);
        // Empty windows yield empty (0-NFE) schedules, not errors.
        assert_eq!(Schedule::segment(10, 0.5, 0.8, 0.8).unwrap().nfe(), 0);
        assert_eq!(Schedule::segment(10, 0.5, 0.9, 0.6).unwrap().nfe(), 0);
    }

    /// Partition a run at `cuts` (clamped into `[t0, 1]`, sorted) and
    /// require the concatenated segments to reproduce the unsplit
    /// schedule exactly: same times, same per-step sizes, same total NFE,
    /// offsets tiling `[0, nfe)`.
    fn check_partition(steps: usize, t0: f64, cuts: &[f64]) -> Result<(), String> {
        let full = Schedule::new(steps, t0).map_err(|e| e.to_string())?;
        let mut bounds: Vec<f64> = cuts.to_vec();
        bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        bounds.push(1.0);
        let mut prev = t0;
        let mut times: Vec<f64> = Vec::new();
        for &b in &bounds {
            let seg = Schedule::segment(steps, t0, prev, b).map_err(|e| e.to_string())?;
            if seg.nfe() > 0 && seg.step_offset != times.len() {
                return Err(format!(
                    "offset {} != concat position {} (steps={steps} t0={t0} b={b})",
                    seg.step_offset,
                    times.len()
                ));
            }
            for i in 0..seg.nfe() {
                let j = seg.step_offset + i;
                if (seg.step_size(i) - full.step_size(j)).abs() > 1e-12 {
                    return Err(format!("step size diverged at absolute step {j}"));
                }
            }
            times.extend_from_slice(&seg.times);
            prev = b;
        }
        if times != full.times {
            return Err(format!("times diverged: {} vs {} entries", times.len(), full.nfe()));
        }
        Ok(())
    }

    #[test]
    fn segment_partition_property() {
        use crate::util::prop::{check, F64Range, Pair, UsizeRange, VecOf};
        // Random (steps, t0, up-to-5 arbitrary cut points): any partition
        // of [t0, 1] tiles the unsplit schedule exactly.
        let strat =
            Pair(Pair(UsizeRange(1, 300), F64Range(0.0, 0.999)), VecOf(F64Range(0.0, 1.0), 5));
        check("segment partition == unsplit schedule", strat, |((steps, t0), cuts)| {
            check_partition(*steps, *t0, cuts)
        });
    }

    #[test]
    fn segment_partition_epsilon_boundaries() {
        // The PR 3 epsilon boundary cases, now partitioned at every grid
        // point: t0 ∈ {0, h, 1-h, 1-1e-9} with boundaries computed as
        // t0 + k·h in f64 (the exact values a cascade ladder produces).
        for steps in [1usize, 2, 3, 5, 7, 13, 20, 49, 128, 1024] {
            let h = 1.0 / steps as f64;
            for t0 in [0.0, h, 1.0 - h, 1.0 - 1e-9] {
                if !(0.0..1.0).contains(&t0) {
                    continue;
                }
                let n = guaranteed_nfe(steps, t0);
                let cuts: Vec<f64> = (1..n).map(|k| t0 + k as f64 * h).collect();
                check_partition(steps, t0, &cuts).unwrap();
                // And a coarse 2-segment split through the middle.
                check_partition(steps, t0, &[t0 + (1.0 - t0) / 2.0]).unwrap();
            }
        }
    }
}
