//! API-compatible stand-in for the `xla` crate (enabled when the `pjrt`
//! cargo feature is off).
//!
//! The real backend needs the xla_extension native library at build time,
//! which not every environment has. This stub mirrors exactly the slice of
//! the `xla` API the engine uses so the whole crate — engine thread,
//! coordinator, server, benches — compiles and unit-tests without it:
//!
//! * client construction succeeds (the engine thread spawns normally),
//! * host-side [`Literal`] staging is fully functional (and unit-tested),
//! * anything requiring the native runtime (`HloModuleProto::from_text_file`,
//!   `PjRtClient::compile`, `PjRtLoadedExecutable::execute`) returns a
//!   descriptive error, which surfaces as the usual "artifacts not built"
//!   skip path in tests and harnesses.
//!
//! Build with `--features pjrt` to link the real crate instead; the alias
//! in [`crate::runtime::engine`] switches over and this module is unused.

use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `{e:?}` rendering.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: wsfm was built without the `pjrt` feature \
         (xla_extension not linked); rebuild with `--features pjrt`"
    ))
}

/// Host-side literal payload. Only the dtypes the engine stages (s32 tokens
/// in, f32 probs/noise out) are represented.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait Element: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            LiteralData::I32(_) => None,
        }
    }
}

impl Element for i32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            LiteralData::F32(_) => None,
        }
    }
}

/// Host literal: data + dims. Functional (staging works without PJRT).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    fn numel(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { data: T::wrap(data.to_vec()), dims }
    }

    pub fn scalar<T: Element>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: vec![] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.numel() {
            return Err(Error(format!(
                "reshape to {:?} ({} elems) from {} elems",
                dims,
                want,
                self.numel()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        // The real API unpacks a 1-tuple; the stub never produces tuples,
        // and nothing reaches here without a successful execute().
        Ok(self)
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".into()))
    }
}

/// Parsed HLO module handle (parsing requires the native runtime).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("parsing HLO text {:?}", path.as_ref())))
    }
}

/// Computation handle built from a parsed module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("buffer readback"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execution"))
    }
}

/// Client handle. Construction succeeds so the engine thread can spawn and
/// serve manifest/metadata requests; compilation is what errors.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_staging_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[4, 4]).is_err());
        assert!(r.to_vec::<f32>().is_err()); // dtype mismatch
        let s = Literal::scalar(0.5f32);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn runtime_paths_error_descriptively() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation::from_proto(&HloModuleProto)).unwrap_err();
        assert!(format!("{err:?}").contains("pjrt"));
        assert!(HloModuleProto::from_text_file("/tmp/none.hlo.txt").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute(&[]).is_err());
    }
}
