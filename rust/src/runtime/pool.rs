//! Batch-shape planning over the compiled executable set.
//!
//! Each (domain, tag) was AOT-compiled at a fixed set of batch sizes (e.g.
//! `[1, 8, 32]`). The batcher must map a dynamic group of `n` pending
//! samples onto those shapes: pick the smallest compiled size that fits,
//! or split into several chunks, minimizing padded rows (every padded row
//! costs real denoiser FLOPs).

use anyhow::{bail, Result};

/// Pick the smallest compiled batch >= n, else the largest available.
pub fn best_fit(n: usize, compiled: &[usize]) -> Result<usize> {
    if compiled.is_empty() {
        bail!("no compiled batch sizes");
    }
    let mut sizes = compiled.to_vec();
    sizes.sort_unstable();
    for &s in &sizes {
        if s >= n {
            return Ok(s);
        }
    }
    Ok(*sizes.last().unwrap())
}

/// Split `n` samples into chunks, each assigned a compiled batch size.
///
/// Greedy: emit the largest compiled size while it fits fully, then one
/// best-fit chunk for the remainder. Returns `(chunk_len, compiled_size)`
/// pairs; `chunk_len <= compiled_size` and `sum(chunk_len) == n`.
pub fn plan_chunks(n: usize, compiled: &[usize]) -> Result<Vec<(usize, usize)>> {
    if n == 0 {
        return Ok(vec![]);
    }
    let mut sizes = compiled.to_vec();
    sizes.sort_unstable();
    if sizes.is_empty() {
        bail!("no compiled batch sizes");
    }
    let mut plan = Vec::new();
    let mut remaining = n;
    // Full chunks of the largest compiled size first.
    let largest = *sizes.last().unwrap();
    while remaining >= largest {
        plan.push((largest, largest));
        remaining -= largest;
    }
    if remaining > 0 {
        // Remainder: decompose over descending compiled sizes (9 over
        // {1,8,32} -> 8 + 1, zero padding) — but every chunk is a separate
        // engine dispatch *per Euler step*, so a long tail of tiny chunks
        // costs far more than padding one larger call (measured: 8 x b1
        // steps ≈ 5x one padded b64 step on two_moons). If the zero-padding
        // decomposition needs more than 2 chunks, use a single best-fit
        // padded chunk instead.
        let mut tail = Vec::new();
        let mut rem = remaining;
        for &size in sizes.iter().rev() {
            while rem >= size {
                tail.push((size, size));
                rem -= size;
            }
        }
        if rem > 0 {
            tail.push((rem, best_fit(rem, &sizes)?));
        }
        let fit = best_fit(remaining, &sizes)?;
        if tail.len() > 2 && fit < 4 * remaining {
            // Bounded waste: merging is only allowed when the padded call
            // computes strictly less than 4x the useful rows (a padded b1024 call for
            // a 256-row remainder measured ~4x slower than 4 x b64 calls).
            plan.push((remaining, fit));
        } else {
            plan.append(&mut tail);
        }
    }
    Ok(plan)
}

/// Total padded rows a plan would execute.
pub fn padding_cost(plan: &[(usize, usize)]) -> usize {
    plan.iter().map(|&(len, size)| size - len).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_picks_smallest_fitting() {
        let compiled = vec![32, 1, 8];
        assert_eq!(best_fit(1, &compiled).unwrap(), 1);
        assert_eq!(best_fit(2, &compiled).unwrap(), 8);
        assert_eq!(best_fit(8, &compiled).unwrap(), 8);
        assert_eq!(best_fit(9, &compiled).unwrap(), 32);
        assert_eq!(best_fit(100, &compiled).unwrap(), 32); // caller splits
        assert!(best_fit(4, &[]).is_err());
    }

    #[test]
    fn plan_chunks_covers_exactly() {
        let compiled = vec![1, 8, 32];
        for n in [0usize, 1, 5, 8, 9, 31, 32, 33, 100, 129] {
            let plan = plan_chunks(n, &compiled).unwrap();
            let total: usize = plan.iter().map(|p| p.0).sum();
            assert_eq!(total, n, "n={n} plan={plan:?}");
            for &(len, size) in &plan {
                assert!(len <= size);
                assert!(compiled.contains(&size));
            }
        }
    }

    #[test]
    fn plan_minimizes_padding_reasonably() {
        let compiled = vec![1, 8, 32];
        // 33 = 32 + 1 with zero padding.
        let plan = plan_chunks(33, &compiled).unwrap();
        assert_eq!(padding_cost(&plan), 0);
        // 9 = 32-chunk would waste 23; greedy gives 8+1 (wastes 0).
        let plan9 = plan_chunks(9, &compiled).unwrap();
        assert_eq!(padding_cost(&plan9), 0);
        // 2 -> pad to 8 (cost 6): unavoidable with {1,8,32} in one chunk,
        // but greedy uses the 8 not the 32.
        let plan2 = plan_chunks(2, &compiled).unwrap();
        assert!(padding_cost(&plan2) <= 6);
    }

    #[test]
    fn plan_merges_long_tails_with_bounded_padding() {
        // 12 over {1,8,32}: zero padding needs 5 chunks (8 + 4x1); the
        // merge rule pads one b32 call instead (32 <= 4*12).
        assert_eq!(plan_chunks(12, &[1, 8, 32]).unwrap(), vec![(12, 32)]);
        // 8 over {1,64,...}: merging would pad 8x (64 > 4*8) — keep the
        // zero-padding decomposition even though it is 8 dispatches.
        assert_eq!(plan_chunks(8, &[1, 64, 1024]).unwrap(), vec![(1, 1); 8]);
        // 256 over {1,64,1024}: 4 full b64 chunks, no merge into b1024
        // (1024 = 4*256 boundary is allowed, but the tail here is full
        // chunks of one size handled by the descending loop).
        assert_eq!(plan_chunks(256, &[1, 64, 1024]).unwrap(), vec![(64, 64); 4]);
        // Short tails keep zero padding.
        assert_eq!(plan_chunks(65, &[1, 64, 1024]).unwrap(), vec![(64, 64), (1, 1)]);
        assert_eq!(plan_chunks(9, &[1, 8, 32]).unwrap(), vec![(8, 8), (1, 1)]);
    }

    #[test]
    fn plan_chunks_properties_hold_for_random_inputs() {
        // Property-based pin of the planner invariants, over random
        // (n_total, compiled-set) pairs:
        //   1. exact cover: chunk lengths sum to n, every chunk fits its
        //      compiled size, every size is from the compiled set;
        //   2. at most one padded chunk (only the tail can pad);
        //   3. padding is bounded: either total padding <= smallest
        //      compiled size - 1 (the zero-pad tail decomposition), or
        //      the tail was merged and its padded call computes < 4x the
        //      useful rows (the documented dispatch-vs-padding trade).
        use crate::util::prop::{check, Pair, UsizeRange, VecOf};
        check(
            "plan_chunks exact cover + bounded padding",
            Pair(UsizeRange(0, 300), VecOf(UsizeRange(0, 4), 4)),
            |(n, size_idx)| {
                let universe = [1usize, 4, 8, 32, 64];
                let mut compiled: Vec<usize> = size_idx.iter().map(|&i| universe[i]).collect();
                compiled.sort_unstable();
                compiled.dedup();
                if compiled.is_empty() {
                    // Degenerate input: the planner must reject it (for
                    // n > 0) rather than emit an empty cover.
                    if *n > 0 && plan_chunks(*n, &compiled).is_ok() {
                        return Err("empty compiled set accepted".into());
                    }
                    return Ok(());
                }
                let plan =
                    plan_chunks(*n, &compiled).map_err(|e| format!("planner failed: {e:#}"))?;
                let total: usize = plan.iter().map(|p| p.0).sum();
                if total != *n {
                    return Err(format!("covers {total} != n={n}: {plan:?}"));
                }
                for &(len, size) in &plan {
                    if len > size || !compiled.contains(&size) {
                        return Err(format!("bad chunk ({len}, {size}) over {compiled:?}"));
                    }
                }
                let padded: Vec<(usize, usize)> =
                    plan.iter().copied().filter(|&(len, size)| len < size).collect();
                if padded.len() > 1 {
                    return Err(format!("{} padded chunks: {plan:?}", padded.len()));
                }
                if let Some(&(len, size)) = padded.first() {
                    let min = *compiled.iter().min().unwrap();
                    let zero_pad_tail = padding_cost(&plan) <= min.saturating_sub(1);
                    let bounded_merge = size < 4 * len;
                    if !zero_pad_tail && !bounded_merge {
                        return Err(format!(
                            "padding unbounded: chunk ({len}, {size}), min={min}: {plan:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn best_fit_beyond_largest_returns_largest() {
        // When n exceeds every compiled size, best_fit answers with the
        // largest (the caller splits) — for any non-multiple overshoot.
        assert_eq!(best_fit(33, &[8, 32]).unwrap(), 32);
        assert_eq!(best_fit(65, &[1, 8, 32]).unwrap(), 32);
        assert_eq!(best_fit(1000, &[4]).unwrap(), 4);
    }

    #[test]
    fn plan_pins_greedy_largest_first_when_n_exceeds_all_sizes() {
        // Fixed-case pins of the greedy largest-first decomposition for
        // `n` beyond every compiled size by a NON-multiple. The property
        // test above guarantees exact cover + bounded padding; these pin
        // the exact plans, because the fleet's per-replica chunk dispatch
        // keys RNG substreams off chunk_index — a planner that re-ordered
        // or re-grouped chunks would silently re-seed every chunk.
        //
        // 70 over {1,8,32}: two full b32 chunks, remainder 6 would need
        // 6 b1 dispatches — merged into one padded b8 (8 < 4*6).
        assert_eq!(plan_chunks(70, &[1, 8, 32]).unwrap(), vec![(32, 32), (32, 32), (6, 8)]);
        // 67 over {1,8,32}: remainder 3 likewise merges into a b8.
        assert_eq!(plan_chunks(67, &[1, 8, 32]).unwrap(), vec![(32, 32), (32, 32), (3, 8)]);
        // 33 over {8,32}: short tail keeps the zero-padding-first shape —
        // one full b32, then the b8 best fit for the single leftover row.
        assert_eq!(plan_chunks(33, &[8, 32]).unwrap(), vec![(32, 32), (1, 8)]);
        // 100 over {32} alone: three full chunks + one padded tail.
        assert_eq!(
            plan_chunks(100, &[32]).unwrap(),
            vec![(32, 32), (32, 32), (32, 32), (4, 32)]
        );
        // 9 over {4} alone: two full + padded remainder, all on the only
        // compiled size.
        assert_eq!(plan_chunks(9, &[4]).unwrap(), vec![(4, 4), (4, 4), (1, 4)]);
        // Order is part of the contract: full largest-size chunks always
        // precede the tail, so chunk_index is stable under load.
        let plan = plan_chunks(70, &[1, 8, 32]).unwrap();
        assert!(plan.windows(2).all(|w| w[0].1 >= w[1].1), "descending sizes: {plan:?}");
    }

    #[test]
    fn single_size_always_works() {
        let plan = plan_chunks(10, &[4]).unwrap();
        let total: usize = plan.iter().map(|p| p.0).sum();
        assert_eq!(total, 10);
        assert!(plan.iter().all(|&(_, s)| s == 4));
    }
}
