//! Artifact metadata: the `*.meta.json` sidecars and `manifest.json`
//! emitted by `python/compile/aot.py`.

use crate::core::rng::{fnv1a64, FNV_OFFSET};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Current manifest schema version. v1 manifests (no `schema_version`
/// field, no content hashes) still load — hashes are simply absent and
/// `verify_hashes` reports them as unhashed rather than failing.
pub const MANIFEST_SCHEMA_VERSION: u64 = 2;

/// One input/output tensor spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let name = j.get("name").as_str().context("spec missing name")?.to_string();
        let dtype = j.get("dtype").as_str().context("spec missing dtype")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("spec missing shape")?
            .iter()
            .map(|v| v.as_usize().context("bad shape dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata for one compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo_file: String,
    pub domain: String,
    /// "step" (fused denoise+update) or "draft".
    pub kind: String,
    /// For steps: the training tag ("cold", "ws_t080", "ws_good_t095", ...).
    pub tag: String,
    /// For drafts: "lstm" | "pca". For steps trained warm: the draft kind.
    pub draft: Option<String>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub t0: Option<f64>,
    pub latent_dim: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// FNV-1a 64 hash of the referenced HLO file's bytes, as emitted by
    /// the AOT pipeline (`content_hash: "<16 hex digits>"`). `None` on
    /// schema-v1 manifests.
    pub content_hash: Option<u64>,
}

impl ArtifactMeta {
    pub fn from_json(j: &Json) -> Result<Self> {
        let get_str = |k: &str| j.get(k).as_str().map(|s| s.to_string());
        Ok(ArtifactMeta {
            name: get_str("name").context("artifact missing name")?,
            hlo_file: get_str("hlo_file").context("artifact missing hlo_file")?,
            domain: get_str("domain").unwrap_or_default(),
            kind: get_str("kind").unwrap_or_default(),
            tag: get_str("tag").unwrap_or_default(),
            draft: get_str("draft"),
            batch: j.get("batch").as_usize().unwrap_or(0),
            seq_len: j.get("seq_len").as_usize().unwrap_or(0),
            vocab: j.get("vocab").as_usize().unwrap_or(0),
            t0: j.get("t0").as_f64(),
            latent_dim: j.get("latent_dim").as_usize(),
            inputs: j
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
            outputs: j
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
            content_hash: match j.get("content_hash").as_str() {
                Some(h) => Some(
                    u64::from_str_radix(h, 16)
                        .with_context(|| format!("bad content_hash {h:?}"))?,
                ),
                None => None,
            },
        })
    }
}

/// Outcome of [`Manifest::verify_hashes`]: how many artifacts matched
/// their declared content hash, how many carry no hash (schema v1), and
/// which ones disagreed with the bytes on disk.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VerifyReport {
    pub verified: usize,
    pub unhashed: usize,
    /// `(artifact name, declared hash, actual hash)` per mismatch.
    pub mismatches: Vec<(String, u64, u64)>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }
}

impl std::fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "verified={} unhashed={} mismatched={}",
            self.verified,
            self.unhashed,
            self.mismatches.len()
        )
    }
}

/// The artifact index: everything the AOT pipeline emitted.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub domains: Json,
    pub batch_sizes: BTreeMap<String, Vec<usize>>,
    /// Declared `schema_version` (1 when the field is absent — legacy
    /// manifests predate the versioned contract).
    pub schema_version: u64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let artifacts = j
            .get("artifacts")
            .as_arr()
            .context("manifest missing artifacts")?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut batch_sizes = BTreeMap::new();
        if let Some(obj) = j.get("batch_sizes").as_obj() {
            for (k, v) in obj {
                let sizes = v
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect::<Vec<_>>();
                batch_sizes.insert(k.clone(), sizes);
            }
        }
        let schema_version = j.get("schema_version").as_u64().unwrap_or(1);
        if schema_version > MANIFEST_SCHEMA_VERSION {
            bail!(
                "manifest schema_version {schema_version} is newer than this binary \
                 supports ({MANIFEST_SCHEMA_VERSION}) — rebuild or regenerate artifacts"
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            domains: j.get("domains").clone(),
            batch_sizes,
            schema_version,
        })
    }

    /// FNV-1a 64 over a file's bytes — the manifest content-hash
    /// function, shared with the verify path and the fleet swap probe.
    pub fn hash_file(path: &Path) -> Result<u64> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Ok(fnv1a64(FNV_OFFSET, &bytes))
    }

    /// Check every artifact's declared `content_hash` against the bytes
    /// on disk. Missing files are errors; missing hashes (schema v1) are
    /// tallied, not failed — `wsfm verify-artifacts` decides how strict
    /// to be.
    pub fn verify_hashes(&self) -> Result<VerifyReport> {
        let mut report = VerifyReport::default();
        for a in &self.artifacts {
            match a.content_hash {
                None => report.unhashed += 1,
                Some(declared) => {
                    let actual = Self::hash_file(&self.hlo_path(a))
                        .with_context(|| format!("hashing artifact {}", a.name))?;
                    if actual == declared {
                        report.verified += 1;
                    } else {
                        report.mismatches.push((a.name.clone(), declared, actual));
                    }
                }
            }
        }
        Ok(report)
    }

    /// All artifacts for a domain.
    pub fn for_domain(&self, domain: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.domain == domain).collect()
    }

    /// Find a step artifact by (domain, tag, batch).
    pub fn find_step(&self, domain: &str, tag: &str, batch: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.domain == domain && a.kind == "step" && a.tag == tag && a.batch == batch)
            .with_context(|| format!("no step artifact for {domain}/{tag}/b{batch}"))
    }

    /// Find a draft artifact by (domain, draft kind, batch).
    pub fn find_draft(&self, domain: &str, draft: &str, batch: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| {
                a.domain == domain
                    && a.kind == "draft"
                    && a.draft.as_deref() == Some(draft)
                    && a.batch == batch
            })
            .with_context(|| format!("no draft artifact for {domain}/{draft}/b{batch}"))
    }

    /// Compiled batch sizes available for (domain, tag) steps, ascending.
    pub fn step_batches(&self, domain: &str, tag: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.domain == domain && a.kind == "step" && a.tag == tag)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// All step tags for a domain (e.g. ["cold", "ws_t050", "ws_t080"]).
    pub fn step_tags(&self, domain: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .iter()
            .filter(|a| a.domain == domain && a.kind == "step")
            .map(|a| a.tag.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Domain names present.
    pub fn domain_names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.artifacts.iter().map(|a| a.domain.clone()).filter(|d| !d.is_empty()).collect();
        v.sort();
        v.dedup();
        v
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.hlo_file)
    }

    /// Validate structural invariants (every referenced file exists, specs
    /// are consistent). Used by `wsfm selfcheck`.
    pub fn selfcheck(&self) -> Result<()> {
        if self.artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        for a in &self.artifacts {
            let p = self.hlo_path(a);
            if !p.exists() {
                bail!("artifact {} references missing file {:?}", a.name, p);
            }
            if a.kind == "step" {
                if a.inputs.len() != 4 {
                    bail!("step {} should have 4 inputs, has {}", a.name, a.inputs.len());
                }
                if a.inputs[0].shape != vec![a.batch, a.seq_len] {
                    bail!("step {} x_t spec mismatch", a.name);
                }
                if a.outputs[0].shape != vec![a.batch, a.seq_len, a.vocab] {
                    bail!("step {} probs spec mismatch", a.name);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json() -> Json {
        Json::parse(
            r#"{
              "name":"d_cold_step_b4","hlo_file":"d_cold_step_b4.hlo.txt",
              "domain":"d","kind":"step","tag":"cold","batch":4,"seq_len":8,"vocab":16,
              "t0":0.0,
              "inputs":[{"name":"x_t","shape":[4,8],"dtype":"s32"},
                        {"name":"t","shape":[],"dtype":"f32"},
                        {"name":"h","shape":[],"dtype":"f32"},
                        {"name":"warp","shape":[],"dtype":"f32"}],
              "outputs":[{"name":"probs","shape":[4,8,16],"dtype":"f32"}]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn artifact_meta_parses() {
        let m = ArtifactMeta::from_json(&meta_json()).unwrap();
        assert_eq!(m.name, "d_cold_step_b4");
        assert_eq!(m.batch, 4);
        assert_eq!(m.inputs.len(), 4);
        assert_eq!(m.outputs[0].numel(), 4 * 8 * 16);
        assert_eq!(m.t0, Some(0.0));
    }

    #[test]
    fn manifest_lookup() {
        let m = Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![ArtifactMeta::from_json(&meta_json()).unwrap()],
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
            schema_version: 1,
        };
        assert!(m.find_step("d", "cold", 4).is_ok());
        assert!(m.find_step("d", "cold", 8).is_err());
        assert!(m.find_step("d", "ws_t080", 4).is_err());
        assert_eq!(m.step_batches("d", "cold"), vec![4]);
        assert_eq!(m.step_tags("d"), vec!["cold"]);
        assert_eq!(m.domain_names(), vec!["d"]);
        assert!(m.find_draft("d", "lstm", 4).is_err());
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"name":"x"}"#).unwrap();
        assert!(ArtifactMeta::from_json(&j).is_err());
    }

    #[test]
    fn manifest_load_missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    /// Build a real on-disk manifest dir: one hashed artifact, one
    /// legacy (unhashed) artifact.
    fn write_fixture(dir: &Path) -> u64 {
        let hlo = b"HloModule step, entry_computation_layout={()->f32[]}";
        std::fs::write(dir.join("a.hlo.txt"), hlo).unwrap();
        std::fs::write(dir.join("b.hlo.txt"), b"HloModule other").unwrap();
        let hash = fnv1a64(FNV_OFFSET, hlo);
        let manifest = format!(
            r#"{{"schema_version":2,"artifacts":[
              {{"name":"a","hlo_file":"a.hlo.txt","content_hash":"{hash:016x}"}},
              {{"name":"b","hlo_file":"b.hlo.txt"}}
            ]}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        hash
    }

    #[test]
    fn verify_hashes_passes_then_catches_tamper() {
        let dir = std::env::temp_dir().join(format!("wsfm_verify_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let declared = write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.schema_version, 2);
        assert_eq!(m.artifacts[0].content_hash, Some(declared));
        assert_eq!(m.artifacts[1].content_hash, None);
        let report = m.verify_hashes().unwrap();
        assert!(report.ok());
        assert_eq!((report.verified, report.unhashed), (1, 1));

        // Flip one byte: the mismatch is caught and names the artifact.
        std::fs::write(dir.join("a.hlo.txt"), b"HloModule step, tampered").unwrap();
        let report = m.verify_hashes().unwrap();
        assert!(!report.ok());
        assert_eq!(report.mismatches.len(), 1);
        assert_eq!(report.mismatches[0].0, "a");
        assert_eq!(report.mismatches[0].1, declared);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn future_schema_version_is_rejected() {
        let dir = std::env::temp_dir().join(format!("wsfm_schema_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"schema_version":99,"artifacts":[{"name":"a","hlo_file":"a.hlo.txt"}]}"#,
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("newer than this binary"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_content_hash_string_errors() {
        let j = Json::parse(r#"{"name":"x","hlo_file":"x.hlo","content_hash":"zzzz"}"#).unwrap();
        assert!(ArtifactMeta::from_json(&j).is_err());
    }
}
