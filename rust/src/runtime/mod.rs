//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path. Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin).

pub mod artifact;
pub mod engine;
pub mod pool;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use engine::{Engine, EngineHandle, ExecutableKind, Executor};
pub use pool::{best_fit, padding_cost, plan_chunks};
