//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path. Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin)
//! behind the `pjrt` cargo feature; without it, [`xla_stub`] keeps the
//! engine API compiling (execution paths error, artifact tests skip).

pub mod artifact;
pub mod engine;
pub mod pool;
#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use engine::{
    drive_loop, Engine, EngineDead, EngineHandle, EngineStats, EngineTimeout, ExecutableKind,
    Executor, LoopReport, LoopScratch, LoopSpec,
};
pub use pool::{best_fit, padding_cost, plan_chunks};
