//! The PJRT execution engine.
//!
//! `xla::PjRtClient` is `Rc`-based and not `Send`, so all PJRT work runs on
//! a dedicated **engine thread** — one independent execution stream per
//! spawned engine. The rest of the stack talks to it through
//! [`EngineHandle`], a cloneable, `Send + Sync` channel front-end
//! implementing [`Executor`]; [`crate::fleet`] replicates whole engines
//! (thread + artifact cache) behind one routing handle when a single
//! stream is the throughput bottleneck.
//!
//! Artifacts are compiled lazily on first use and cached for the process
//! lifetime; `preload` warms them eagerly at startup.
//!
//! ## The engine-resident sampling loop
//!
//! The Euler refinement loop used to live in the sampler and cross the
//! engine channel once **per step** (plus a `tokens.to_vec()` copy and a
//! fresh `[B, N, V]` probs allocation each time). `Req::RunLoop` moves
//! the whole loop onto the engine thread: schedule + init tokens go in,
//! final tokens (+ optional trace snapshots) come out — **one** channel
//! round-trip per run, with per-artifact [`LoopScratch`] buffers reused
//! across steps and across runs, and categorical sampling parallelized
//! over rows with deterministic per-row RNG substreams
//! ([`crate::core::prob::categorical_batch_par`]). The shared loop body
//! [`drive_loop`] also backs [`Executor::run_loop`]'s default
//! implementation, so mock executors and the legacy per-step path sample
//! identically (seed-parity is pinned by tests).
//!
//! When the `pjrt` cargo feature is off, the API-compatible
//! [`crate::runtime::xla_stub`] stands in for the `xla` crate: the engine
//! thread spawns and serves metadata, and compilation/execution error
//! with a descriptive message (tests over real artifacts skip).

use crate::core::prob;
use crate::core::schedule::Schedule;
use crate::core::workers::WorkerPool;
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::sampler::trace::Trace;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_stub as xla;

/// Typed error for a dead engine thread: the request or response channel
/// disconnected, meaning the thread panicked, was shut down, or otherwise
/// exited. Callers that supervise replicas ([`crate::fleet`]) downcast to
/// this to distinguish "this engine is gone, re-route" from ordinary
/// execution errors ("bad artifact name") that would also fail anywhere
/// else. Every [`EngineHandle`] entry point returns it on disconnect —
/// never a hang, never a generic string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineDead;

impl std::fmt::Display for EngineDead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine thread dead (channel disconnected)")
    }
}

impl std::error::Error for EngineDead {}

/// Typed error for a wedged engine thread: the engine is still connected
/// but did not reply within the watchdog deadline
/// (`robustness.call_timeout_ms`). Supervisors treat it exactly like
/// [`EngineDead`] — quarantine the replica and re-route — because a
/// wedged-but-alive stream is just as unusable. The caller's reply
/// channel is dropped on timeout, so a late reply from the wedged engine
/// has no receiver and is discarded structurally (the engine-side `send`
/// fails); a resurrected replica can never observe a stale answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineTimeout {
    /// The deadline that was exceeded.
    pub timeout: Duration,
}

impl std::fmt::Display for EngineTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine call exceeded watchdog deadline ({:?})", self.timeout)
    }
}

impl std::error::Error for EngineTimeout {}

/// Executable kinds the engine knows how to drive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutableKind {
    /// `(x_t i32[B,N], t f32[], h f32[], warp f32[]) -> (probs f32[B,N,V],)`
    Step,
    /// `(noise f32[...]) -> (tokens i32[B,N],)`
    Draft,
}

/// Everything an engine-resident Euler run needs besides the init tokens.
///
/// A spec describes either a full run (`t_start == t0`, `t_end == 1.0` —
/// the [`LoopSpec::full`] constructor) or one **cascade segment** of it:
/// the window `[t_start, t_end)` of the run's step grid. Segments are
/// resumable and bitwise-faithful: the run seed plus the *absolute* step
/// index (via `Schedule::segment`'s `step_offset`) key every categorical
/// substream, so executing a run in k consecutive segments — even on
/// different engine replicas — produces exactly the unsplit run's tokens.
#[derive(Debug, Clone)]
pub struct LoopSpec {
    /// Step artifact name (fixed `[B, N]` shape).
    pub artifact: String,
    /// Cold-run step count (grid resolution).
    pub steps_cold: usize,
    /// Run-level warm-start time (`0.0` = cold DFM): anchors the step
    /// grid (and the pre-resolved warp factor) for every segment.
    pub t0: f64,
    /// Segment window start (`== t0` for a full run).
    pub t_start: f64,
    /// Segment window end (`1.0` = run to completion).
    pub t_end: f64,
    /// Pre-resolved warp factor (`WarpMode::warp_factor(t0)`).
    pub warp: f32,
    /// Run seed. Every `(absolute step, row)` categorical draw derives
    /// its own substream from it (`Pcg64::substream`), making results
    /// independent of worker count, of where the loop runs, and of how
    /// the run is split into segments.
    pub seed: u64,
    /// Capture per-step token snapshots (Fig. 5/7 dumps; costs one
    /// `[B, N]` clone per step, so off on the serving path).
    pub want_trace: bool,
    /// Trace recording stride (record every n-th snapshot; `1` = every
    /// step). Only read when `want_trace` is set.
    pub trace_stride: usize,
    /// Retained-trace-snapshot bound (`0` = unbounded). Bounds the
    /// engine-side collection itself (`sampler::trace::Trace` policy),
    /// so long traced runs hold at most `cap + 1` states.
    pub trace_cap: usize,
}

impl LoopSpec {
    /// A spec covering the whole run `[t0, 1]` (the non-cascade path).
    pub fn full(
        artifact: String,
        steps_cold: usize,
        t0: f64,
        warp: f32,
        seed: u64,
        want_trace: bool,
    ) -> LoopSpec {
        LoopSpec {
            artifact,
            steps_cold,
            t0,
            t_start: t0,
            t_end: 1.0,
            warp,
            seed,
            want_trace,
            trace_stride: 1,
            trace_cap: 0,
        }
    }
}

/// Reusable scratch for the sampling loop. In steady state the loop
/// performs **zero heap allocations per Euler step**: the probs buffer is
/// written in place every iteration and retains its `B·N·V` capacity
/// across steps (and, for the engine-resident path, across runs — the
/// engine keeps one per artifact). Pinned by the buffer-reuse test.
#[derive(Debug, Default)]
pub struct LoopScratch {
    /// `[B * N * V]` probs output staging, reused across steps.
    pub probs: Vec<f32>,
}

/// What a loop run reports besides the final tokens.
#[derive(Debug, Clone)]
pub struct LoopReport {
    /// Denoiser evaluations performed (`== Schedule::nfe()` by construction).
    pub nfe: usize,
    /// Wall-clock of the refinement loop.
    pub elapsed: Duration,
    /// The recorded trajectory (initial state + per-step snapshots under
    /// the spec's stride/cap policy), when `want_trace` was set. Bounded
    /// at the collection site, so the channel never carries an unbounded
    /// snapshot payload.
    pub snapshots: Option<Trace>,
}

/// Drive the Euler CTMC loop over a step callback: the single loop body
/// shared by the engine thread ([`Engine::exec_loop`]) and the default
/// [`Executor::run_loop`], so every executor samples identically.
///
/// `step_into` must fill `out` with the `[B, N, V]` transition probs for
/// the current tokens; `tokens` is resampled in place after every step.
pub fn drive_loop<F>(
    spec: &LoopSpec,
    batch: usize,
    seq_len: usize,
    vocab: usize,
    tokens: &mut Vec<i32>,
    scratch: &mut LoopScratch,
    mut step_into: F,
) -> Result<LoopReport>
where
    F: FnMut(&[i32], f32, f32, f32, &mut Vec<f32>) -> Result<()>,
{
    if tokens.len() != batch * seq_len {
        bail!(
            "loop {}: tokens len {} != {}x{}",
            spec.artifact,
            tokens.len(),
            batch,
            seq_len
        );
    }
    // A full spec (t_start == t0, t_end == 1) yields the unsplit schedule
    // with step_offset 0 — the legacy path, bit for bit. A segment spec
    // yields the corresponding sub-window of that same grid.
    let schedule = Schedule::segment(spec.steps_cold, spec.t0, spec.t_start, spec.t_end)?;
    let want = batch * seq_len * vocab;
    scratch.probs.clear();
    scratch.probs.reserve(want); // one-time growth; steady state reuses it

    let start = Instant::now();
    let mut snapshots = spec.want_trace.then(|| {
        let mut tr = Trace::with_policy(spec.trace_stride, spec.trace_cap);
        tr.push_raw(schedule.t0, batch, seq_len, tokens);
        tr
    });
    for i in 0..schedule.nfe() {
        let t = schedule.times[i] as f32;
        let h = schedule.step_size(i) as f32;
        step_into(tokens.as_slice(), t, h, spec.warp, &mut scratch.probs)?;
        if scratch.probs.len() != want {
            bail!(
                "artifact {} returned {} probs, want {}",
                spec.artifact,
                scratch.probs.len(),
                want
            );
        }
        prob::categorical_batch_par(
            &scratch.probs,
            vocab,
            tokens.as_mut_slice(),
            spec.seed,
            (schedule.step_offset + i) as u64, // absolute step: split == unsplit
            WorkerPool::shared(),
        );
        if let Some(sn) = snapshots.as_mut() {
            sn.push_raw(schedule.times[i] + schedule.step_size(i), batch, seq_len, tokens);
        }
    }
    Ok(LoopReport { nfe: schedule.nfe(), elapsed: start.elapsed(), snapshots })
}

/// Per-row step parameters for a **composed** engine step: rows merged
/// from different bundles (or cascade segments) may sit at different
/// trajectory points, so each row carries its own evaluation time, step
/// size, and warp factor. Rows with equal `RowStep` values share one
/// denoiser forward pass; the composer sorts same-parameter rows together
/// so the common case (concurrently admitted bundles with the same
/// schedule) is a single full forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowStep {
    /// Evaluation time of this row's current Euler step.
    pub t: f32,
    /// Step size of this row's current Euler step.
    pub h: f32,
    /// The row's run-level warp factor.
    pub warp: f32,
}

/// Abstract executor — the seam between the coordinator/sampler and PJRT.
/// Tests substitute a mock; production uses [`EngineHandle`].
///
/// `step` and `step_into` are defined in terms of each other: implement at
/// least one (allocation-sensitive executors should implement `step_into`).
pub trait Executor: Send + Sync {
    /// Run a fused denoise+update step artifact, returning a fresh buffer.
    fn step(&self, artifact: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.step_into(artifact, tokens, t, h, warp, &mut out)?;
        Ok(out)
    }

    /// Run a step artifact, writing probs into `out` (cleared and refilled;
    /// capacity is retained across calls so steady-state use is
    /// allocation-free).
    fn step_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let probs = self.step(artifact, tokens, t, h, warp)?;
        out.clear();
        out.extend_from_slice(&probs);
        Ok(())
    }

    /// Run one **composed** step: `rows.len()` rows (`tokens` is
    /// `[rows, seq_len]`, row-major), each advancing by its own
    /// [`RowStep`] parameters, in a single executor dispatch. Fills `out`
    /// with the concatenated `[rows, seq_len, vocab]` transition probs in
    /// row order. `artifact` names a step artifact of the rows' shared
    /// `(domain, tag, seq_len, vocab)` family; implementations may
    /// execute on any compiled batch of that family (padding rows never
    /// leak — `out` holds exactly `rows.len()` rows' probs).
    ///
    /// The default groups maximal runs of parameter-equal rows and issues
    /// one `step_into` per run — correct for shape-flexible executors
    /// (mocks, whose kernels are per-row). [`EngineHandle`] overrides it
    /// to ship the whole composed step to the engine thread in one
    /// round-trip, where runs are padded onto compiled batches.
    fn step_rows_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        seq_len: usize,
        rows: &[RowStep],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if tokens.len() != rows.len() * seq_len.max(1) {
            bail!(
                "composed step {}: tokens len {} != {} rows x {}",
                artifact,
                tokens.len(),
                rows.len(),
                seq_len
            );
        }
        out.clear();
        let mut probs = Vec::new();
        let mut start = 0;
        while start < rows.len() {
            let mut end = start + 1;
            while end < rows.len() && rows[end] == rows[start] {
                end += 1;
            }
            let rs = rows[start];
            self.step_into(
                artifact,
                &tokens[start * seq_len..end * seq_len],
                rs.t,
                rs.h,
                rs.warp,
                &mut probs,
            )?;
            out.extend_from_slice(&probs);
            start = end;
        }
        Ok(())
    }

    /// Run a draft sampler artifact with externally-generated noise.
    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>>;

    /// Metadata lookup.
    fn meta(&self, artifact: &str) -> Result<ArtifactMeta>;

    /// Liveness probe: a cheap round-trip that succeeds iff the executor
    /// can serve calls. The fleet health loop requires a passing probe
    /// before readmitting a resurrected replica. Default: trivially
    /// healthy (pure mocks never wedge); [`EngineHandle`] overrides this
    /// with a real engine-thread round-trip.
    fn probe(&self) -> Result<()> {
        Ok(())
    }

    /// Run the whole Euler sampling loop, resampling `tokens` in place.
    ///
    /// The default drives [`drive_loop`] through `step_into` using the
    /// caller's `scratch` — zero per-step allocations when `step_into` is
    /// allocation-free. [`EngineHandle`] overrides this to ship the loop
    /// to the engine thread in a single channel round-trip (the engine
    /// keeps its own persistent per-artifact scratch; the caller's is then
    /// untouched). On error, `tokens` content is unspecified.
    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        let meta = self.meta(&spec.artifact)?;
        drive_loop(
            spec,
            meta.batch,
            meta.seq_len,
            meta.vocab,
            tokens,
            scratch,
            |toks, t, h, warp, out| self.step_into(&spec.artifact, toks, t, h, warp, out),
        )
    }
}

/// Shared executors are executors: the pipelined coordinator hands one
/// `Arc<E>` to each stage thread, and anything expecting an [`Executor`]
/// (scheduler, sampler, benches) can take the `Arc` directly. Every
/// method delegates — including `run_loop`, so an `Arc<EngineHandle>`
/// keeps the single-round-trip engine-resident path.
impl<T: Executor + ?Sized> Executor for std::sync::Arc<T> {
    fn step(&self, artifact: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        (**self).step(artifact, tokens, t, h, warp)
    }

    fn step_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        (**self).step_into(artifact, tokens, t, h, warp, out)
    }

    fn step_rows_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        seq_len: usize,
        rows: &[RowStep],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        (**self).step_rows_into(artifact, tokens, seq_len, rows, out)
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        (**self).draft(artifact, noise)
    }

    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        (**self).meta(artifact)
    }

    fn probe(&self) -> Result<()> {
        (**self).probe()
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        (**self).run_loop(spec, tokens, scratch)
    }
}

/// Marker alias used in public re-exports.
pub type StepFn = dyn Executor;

// ---------------------------------------------------------------------------
// Engine thread internals
// ---------------------------------------------------------------------------

enum Req {
    Step { name: String, tokens: Vec<i32>, t: f32, h: f32, warp: f32, resp: mpsc::Sender<Result<Vec<f32>>> },
    /// One composed step over rows merged from multiple bundles: one
    /// round-trip per composed step, not per contributing bundle.
    StepRows { name: String, tokens: Vec<i32>, seq_len: usize, rows: Vec<RowStep>, resp: mpsc::Sender<Result<Vec<f32>>> },
    /// The engine-resident Euler loop: one request per *run*, not per step.
    RunLoop { spec: LoopSpec, tokens: Vec<i32>, resp: mpsc::Sender<Result<(Vec<i32>, LoopReport)>> },
    Draft { name: String, noise: Vec<f32>, resp: mpsc::Sender<Result<Vec<i32>>> },
    Preload { names: Vec<String>, resp: mpsc::Sender<Result<()>> },
    Stats { resp: mpsc::Sender<EngineStats> },
    Shutdown,
}

/// Compile/exec statistics (surfaced in `wsfm selfcheck`/`serve` and
/// EXPERIMENTS.md §Perf). Counters are microseconds — engine steps on
/// small shapes run well under a millisecond, and the old `as_millis()`
/// counters truncated them all to zero.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub compiled: usize,
    pub executions: u64,
    /// Engine-resident loop runs completed (each covering `nfe` executions).
    pub loop_runs: u64,
    pub compile_us_total: u64,
    pub exec_us_total: u64,
}

impl EngineStats {
    pub fn compile_ms(&self) -> f64 {
        self.compile_us_total as f64 / 1e3
    }

    pub fn exec_ms(&self) -> f64 {
        self.exec_us_total as f64 / 1e3
    }

    /// One-line human rendering (used by the CLI).
    pub fn summary(&self) -> String {
        format!(
            "{} compiled in {:.1} ms; {} execs ({} loop runs) in {:.1} ms ({:.1} µs/exec)",
            self.compiled,
            self.compile_ms(),
            self.executions,
            self.loop_runs,
            self.exec_ms(),
            self.exec_us_total as f64 / (self.executions.max(1) as f64)
        )
    }
}

/// The engine proper (lives on the engine thread; `!Send` by content).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Per-artifact loop scratch, reused across steps and runs.
    scratch: HashMap<String, LoopScratch>,
    stats: EngineStats,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            scratch: HashMap::new(),
            stats: EngineStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    /// Compile (and cache) an artifact by name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?;
        let path = self.manifest.hlo_path(&meta);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.stats.compile_us_total += start.elapsed().as_micros() as u64;
        self.stats.compiled += 1;
        crate::info!("compiled {name} in {:?}", start.elapsed());
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a step artifact into `out` given its (pre-looked-up) meta.
    /// The probs copy lands in `out` so callers can reuse one buffer across
    /// steps; the PJRT readback itself (`to_vec`) still allocates — that is
    /// an `xla` API constraint, noted in EXPERIMENTS.md §Perf.
    fn exec_step_with_meta(
        &mut self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if meta.kind != "step" {
            bail!("artifact {} is not a step (kind={})", meta.name, meta.kind);
        }
        let (b, n, v) = (meta.batch, meta.seq_len, meta.vocab);
        if tokens.len() != b * n {
            bail!("step {}: tokens len {} != {}x{}", meta.name, tokens.len(), b, n);
        }
        self.ensure_compiled(&meta.name)?;
        let start = Instant::now();
        let x = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, n as i64])
            .map_err(|e| anyhow!("reshape x_t: {e:?}"))?;
        let args =
            [x, xla::Literal::scalar(t), xla::Literal::scalar(h), xla::Literal::scalar(warp)];
        let exe = self.cache.get(&meta.name).unwrap();
        let result = exe.execute(&args).map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tup = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let probs = tup.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if probs.len() != b * n * v {
            bail!("step {}: output len {} != {}", meta.name, probs.len(), b * n * v);
        }
        // Move, don't copy: to_vec() already allocated this run's buffer.
        *out = probs;
        self.stats.executions += 1;
        self.stats.exec_us_total += start.elapsed().as_micros() as u64;
        Ok(())
    }

    /// Execute a step artifact.
    pub fn exec_step(&mut self, name: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        let meta = self.meta(name)?;
        let mut out = Vec::new();
        self.exec_step_with_meta(&meta, tokens, t, h, warp, &mut out)?;
        Ok(out)
    }

    /// Run one composed step (the `Req::StepRows` service routine):
    /// maximal runs of parameter-equal rows are padded onto compiled
    /// batches of the artifact's `(domain, tag)` family and executed;
    /// padding probs are stripped before the reply, so the caller sees
    /// exactly `rows.len()` rows — and, because the position-wise step
    /// kernels are row-independent, exactly the probs the unbatched path
    /// would have produced for those rows.
    pub fn exec_step_rows(
        &mut self,
        name: &str,
        tokens: &[i32],
        seq_len: usize,
        rows: &[RowStep],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let family = self.meta(name)?;
        if family.kind != "step" {
            bail!("artifact {} is not a step (kind={})", family.name, family.kind);
        }
        if seq_len != family.seq_len {
            bail!(
                "composed step {name}: seq_len {seq_len} != artifact seq_len {}",
                family.seq_len
            );
        }
        if tokens.len() != rows.len() * seq_len {
            bail!(
                "composed step {name}: tokens len {} != {} rows x {seq_len}",
                tokens.len(),
                rows.len()
            );
        }
        let mut batches: Vec<usize> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "step" && a.domain == family.domain && a.tag == family.tag)
            .map(|a| a.batch)
            .collect();
        batches.sort_unstable();
        batches.dedup();
        let largest = *batches.last().expect("family contains the named artifact");
        out.clear();
        out.reserve(rows.len() * seq_len * family.vocab);
        let mut probs = Vec::new();
        let mut padded: Vec<i32> = Vec::new();
        let mut start = 0;
        while start < rows.len() {
            let mut end = start + 1;
            while end < rows.len() && rows[end] == rows[start] {
                end += 1;
            }
            let rs = rows[start];
            // A run larger than the largest compiled batch executes in
            // largest-batch slices; smaller runs pad up to the smallest
            // compiled batch that fits.
            let mut cursor = start;
            while cursor < end {
                let remaining = end - cursor;
                let exec_batch =
                    batches.iter().copied().find(|&b| b >= remaining).unwrap_or(largest);
                let take = remaining.min(exec_batch);
                let meta =
                    self.manifest.find_step(&family.domain, &family.tag, exec_batch)?.clone();
                padded.clear();
                padded.extend_from_slice(&tokens[cursor * seq_len..(cursor + take) * seq_len]);
                padded.resize(exec_batch * seq_len, 0);
                self.exec_step_with_meta(&meta, &padded, rs.t, rs.h, rs.warp, &mut probs)?;
                out.extend_from_slice(&probs[..take * seq_len * meta.vocab]);
                cursor += take;
            }
            start = end;
        }
        Ok(())
    }

    /// Run the whole Euler loop on the engine thread (the `Req::RunLoop`
    /// service routine). Scratch buffers persist per artifact, so
    /// steady-state runs allocate nothing per step beyond what the PJRT
    /// readback API imposes.
    pub fn exec_loop(&mut self, spec: &LoopSpec, tokens: &mut Vec<i32>) -> Result<LoopReport> {
        let meta = self.meta(&spec.artifact)?;
        if meta.kind != "step" {
            bail!("artifact {} is not a step (kind={})", meta.name, meta.kind);
        }
        self.ensure_compiled(&spec.artifact)?;
        let mut scratch = self.scratch.remove(&spec.artifact).unwrap_or_default();
        let result = drive_loop(
            spec,
            meta.batch,
            meta.seq_len,
            meta.vocab,
            tokens,
            &mut scratch,
            |toks, t, h, warp, out| self.exec_step_with_meta(&meta, toks, t, h, warp, out),
        );
        self.scratch.insert(spec.artifact.clone(), scratch);
        if result.is_ok() {
            self.stats.loop_runs += 1;
        }
        result
    }

    /// Execute a draft artifact.
    pub fn exec_draft(&mut self, name: &str, noise: &[f32]) -> Result<Vec<i32>> {
        let meta = self.meta(name)?;
        if meta.kind != "draft" {
            bail!("artifact {name} is not a draft (kind={})", meta.kind);
        }
        let in_spec = meta.inputs.first().context("draft missing input spec")?;
        if noise.len() != in_spec.numel() {
            bail!("draft {name}: noise len {} != {}", noise.len(), in_spec.numel());
        }
        self.ensure_compiled(name)?;
        let start = Instant::now();
        let dims: Vec<i64> = in_spec.shape.iter().map(|&d| d as i64).collect();
        let z = xla::Literal::vec1(noise).reshape(&dims).map_err(|e| anyhow!("reshape noise: {e:?}"))?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute(&[z]).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let tokens = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if tokens.len() != meta.batch * meta.seq_len {
            bail!("draft {name}: output len {} != {}", tokens.len(), meta.batch * meta.seq_len);
        }
        self.stats.executions += 1;
        self.stats.exec_us_total += start.elapsed().as_micros() as u64;
        Ok(tokens)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.clone()
    }
}

// ---------------------------------------------------------------------------
// Thread + handle
// ---------------------------------------------------------------------------

/// Cloneable, thread-safe front-end to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Req>,
    manifest: std::sync::Arc<Manifest>,
    /// Watchdog deadline applied to every call's reply wait (`None` =
    /// block until the engine replies, the pre-robustness behaviour).
    /// `preload` is exempt — initial compilation of a large artifact set
    /// legitimately outlasts any per-call deadline.
    call_timeout: Option<Duration>,
}

impl EngineHandle {
    /// Spawn the engine thread over a loaded manifest.
    pub fn spawn(manifest: Manifest) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Req>();
        let manifest_arc = std::sync::Arc::new(manifest.clone());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("wsfm-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(manifest) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Step { name, tokens, t, h, warp, resp } => {
                            let _ = resp.send(engine.exec_step(&name, &tokens, t, h, warp));
                        }
                        Req::StepRows { name, tokens, seq_len, rows, resp } => {
                            let mut out = Vec::new();
                            let r = engine
                                .exec_step_rows(&name, &tokens, seq_len, &rows, &mut out)
                                .map(|()| out);
                            let _ = resp.send(r);
                        }
                        Req::RunLoop { spec, mut tokens, resp } => {
                            let r = engine.exec_loop(&spec, &mut tokens).map(|rep| (tokens, rep));
                            let _ = resp.send(r);
                        }
                        Req::Draft { name, noise, resp } => {
                            let _ = resp.send(engine.exec_draft(&name, &noise));
                        }
                        Req::Preload { names, resp } => {
                            let mut r = Ok(());
                            for n in &names {
                                if let Err(e) = engine.ensure_compiled(n) {
                                    r = Err(e);
                                    break;
                                }
                            }
                            let _ = resp.send(r);
                        }
                        Req::Stats { resp } => {
                            let _ = resp.send(engine.stats());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .context("spawning engine thread")?;
        ready_rx.recv().context("engine thread died during init")??;
        Ok(EngineHandle { tx, manifest: manifest_arc, call_timeout: None })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Arm the refine watchdog: every subsequent call on this handle (and
    /// its clones) waits at most `timeout` for the engine's reply, then
    /// surfaces a typed [`EngineTimeout`]. The timed-out call's reply
    /// channel is dropped, so the wedged engine's eventual answer is
    /// discarded, never delivered stale.
    pub fn with_call_timeout(mut self, timeout: Option<Duration>) -> EngineHandle {
        self.call_timeout = timeout;
        self
    }

    /// Wait for a reply under the watchdog policy: no deadline = block
    /// until reply or disconnect (`EngineDead`); with a deadline, a slow
    /// reply becomes `EngineTimeout` and the receiver is dropped on
    /// return, orphaning the late reply.
    fn recv_guarded<T>(&self, rx: mpsc::Receiver<T>) -> Result<T> {
        match self.call_timeout {
            None => rx.recv().map_err(|_| anyhow::Error::new(EngineDead)),
            Some(timeout) => rx.recv_timeout(timeout).map_err(|e| match e {
                mpsc::RecvTimeoutError::Timeout => anyhow::Error::new(EngineTimeout { timeout }),
                mpsc::RecvTimeoutError::Disconnected => anyhow::Error::new(EngineDead),
            }),
        }
    }

    /// Eagerly compile a set of artifacts.
    pub fn preload(&self, names: &[String]) -> Result<()> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Preload { names: names.to_vec(), resp })
            .map_err(|_| anyhow::Error::new(EngineDead))?;
        rx.recv().map_err(|_| anyhow::Error::new(EngineDead))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (resp, rx) = mpsc::channel();
        self.tx.send(Req::Stats { resp }).map_err(|_| anyhow::Error::new(EngineDead))?;
        self.recv_guarded(rx)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

impl Executor for EngineHandle {
    fn step(&self, artifact: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Step { name: artifact.to_string(), tokens: tokens.to_vec(), t, h, warp, resp })
            .map_err(|_| anyhow::Error::new(EngineDead))?;
        self.recv_guarded(rx)?
    }

    /// One channel round-trip for the whole composed step (vs one per
    /// parameter-run through `step`); the engine thread pads runs onto
    /// compiled batches and strips the padding before replying.
    fn step_rows_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        seq_len: usize,
        rows: &[RowStep],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::StepRows {
                name: artifact.to_string(),
                tokens: tokens.to_vec(),
                seq_len,
                rows: rows.to_vec(),
                resp,
            })
            .map_err(|_| anyhow::Error::new(EngineDead))?;
        *out = self.recv_guarded(rx)??;
        Ok(())
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Draft { name: artifact.to_string(), noise: noise.to_vec(), resp })
            .map_err(|_| anyhow::Error::new(EngineDead))?;
        self.recv_guarded(rx)?
    }

    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == artifact)
            .cloned()
            .with_context(|| format!("unknown artifact {artifact:?}"))
    }

    /// A real engine-thread round-trip (stats request) under the watchdog
    /// — succeeds iff the thread is alive and draining its queue.
    fn probe(&self) -> Result<()> {
        self.stats().map(|_| ())
    }

    /// One channel round-trip for the entire run (vs one per step through
    /// `step`). Token storage moves to the engine thread and back, so no
    /// copy is made; the engine's persistent per-artifact scratch is used
    /// and the caller's `scratch` stays untouched.
    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        _scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        let (resp, rx) = mpsc::channel();
        let staged = std::mem::take(tokens);
        self.tx
            .send(Req::RunLoop { spec: spec.clone(), tokens: staged, resp })
            .map_err(|_| anyhow::Error::new(EngineDead))?;
        let (final_tokens, report) = self.recv_guarded(rx)??;
        *tokens = final_tokens;
        Ok(report)
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    //! Wedged-engine harness shared by the engine and fleet tests: a real
    //! [`EngineHandle`] whose serving thread parks every work request on a
    //! gate, then records whether its (late) reply ever reached a live
    //! receiver — the structural proof that a timed-out call's reply is
    //! discarded, not delivered stale.

    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex};

    /// Gate + late-reply accounting for a wedged engine.
    pub(crate) struct WedgeCtl {
        released: Mutex<bool>,
        cv: Condvar,
        late_sends: AtomicUsize,
        late_delivered: AtomicUsize,
    }

    impl WedgeCtl {
        pub(crate) fn new() -> Arc<WedgeCtl> {
            Arc::new(WedgeCtl {
                released: Mutex::new(false),
                cv: Condvar::new(),
                late_sends: AtomicUsize::new(0),
                late_delivered: AtomicUsize::new(0),
            })
        }

        /// Un-wedge: parked requests reply (late) and new ones flow.
        pub(crate) fn release(&self) {
            *self.released.lock().unwrap() = true;
            self.cv.notify_all();
        }

        fn wait(&self) {
            let mut released = self.released.lock().unwrap();
            while !*released {
                released = self.cv.wait(released).unwrap();
            }
        }

        fn record<T>(&self, sent: std::result::Result<(), mpsc::SendError<T>>) {
            self.late_sends.fetch_add(1, Ordering::SeqCst);
            if sent.is_ok() {
                self.late_delivered.fetch_add(1, Ordering::SeqCst);
            }
        }

        /// Work replies sent after the wedge released.
        pub(crate) fn late_sends(&self) -> usize {
            self.late_sends.load(Ordering::SeqCst)
        }

        /// Of those, how many found a live receiver (0 = all discarded).
        pub(crate) fn late_delivered(&self) -> usize {
            self.late_delivered.load(Ordering::SeqCst)
        }
    }

    /// Spawn a wedged engine behind a real [`EngineHandle`]: work
    /// requests (step / draft / run_loop) park on `ctl` before replying;
    /// stats/preload reply immediately (so probes still succeed).
    pub(crate) fn wedged_handle(manifest: Manifest, ctl: Arc<WedgeCtl>) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<Req>();
        std::thread::Builder::new()
            .name("wsfm-wedged-engine".into())
            .spawn(move || {
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Step { tokens, resp, .. } => {
                            ctl.wait();
                            ctl.record(resp.send(Ok(vec![0.0; tokens.len()])));
                        }
                        Req::StepRows { tokens, resp, .. } => {
                            ctl.wait();
                            ctl.record(resp.send(Ok(vec![0.0; tokens.len()])));
                        }
                        Req::RunLoop { tokens, resp, .. } => {
                            ctl.wait();
                            let report = LoopReport {
                                nfe: 0,
                                elapsed: Duration::ZERO,
                                snapshots: None,
                            };
                            ctl.record(resp.send(Ok((tokens, report))));
                        }
                        Req::Draft { resp, .. } => {
                            ctl.wait();
                            ctl.record(resp.send(Ok(Vec::new())));
                        }
                        Req::Preload { resp, .. } => {
                            let _ = resp.send(Ok(()));
                        }
                        Req::Stats { resp } => {
                            let _ = resp.send(EngineStats::default());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .expect("spawning wedged engine thread");
        EngineHandle { tx, manifest: std::sync::Arc::new(manifest), call_timeout: None }
    }
}

#[cfg(test)]
mod tests {
    // Engine tests requiring real artifacts live in rust/tests/ (they need
    // `make artifacts` to have run). Here we only check the handle's error
    // paths with an empty manifest.
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![],
            domains: crate::util::json::Json::Null,
            batch_sizes: BTreeMap::new(),
            schema_version: 1,
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        assert!(h.meta("nope").is_err());
        assert!(Executor::step(&h, "nope", &[0], 0.0, 0.1, 1.0).is_err());
        assert!(h.draft("nope", &[0.0]).is_err());
        let spec = LoopSpec::full("nope".into(), 4, 0.0, 1.0, 0, false);
        let mut tokens = vec![0i32; 4];
        let mut scratch = LoopScratch::default();
        assert!(h.run_loop(&spec, &mut tokens, &mut scratch).is_err());
        h.shutdown();
    }

    #[test]
    fn arc_executor_delegates() {
        let h = std::sync::Arc::new(EngineHandle::spawn(empty_manifest()).unwrap());
        // The Arc passes anywhere a `&dyn Executor` is expected and
        // delegates every method (here: the error paths of an empty
        // manifest).
        let as_dyn: &dyn Executor = &h;
        assert!(as_dyn.meta("nope").is_err());
        assert!(as_dyn.draft("nope", &[0.0]).is_err());
        h.shutdown();
    }

    #[test]
    fn dead_engine_surfaces_typed_engine_dead() {
        // Deliberately kill the engine thread, then hit every handle entry
        // point: each must return a typed EngineDead error (downcastable
        // through any anyhow context), never hang and never a generic
        // string-only failure. Requests are FIFO on one channel, so
        // anything sent after Shutdown observes the disconnect.
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        h.shutdown();
        let stats_err = h.stats().unwrap_err();
        assert!(stats_err.downcast_ref::<EngineDead>().is_some(), "{stats_err:#}");
        let step_err = Executor::step(&h, "a", &[0], 0.0, 0.1, 1.0).unwrap_err();
        assert!(step_err.downcast_ref::<EngineDead>().is_some(), "{step_err:#}");
        let draft_err = h.draft("a", &[0.0]).unwrap_err();
        assert!(draft_err.downcast_ref::<EngineDead>().is_some(), "{draft_err:#}");
        let preload_err = h.preload(&["a".to_string()]).unwrap_err();
        assert!(preload_err.downcast_ref::<EngineDead>().is_some(), "{preload_err:#}");
        let spec = LoopSpec::full("a".into(), 4, 0.0, 1.0, 0, false);
        let mut tokens = vec![0i32; 4];
        let mut scratch = LoopScratch::default();
        let loop_err = h.run_loop(&spec, &mut tokens, &mut scratch).unwrap_err();
        assert!(loop_err.downcast_ref::<EngineDead>().is_some(), "{loop_err:#}");
        let mut probs = Vec::new();
        let rows_err = h
            .step_rows_into("a", &[0, 0], 1, &[RowStep { t: 0.0, h: 0.5, warp: 1.0 }; 2], &mut probs)
            .unwrap_err();
        assert!(rows_err.downcast_ref::<EngineDead>().is_some(), "{rows_err:#}");
        // A live engine's ordinary failures (unknown artifact) are NOT
        // EngineDead — supervisors must be able to tell them apart.
        let live = EngineHandle::spawn(empty_manifest()).unwrap();
        let err = live.draft("nope", &[0.0]).unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_none(), "{err:#}");
        live.shutdown();
    }

    #[test]
    fn step_rows_default_impl_groups_parameter_runs_and_concatenates() {
        use crate::coordinator::testutil::TestExec;
        // Three rows at step params A, one at B: the default impl must
        // issue one step_into per maximal parameter run and return the
        // same probs as stepping each run separately.
        let exec = TestExec::stochastic(vec![1, 4, 8], 2, 5, 2);
        let a = RowStep { t: 0.5, h: 0.1, warp: 2.0 };
        let b = RowStep { t: 0.6, h: 0.1, warp: 2.0 };
        let tokens = vec![1, 2, 3, 4, 0, 1, 2, 3];
        let mut composed = Vec::new();
        exec.step_rows_into("mock_cold_step_b4", &tokens, 2, &[a, a, a, b], &mut composed)
            .unwrap();
        assert_eq!(composed.len(), 4 * 2 * 5);
        let mut run_a = Vec::new();
        exec.step_into("mock_cold_step_b4", &tokens[..6], a.t, a.h, a.warp, &mut run_a).unwrap();
        let mut run_b = Vec::new();
        exec.step_into("mock_cold_step_b4", &tokens[6..], b.t, b.h, b.warp, &mut run_b).unwrap();
        assert_eq!(&composed[..6 * 5], &run_a[..]);
        assert_eq!(&composed[6 * 5..], &run_b[..]);
        // A shape mismatch is rejected before any dispatch.
        assert!(exec.step_rows_into("mock_cold_step_b4", &tokens, 3, &[a, a], &mut run_a).is_err());
    }

    #[test]
    fn stats_roundtrip() {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.compiled, 0);
        assert_eq!(s.loop_runs, 0);
        assert!(s.summary().contains("0 compiled"));
        h.shutdown();
    }

    #[test]
    fn watchdog_times_out_wedged_engine_and_discards_late_reply() {
        // A wedged-but-alive engine must trip the typed EngineTimeout
        // within the configured deadline — and its eventual late reply
        // must find no receiver (provably discarded, never stale-served).
        let ctl = testsupport::WedgeCtl::new();
        let h = testsupport::wedged_handle(empty_manifest(), ctl.clone())
            .with_call_timeout(Some(Duration::from_millis(40)));
        let start = Instant::now();
        let err = Executor::step(&h, "a", &[0, 0], 0.0, 0.1, 1.0).unwrap_err();
        let timeout = err.downcast_ref::<EngineTimeout>().unwrap_or_else(|| {
            panic!("expected EngineTimeout, got {err:#}");
        });
        assert_eq!(timeout.timeout, Duration::from_millis(40));
        assert!(start.elapsed() < Duration::from_secs(5), "watchdog did not bound the wait");
        // Probes (stats) bypass the wedge in this harness, so supervisors
        // can still health-check the handle.
        h.probe().unwrap();
        // Un-wedge: the parked reply goes out late — to a dropped channel.
        ctl.release();
        let deadline = Instant::now() + Duration::from_secs(5);
        while ctl.late_sends() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(ctl.late_sends(), 1, "wedged engine never sent its late reply");
        assert_eq!(ctl.late_delivered(), 0, "stale late reply reached a live receiver");
        h.shutdown();
    }

    #[test]
    fn watchdog_disabled_or_generous_leaves_behaviour_unchanged() {
        // No deadline = the legacy blocking wait; a generous deadline
        // passes healthy calls through and keeps ordinary errors typed as
        // themselves (not EngineTimeout / EngineDead).
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        assert!(h.stats().is_ok());
        let h = h.with_call_timeout(Some(Duration::from_secs(30)));
        h.probe().unwrap();
        let err = h.draft("nope", &[0.0]).unwrap_err();
        assert!(err.downcast_ref::<EngineTimeout>().is_none(), "{err:#}");
        assert!(err.downcast_ref::<EngineDead>().is_none(), "{err:#}");
        // Under the watchdog a *dead* engine still surfaces EngineDead —
        // disconnect is observed before the deadline, never conflated
        // with a timeout.
        h.shutdown();
        let err = h.stats().unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_some(), "{err:#}");
    }
}
