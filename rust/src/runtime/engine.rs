//! The PJRT execution engine.
//!
//! `xla::PjRtClient` is `Rc`-based and not `Send`, so all PJRT work runs on
//! one dedicated **engine thread** (the machine has one accelerator — the
//! CPU plugin — so a single execution stream is also the right throughput
//! model). The rest of the stack talks to it through [`EngineHandle`], a
//! cloneable, `Send + Sync` channel front-end implementing [`Executor`].
//!
//! Artifacts are compiled lazily on first use and cached for the process
//! lifetime; `preload` warms them eagerly at startup.

use crate::runtime::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

/// Executable kinds the engine knows how to drive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutableKind {
    /// `(x_t i32[B,N], t f32[], h f32[], warp f32[]) -> (probs f32[B,N,V],)`
    Step,
    /// `(noise f32[...]) -> (tokens i32[B,N],)`
    Draft,
}

/// Abstract executor — the seam between the coordinator/sampler and PJRT.
/// Tests substitute a mock; production uses [`EngineHandle`].
pub trait Executor: Send + Sync {
    /// Run a fused denoise+update step artifact.
    fn step(&self, artifact: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>>;
    /// Run a draft sampler artifact with externally-generated noise.
    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>>;
    /// Metadata lookup.
    fn meta(&self, artifact: &str) -> Result<ArtifactMeta>;
}

/// Marker alias used in public re-exports.
pub type StepFn = dyn Executor;

// ---------------------------------------------------------------------------
// Engine thread internals
// ---------------------------------------------------------------------------

enum Req {
    Step { name: String, tokens: Vec<i32>, t: f32, h: f32, warp: f32, resp: mpsc::Sender<Result<Vec<f32>>> },
    Draft { name: String, noise: Vec<f32>, resp: mpsc::Sender<Result<Vec<i32>>> },
    Preload { names: Vec<String>, resp: mpsc::Sender<Result<()>> },
    Stats { resp: mpsc::Sender<EngineStats> },
    Shutdown,
}

/// Compile/exec statistics (surfaced in `wsfm info` and §Perf).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub compiled: usize,
    pub executions: u64,
    pub compile_ms_total: u64,
    pub exec_ms_total: u64,
}

/// The engine proper (lives on the engine thread; `!Send` by content).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: EngineStats,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, manifest, cache: HashMap::new(), stats: EngineStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    /// Compile (and cache) an artifact by name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?;
        let path = self.manifest.hlo_path(&meta);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.stats.compile_ms_total += start.elapsed().as_millis() as u64;
        self.stats.compiled += 1;
        crate::info!("compiled {name} in {:?}", start.elapsed());
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a step artifact.
    pub fn exec_step(&mut self, name: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        let meta = self.meta(name)?;
        if meta.kind != "step" {
            bail!("artifact {name} is not a step (kind={})", meta.kind);
        }
        let (b, n, v) = (meta.batch, meta.seq_len, meta.vocab);
        if tokens.len() != b * n {
            bail!("step {name}: tokens len {} != {}x{}", tokens.len(), b, n);
        }
        self.ensure_compiled(name)?;
        let start = Instant::now();
        let x = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, n as i64])
            .map_err(|e| anyhow!("reshape x_t: {e:?}"))?;
        let args =
            [x, xla::Literal::scalar(t), xla::Literal::scalar(h), xla::Literal::scalar(warp)];
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute(&args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let probs = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if probs.len() != b * n * v {
            bail!("step {name}: output len {} != {}", probs.len(), b * n * v);
        }
        self.stats.executions += 1;
        self.stats.exec_ms_total += start.elapsed().as_millis() as u64;
        Ok(probs)
    }

    /// Execute a draft artifact.
    pub fn exec_draft(&mut self, name: &str, noise: &[f32]) -> Result<Vec<i32>> {
        let meta = self.meta(name)?;
        if meta.kind != "draft" {
            bail!("artifact {name} is not a draft (kind={})", meta.kind);
        }
        let in_spec = meta.inputs.first().context("draft missing input spec")?;
        if noise.len() != in_spec.numel() {
            bail!("draft {name}: noise len {} != {}", noise.len(), in_spec.numel());
        }
        self.ensure_compiled(name)?;
        let start = Instant::now();
        let dims: Vec<i64> = in_spec.shape.iter().map(|&d| d as i64).collect();
        let z = xla::Literal::vec1(noise).reshape(&dims).map_err(|e| anyhow!("reshape noise: {e:?}"))?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute(&[z]).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let tokens = out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if tokens.len() != meta.batch * meta.seq_len {
            bail!("draft {name}: output len {} != {}", tokens.len(), meta.batch * meta.seq_len);
        }
        self.stats.executions += 1;
        self.stats.exec_ms_total += start.elapsed().as_millis() as u64;
        Ok(tokens)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.clone()
    }
}

// ---------------------------------------------------------------------------
// Thread + handle
// ---------------------------------------------------------------------------

/// Cloneable, thread-safe front-end to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Req>,
    manifest: std::sync::Arc<Manifest>,
}

impl EngineHandle {
    /// Spawn the engine thread over a loaded manifest.
    pub fn spawn(manifest: Manifest) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Req>();
        let manifest_arc = std::sync::Arc::new(manifest.clone());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("wsfm-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(manifest) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Step { name, tokens, t, h, warp, resp } => {
                            let _ = resp.send(engine.exec_step(&name, &tokens, t, h, warp));
                        }
                        Req::Draft { name, noise, resp } => {
                            let _ = resp.send(engine.exec_draft(&name, &noise));
                        }
                        Req::Preload { names, resp } => {
                            let mut r = Ok(());
                            for n in &names {
                                if let Err(e) = engine.ensure_compiled(n) {
                                    r = Err(e);
                                    break;
                                }
                            }
                            let _ = resp.send(r);
                        }
                        Req::Stats { resp } => {
                            let _ = resp.send(engine.stats());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .context("spawning engine thread")?;
        ready_rx.recv().context("engine thread died during init")??;
        Ok(EngineHandle { tx, manifest: manifest_arc })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Eagerly compile a set of artifacts.
    pub fn preload(&self, names: &[String]) -> Result<()> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Preload { names: names.to_vec(), resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (resp, rx) = mpsc::channel();
        self.tx.send(Req::Stats { resp }).map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

impl Executor for EngineHandle {
    fn step(&self, artifact: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> Result<Vec<f32>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Step { name: artifact.to_string(), tokens: tokens.to_vec(), t, h, warp, resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Req::Draft { name: artifact.to_string(), noise: noise.to_vec(), resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread gone"))?
    }

    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == artifact)
            .cloned()
            .with_context(|| format!("unknown artifact {artifact:?}"))
    }
}

#[cfg(test)]
mod tests {
    // Engine tests requiring real artifacts live in rust/tests/runtime.rs
    // (they need `make artifacts` to have run). Here we only check the
    // handle's error paths with an empty manifest.
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![],
            domains: crate::util::json::Json::Null,
            batch_sizes: BTreeMap::new(),
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        assert!(h.meta("nope").is_err());
        assert!(Executor::step(&h, "nope", &[0], 0.0, 0.1, 1.0).is_err());
        assert!(h.draft("nope", &[0.0]).is_err());
        h.shutdown();
    }

    #[test]
    fn stats_roundtrip() {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        let s = h.stats().unwrap();
        assert_eq!(s.compiled, 0);
        h.shutdown();
    }
}
