//! DFM / WS-DFM sampling (paper Fig. 3).
//!
//! [`dfm`] implements the Euler CTMC integration loop over the fused
//! denoise+update artifacts; cold DFM is the `t0 = 0` special case of the
//! warm sampler, so there is one loop with two entry points. The loop body
//! itself is engine-resident ([`crate::runtime::engine`]): `sample_warm`
//! resolves a `LoopSpec` and ships it through `Executor::run_loop` in one
//! round-trip, while [`dfm::sample_warm_stepwise`] keeps the legacy
//! one-call-per-step path as the bit-exact reference. [`trace`] captures
//! per-step snapshots for the paper's Fig. 5/7/9 progress figures.

pub mod dfm;
pub mod trace;

pub use dfm::{
    sample_cold, sample_warm, sample_warm_stepwise, sample_warm_with_scratch, SampleOutput,
    SamplerParams,
};
pub use trace::Trace;
