//! DFM / WS-DFM sampling (paper Fig. 3).
//!
//! [`dfm`] implements the Euler CTMC integration loop over the fused
//! denoise+update artifacts; cold DFM is the `t0 = 0` special case of the
//! warm sampler, so there is one loop with two entry points. [`trace`]
//! captures per-step snapshots for the paper's Fig. 5/7/9 progress figures.

pub mod dfm;
pub mod trace;

pub use dfm::{sample_cold, sample_warm, SampleOutput, SamplerParams};
pub use trace::Trace;
