//! The Euler CTMC sampling loop (paper Fig. 3, both columns).
//!
//! Cold DFM (left column):
//! ```text
//! t = 0; x ~ uniform noise
//! while t < 1: probs = step(x, t, h, warp=1); x ~ Cat(probs); t += h
//! ```
//! WS-DFM (right column): start at `t0` from draft samples and (in the
//! paper's literal rule) scale the velocity by `1 - t0`:
//! ```text
//! t = t0; x ~ draft model
//! while t < 1: probs = step(x, t, h, warp=1-t0); x ~ Cat(probs); t += h
//! ```
//! The softmax→velocity→Euler-transition math is *inside* the AOT artifact
//! (the fused Pallas `dfm_update` kernel); the loop owns time stepping,
//! categorical sampling, RNG, and NFE accounting. The NFE is guaranteed by
//! construction: the loop runs exactly `Schedule::nfe()` iterations.
//!
//! Since the engine-resident refactor, [`sample_warm`] ships the whole
//! loop through [`Executor::run_loop`] — for [`EngineHandle`] that is one
//! channel round-trip per run instead of one per step, with scratch
//! buffers reused across steps (see `runtime::engine`). The RNG contract:
//! one `next_u64` is drawn from the caller's `rng` as the *run seed*, and
//! every `(step, row)` categorical draw derives a stateless substream from
//! it, so tokens are bitwise-identical whether the loop runs in-process,
//! on the engine thread, or row-parallel ([`sample_warm_stepwise`] pins
//! this parity in tests).
//!
//! [`EngineHandle`]: crate::runtime::EngineHandle

use crate::core::prob;
use crate::core::rng::Pcg64;
use crate::core::schedule::{Schedule, WarpMode};
use crate::core::tensor::TokenBatch;
use crate::runtime::engine::{Executor, LoopScratch, LoopSpec};
use crate::sampler::trace::Trace;
use anyhow::{bail, Result};
use std::time::Instant;

/// Everything a sampling run needs besides the initial state.
#[derive(Debug, Clone)]
pub struct SamplerParams {
    /// Step artifact name (fixed batch shape).
    pub artifact: String,
    /// Cold-run step count (grid resolution; e.g. 20 for two-moons).
    pub steps_cold: usize,
    /// Warm-start time (0.0 = cold DFM).
    pub t0: f64,
    /// Update-rule variant.
    pub warp_mode: WarpMode,
}

impl SamplerParams {
    /// Resolve into a full-run engine [`LoopSpec`], drawing the run seed.
    /// Traced runs carry the process trace policy
    /// ([`crate::sampler::trace::policy_from_env`]) so long trajectories
    /// can be bounded at the engine-side collection site.
    fn loop_spec(&self, rng: &mut Pcg64, want_trace: bool) -> LoopSpec {
        let mut spec = LoopSpec::full(
            self.artifact.clone(),
            self.steps_cold,
            self.t0,
            self.warp_mode.warp_factor(self.t0) as f32,
            rng.next_u64(),
            want_trace,
        );
        if want_trace {
            let (stride, cap) = crate::sampler::trace::policy_from_env();
            spec.trace_stride = stride;
            spec.trace_cap = cap;
        }
        spec
    }
}

/// Result of one batched sampling run.
#[derive(Debug, Clone)]
pub struct SampleOutput {
    pub tokens: TokenBatch,
    /// Number of denoiser evaluations actually performed.
    pub nfe: usize,
    /// Wall-clock of the refinement loop.
    pub elapsed: std::time::Duration,
    /// Optional per-step snapshots (for Fig. 5/7 dumps).
    pub trace: Option<Trace>,
}

/// Validate a warm-start init batch against an artifact's compiled shape
/// (shared with the cascade path in the coordinator scheduler).
pub(crate) fn check_shape(
    meta_batch: usize,
    meta_seq: usize,
    artifact: &str,
    init: &TokenBatch,
) -> Result<()> {
    if meta_batch != init.batch || meta_seq != init.seq_len {
        bail!(
            "init shape [{}, {}] != artifact {} shape [{}, {}]",
            init.batch,
            init.seq_len,
            artifact,
            meta_batch,
            meta_seq
        );
    }
    Ok(())
}

/// Run the warm-start sampling loop from `init` (draft samples at `t0`).
///
/// `init` must match the artifact's compiled `[B, N]` shape. The returned
/// NFE equals `schedule::guaranteed_nfe(steps_cold, t0)` — the paper's
/// guarantee, pinned by tests.
pub fn sample_warm(
    exec: &dyn Executor,
    params: &SamplerParams,
    init: TokenBatch,
    rng: &mut Pcg64,
    want_trace: bool,
) -> Result<SampleOutput> {
    let mut scratch = LoopScratch::default();
    sample_warm_with_scratch(exec, params, init, rng, want_trace, &mut scratch)
}

/// [`sample_warm`] with caller-owned scratch, for callers that run many
/// bundles (the coordinator scheduler) and want the probs staging buffer
/// reused across runs on mock/in-process executors. For [`EngineHandle`]
/// the scratch is unused — the engine thread keeps its own, persistent
/// per artifact.
///
/// [`EngineHandle`]: crate::runtime::EngineHandle
pub fn sample_warm_with_scratch(
    exec: &dyn Executor,
    params: &SamplerParams,
    init: TokenBatch,
    rng: &mut Pcg64,
    want_trace: bool,
    scratch: &mut LoopScratch,
) -> Result<SampleOutput> {
    let meta = exec.meta(&params.artifact)?;
    check_shape(meta.batch, meta.seq_len, &params.artifact, &init)?;
    let spec = params.loop_spec(rng, want_trace);

    let mut x = init;
    let report = exec.run_loop(&spec, &mut x.tokens, scratch)?;
    // The engine-side collector already is a policy-bounded Trace — no
    // rebuild (and no second full-trajectory copy) on the way out.
    let trace = report.snapshots;
    Ok(SampleOutput { nfe: report.nfe, elapsed: report.elapsed, tokens: x, trace })
}

/// The legacy per-step loop: one executor call (and, for [`EngineHandle`],
/// one channel round-trip) per Euler step. Kept as the reference
/// implementation the engine-resident path must match bit-for-bit
/// (seed-parity pinned by tests) and as the baseline for the loop
/// round-trip benchmarks in `benches/hotpath.rs`.
///
/// [`EngineHandle`]: crate::runtime::EngineHandle
pub fn sample_warm_stepwise(
    exec: &dyn Executor,
    params: &SamplerParams,
    init: TokenBatch,
    rng: &mut Pcg64,
    want_trace: bool,
) -> Result<SampleOutput> {
    let meta = exec.meta(&params.artifact)?;
    check_shape(meta.batch, meta.seq_len, &params.artifact, &init)?;
    let schedule = Schedule::new(params.steps_cold, params.t0)?;
    let warp = params.warp_mode.warp_factor(params.t0) as f32;
    let vocab = meta.vocab;
    let run_seed = rng.next_u64(); // same derivation as sample_warm

    let start = Instant::now();
    let mut x = init;
    let mut trace = want_trace.then(|| {
        // Same policy as the engine-resident path, so traces stay
        // identical between the two (the parity tests pin this).
        let (stride, cap) = crate::sampler::trace::policy_from_env();
        let mut tr = Trace::with_policy(stride, cap);
        tr.push(schedule.t0, &x);
        tr
    });

    let mut probs: Vec<f32> = Vec::new();
    for i in 0..schedule.nfe() {
        let t = schedule.times[i] as f32;
        let h = schedule.step_size(i) as f32;
        exec.step_into(&params.artifact, &x.tokens, t, h, warp, &mut probs)?;
        if probs.len() != x.batch * x.seq_len * vocab {
            bail!(
                "artifact {} returned {} probs, want {}",
                params.artifact,
                probs.len(),
                x.batch * x.seq_len * vocab
            );
        }
        prob::categorical_batch_seeded(&probs, vocab, &mut x.tokens, run_seed, i as u64);
        if let Some(tr) = trace.as_mut() {
            tr.push(schedule.times[i] + schedule.step_size(i), &x);
        }
    }

    Ok(SampleOutput { nfe: schedule.nfe(), elapsed: start.elapsed(), tokens: x, trace })
}

/// Cold DFM: uniform-noise init at `t = 0` (paper Fig. 3 left).
pub fn sample_cold(
    exec: &dyn Executor,
    artifact: &str,
    steps: usize,
    rng: &mut Pcg64,
    want_trace: bool,
) -> Result<SampleOutput> {
    let meta = exec.meta(artifact)?;
    let mut init = TokenBatch::zeros(meta.batch, meta.seq_len);
    for tok in init.tokens.iter_mut() {
        *tok = rng.below(meta.vocab as u32) as i32;
    }
    let params = SamplerParams {
        artifact: artifact.to_string(),
        steps_cold: steps,
        t0: 0.0,
        warp_mode: WarpMode::Exact, // warp factor is 1 either way at t0=0
    };
    sample_warm(exec, &params, init, rng, want_trace)
}

#[cfg(test)]
pub(crate) mod testutil {
    //! A mock executor implementing an *analytic* DFM over a tiny vocab:
    //! the "denoiser" always predicts a fixed target distribution `p1`.
    //! This lets sampler tests verify transport behaviour without
    //! artifacts. It implements `step_into` (not `step`) so the mock hot
    //! path is allocation-free in steady state, like the engine's.
    use super::*;
    use crate::runtime::artifact::{ArtifactMeta, TensorSpec};
    use std::sync::atomic::{AtomicUsize, Ordering};

    pub struct MockStep {
        pub batch: usize,
        pub seq_len: usize,
        pub vocab: usize,
        /// Fixed target distribution over the vocab.
        pub p1: Vec<f32>,
        pub calls: AtomicUsize,
    }

    impl MockStep {
        pub fn new(batch: usize, seq_len: usize, p1: Vec<f32>) -> Self {
            MockStep { batch, seq_len, vocab: p1.len(), p1, calls: AtomicUsize::new(0) }
        }
    }

    impl Executor for MockStep {
        fn step_into(
            &self,
            _a: &str,
            tokens: &[i32],
            t: f32,
            h: f32,
            warp: f32,
            out: &mut Vec<f32>,
        ) -> Result<()> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            let v = self.vocab;
            out.clear();
            out.reserve(tokens.len() * v);
            let coef = (h * warp / (1.0 - t).max(1e-6)).min(1.0);
            for &tok in tokens {
                for j in 0..v {
                    let delta = if j as i32 == tok { 1.0 } else { 0.0 };
                    out.push((delta + coef * (self.p1[j] - delta)).max(0.0));
                }
            }
            Ok(())
        }

        fn draft(&self, _a: &str, _noise: &[f32]) -> Result<Vec<i32>> {
            Ok(vec![0; self.batch * self.seq_len])
        }

        fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
            Ok(ArtifactMeta {
                name: artifact.to_string(),
                hlo_file: String::new(),
                domain: "mock".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: self.batch,
                seq_len: self.seq_len,
                vocab: self.vocab,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![TensorSpec {
                    name: "x_t".into(),
                    shape: vec![self.batch, self.seq_len],
                    dtype: "s32".into(),
                }],
                outputs: vec![TensorSpec {
                    name: "probs".into(),
                    shape: vec![self.batch, self.seq_len, self.vocab],
                    dtype: "f32".into(),
                }],
                content_hash: None,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::MockStep;
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn cold_nfe_equals_steps() {
        let mock = MockStep::new(4, 3, vec![0.7, 0.2, 0.1]);
        let mut rng = Pcg64::new(0);
        let out = sample_cold(&mock, "m", 20, &mut rng, false).unwrap();
        assert_eq!(out.nfe, 20);
        assert_eq!(mock.calls.load(Ordering::SeqCst), 20);
        assert_eq!(out.tokens.batch, 4);
    }

    #[test]
    fn warm_nfe_guarantee() {
        // The headline: t0=0.8 with 20 cold steps -> exactly 4 calls.
        let mock = MockStep::new(2, 2, vec![0.5, 0.5]);
        let params = SamplerParams {
            artifact: "m".into(),
            steps_cold: 20,
            t0: 0.8,
            warp_mode: WarpMode::Literal,
        };
        let mut rng = Pcg64::new(1);
        let init = TokenBatch::zeros(2, 2);
        let out = sample_warm(&mock, &params, init, &mut rng, false).unwrap();
        assert_eq!(out.nfe, 4);
        assert_eq!(mock.calls.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn transports_to_target_distribution() {
        // With the analytic denoiser, final tokens must follow p1.
        let p1 = vec![0.6f32, 0.3, 0.1];
        let mock = MockStep::new(64, 16, p1.clone());
        let mut rng = Pcg64::new(2);
        let out = sample_cold(&mock, "m", 50, &mut rng, false).unwrap();
        let mut counts = [0usize; 3];
        for &t in &out.tokens.tokens {
            counts[t as usize] += 1;
        }
        let n = out.tokens.tokens.len() as f64;
        for (i, &target) in p1.iter().enumerate() {
            let f = counts[i] as f64 / n;
            assert!((f - target as f64).abs() < 0.06, "token {i}: {f} vs {target}");
        }
    }

    #[test]
    fn warm_transport_also_reaches_target() {
        let p1 = vec![0.1f32, 0.1, 0.8];
        let mock = MockStep::new(64, 8, p1.clone());
        let params = SamplerParams {
            artifact: "m".into(),
            steps_cold: 40,
            t0: 0.5,
            warp_mode: WarpMode::Exact,
        };
        // Drafts: all token 0 (far from target).
        let init = TokenBatch::zeros(64, 8);
        let mut rng = Pcg64::new(3);
        let out = sample_warm(&mock, &params, init, &mut rng, false).unwrap();
        let frac2 = out.tokens.tokens.iter().filter(|&&t| t == 2).count() as f64
            / out.tokens.tokens.len() as f64;
        assert!((frac2 - 0.8).abs() < 0.08, "{frac2}");
        assert_eq!(out.nfe, 20);
    }

    #[test]
    fn exact_rule_lands_on_p1_but_literal_undershoots() {
        // The exact rule's final step has coef = h/(1-t) = 1, committing
        // fully to p1. The paper's literal Fig. 3 rule scales velocity by
        // (1-t0) and therefore only moves a (1-t0) fraction of the
        // remaining mass even on the last step — WS-DFM outputs stay close
        // to the draft (visible in the paper's Fig. 14, where WS samples
        // are light edits of the LSTM text). Pin both behaviours; the
        // trade-off is ablated in benches/hotpath.rs.
        let p1 = vec![0.0f32, 1.0];
        let run = |warp_mode| {
            let mock = MockStep::new(64, 4, p1.clone());
            let params = SamplerParams {
                artifact: "m".into(),
                steps_cold: 20,
                t0: 0.8,
                warp_mode,
            };
            let init = TokenBatch::zeros(64, 4);
            let mut rng = Pcg64::new(4);
            let out = sample_warm(&mock, &params, init, &mut rng, false).unwrap();
            out.tokens.tokens.iter().filter(|&&t| t == 1).count() as f64
                / out.tokens.tokens.len() as f64
        };
        assert_eq!(run(WarpMode::Exact), 1.0, "exact rule must fully commit at t=1");
        let lit = run(WarpMode::Literal);
        // Analytic switch probability: 1 - prod(1 - coef_i) ≈ 0.36.
        assert!(lit > 0.2 && lit < 0.55, "literal-rule switch fraction {lit}");
    }

    #[test]
    fn trace_records_steps() {
        let mock = MockStep::new(2, 2, vec![0.5, 0.5]);
        let mut rng = Pcg64::new(5);
        let out = sample_cold(&mock, "m", 10, &mut rng, true).unwrap();
        let tr = out.trace.unwrap();
        assert_eq!(tr.len(), 11); // init + one per step
        assert!((tr.times[0] - 0.0).abs() < 1e-9);
        assert!((tr.times[10] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mock = MockStep::new(2, 2, vec![0.5, 0.5]);
        let params = SamplerParams {
            artifact: "m".into(),
            steps_cold: 10,
            t0: 0.5,
            warp_mode: WarpMode::Exact,
        };
        let init = TokenBatch::zeros(3, 2); // wrong batch
        let mut rng = Pcg64::new(6);
        assert!(sample_warm(&mock, &params, init, &mut rng, false).is_err());
        let init = TokenBatch::zeros(3, 2);
        let mut rng = Pcg64::new(6);
        assert!(sample_warm_stepwise(&mock, &params, init, &mut rng, false).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let mock = MockStep::new(4, 4, vec![0.3, 0.3, 0.4]);
        let run = |seed| {
            let mut rng = Pcg64::new(seed);
            sample_cold(&mock, "m", 15, &mut rng, false).unwrap().tokens.tokens
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn engine_resident_loop_matches_stepwise_reference() {
        // The seed-parity contract: the run_loop path (engine-resident /
        // default drive_loop, row-parallel sampling) and the legacy
        // per-step loop produce bitwise-identical tokens for the same
        // seed — warm and cold, with and without trace.
        for (t0, steps, warp_mode) in
            [(0.0, 24, WarpMode::Exact), (0.8, 20, WarpMode::Literal), (0.5, 40, WarpMode::Exact)]
        {
            let params = SamplerParams {
                artifact: "m".into(),
                steps_cold: steps,
                t0,
                warp_mode,
            };
            let mock_a = MockStep::new(8, 16, vec![0.2, 0.5, 0.3]);
            let mock_b = MockStep::new(8, 16, vec![0.2, 0.5, 0.3]);
            let mut rng_a = Pcg64::new(99);
            let mut rng_b = Pcg64::new(99);
            let init_a = TokenBatch::zeros(8, 16);
            let init_b = TokenBatch::zeros(8, 16);
            let a = sample_warm(&mock_a, &params, init_a, &mut rng_a, true).unwrap();
            let b = sample_warm_stepwise(&mock_b, &params, init_b, &mut rng_b, true).unwrap();
            assert_eq!(a.tokens, b.tokens, "t0={t0}");
            assert_eq!(a.nfe, b.nfe);
            // Entire trajectories match, not just the endpoint.
            let (ta, tb) = (a.trace.unwrap(), b.trace.unwrap());
            assert_eq!(ta.times, tb.times);
            assert_eq!(ta.states, tb.states);
            // And the caller RNGs were advanced identically.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        }
    }

    #[test]
    fn scratch_buffers_do_not_grow_across_steps_or_runs() {
        // The zero-allocation steady-state contract: the probs scratch
        // reaches B*N*V capacity once and never grows, no matter how many
        // steps run; the token buffer is resampled in place.
        use crate::runtime::engine::LoopSpec;
        let mock = MockStep::new(4, 8, vec![0.25, 0.25, 0.5]);
        let mut scratch = LoopScratch::default();
        let spec = |steps: usize, t0: f64| LoopSpec::full("m".into(), steps, t0, 1.0, 42, false);
        let mut tokens = vec![0i32; 4 * 8];
        let tokens_cap = tokens.capacity();
        mock.run_loop(&spec(2, 0.0), &mut tokens, &mut scratch).unwrap();
        let cap_after_short = scratch.probs.capacity();
        assert!(cap_after_short >= 4 * 8 * 3);
        // Varying step counts AND varying t0 (the adaptive controller's
        // per-bundle choices change Schedule::nfe() bundle to bundle):
        // the scratch must tolerate every mix without reallocating.
        for (steps, t0) in [(200usize, 0.0), (64, 0.0), (64, 0.9), (20, 0.35), (200, 0.95)] {
            mock.run_loop(&spec(steps, t0), &mut tokens, &mut scratch).unwrap();
            assert_eq!(
                scratch.probs.capacity(),
                cap_after_short,
                "probs scratch must not grow in steady state (steps={steps} t0={t0})"
            );
            assert_eq!(tokens.capacity(), tokens_cap, "token buffer must be resampled in place");
        }
        assert_eq!(tokens.len(), 4 * 8);
    }

    #[test]
    fn segmented_run_loop_matches_unsplit_bitwise() {
        // The cascade-resume contract at the loop level: running a warm
        // run as k consecutive segments — feeding each segment's tokens
        // into the next — produces exactly the unsplit run's tokens, for
        // any partition, because substreams key on the absolute step.
        use crate::runtime::engine::LoopSpec;
        let partitions: [&[f64]; 4] = [
            &[],                // single segment == unsplit by definition
            &[0.75],            // two segments
            &[0.6, 0.75, 0.9],  // four segments
            &[0.55, 0.56, 0.9], // includes an empty (0-step) window
        ];
        for (t0, steps) in [(0.5, 20), (0.0, 16), (0.8, 20)] {
            let mock = MockStep::new(8, 16, vec![0.2, 0.5, 0.3]);
            let full = LoopSpec::full("m".into(), steps, t0, 1.0, 77, false);
            let mut unsplit = vec![0i32; 8 * 16];
            let mut scratch = LoopScratch::default();
            let full_report = mock.run_loop(&full, &mut unsplit, &mut scratch).unwrap();

            for cuts in partitions {
                let mock2 = MockStep::new(8, 16, vec![0.2, 0.5, 0.3]);
                let mut tokens = vec![0i32; 8 * 16];
                let mut scratch2 = LoopScratch::default();
                let mut bounds: Vec<f64> = cuts.iter().copied().filter(|&c| c > t0).collect();
                bounds.push(1.0);
                let mut prev = t0;
                let mut total_nfe = 0;
                for &b in &bounds {
                    let mut seg = full.clone();
                    seg.t_start = prev;
                    seg.t_end = b;
                    total_nfe +=
                        mock2.run_loop(&seg, &mut tokens, &mut scratch2).unwrap().nfe;
                    prev = b;
                }
                assert_eq!(tokens, unsplit, "t0={t0} steps={steps} cuts={cuts:?}");
                assert_eq!(total_nfe, full_report.nfe, "NFE must tile exactly");
            }
        }
    }

    #[test]
    fn step_and_step_into_defaults_agree() {
        // MockStep implements step_into; the default step wrapper must
        // return the same probs.
        let mock = MockStep::new(2, 2, vec![0.5, 0.5]);
        let tokens = vec![0i32, 1, 1, 0];
        let direct = mock.step("m", &tokens, 0.25, 0.05, 1.0).unwrap();
        let mut buf = vec![9.0f32; 128]; // dirty, over-sized buffer
        mock.step_into("m", &tokens, 0.25, 0.05, 1.0, &mut buf).unwrap();
        assert_eq!(direct, buf);
    }
}
