//! Per-step generation snapshots (paper Fig. 5 / Fig. 7 / Fig. 9).
//!
//! The trace stores the full token state after every Euler step so the
//! figure harnesses can dump "progress strips": the draft on the left,
//! refinement steps in between, the final sample on the right.

use crate::core::tensor::TokenBatch;
use std::io::Write;
use std::path::Path;

/// A recorded trajectory of token states.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub times: Vec<f64>,
    pub states: Vec<TokenBatch>,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    pub fn push(&mut self, t: f64, state: &TokenBatch) {
        self.times.push(t);
        self.states.push(state.clone());
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Pick roughly `k` evenly spaced snapshot indices (always includes the
    /// first and last) — the paper shows "every other" step in Fig. 5.
    pub fn snapshot_indices(&self, k: usize) -> Vec<usize> {
        let n = self.len();
        if n == 0 {
            return vec![];
        }
        if k >= n || k < 2 {
            return (0..n).collect();
        }
        let mut idx: Vec<usize> =
            (0..k).map(|i| (i as f64 * (n - 1) as f64 / (k - 1) as f64).round() as usize).collect();
        idx.dedup();
        idx
    }

    /// Dump a CSV of point states (for the two-moons Fig. 5 panels):
    /// columns `time,row,x,y`.
    pub fn write_points_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "time,row,x,y")?;
        for (ti, state) in self.times.iter().zip(&self.states) {
            for r in 0..state.batch {
                let row = state.row(r);
                writeln!(f, "{ti},{r},{},{}", row[0], row[1])?;
            }
        }
        Ok(())
    }

    /// Dump one row's trajectory as a sequence of token vectors (for image
    /// progress strips): returns (time, tokens) pairs at `k` snapshots.
    pub fn row_snapshots(&self, row: usize, k: usize) -> Vec<(f64, Vec<i32>)> {
        self.snapshot_indices(k)
            .into_iter()
            .map(|i| (self.times[i], self.states[i].row(row).to_vec()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_trace(steps: usize) -> Trace {
        let mut tr = Trace::new();
        for i in 0..=steps {
            let mut tb = TokenBatch::zeros(2, 2);
            tb.tokens = vec![i as i32; 4];
            tr.push(i as f64 / steps as f64, &tb);
        }
        tr
    }

    #[test]
    fn push_and_len() {
        let tr = toy_trace(10);
        assert_eq!(tr.len(), 11);
        assert!(!tr.is_empty());
    }

    #[test]
    fn snapshot_indices_include_ends() {
        let tr = toy_trace(20);
        let idx = tr.snapshot_indices(5);
        assert_eq!(*idx.first().unwrap(), 0);
        assert_eq!(*idx.last().unwrap(), 20);
        assert!(idx.len() <= 5);
        // Small traces return everything.
        assert_eq!(toy_trace(2).snapshot_indices(10), vec![0, 1, 2]);
    }

    #[test]
    fn row_snapshots_track_rows() {
        let tr = toy_trace(4);
        let snaps = tr.row_snapshots(1, 3);
        assert_eq!(snaps.len(), 3);
        assert_eq!(snaps[0].1, vec![0, 0]);
        assert_eq!(snaps[2].1, vec![4, 4]);
    }

    #[test]
    fn points_csv_dump() {
        let tr = toy_trace(2);
        let p = std::env::temp_dir().join(format!("wsfm_trace_{}.csv", std::process::id()));
        tr.write_points_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("time,row,x,y"));
        assert_eq!(text.lines().count(), 1 + 3 * 2); // header + 3 times x 2 rows
        std::fs::remove_file(&p).unwrap();
    }
}
