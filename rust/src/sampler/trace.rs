//! Per-step generation snapshots (paper Fig. 5 / Fig. 7 / Fig. 9).
//!
//! The trace stores the full token state after every Euler step so the
//! figure harnesses can dump "progress strips": the draft on the left,
//! refinement steps in between, the final sample on the right.
//!
//! Memory is boundable: [`Trace::with_policy`] records only every
//! `stride`-th offered snapshot and, once `cap` retained snapshots are
//! reached, halves the resolution in place (dropping every other kept
//! entry and doubling the stride) — so arbitrarily long cascade runs
//! hold at most `cap + 1` states while the **first and last offered
//! snapshots stay exact** (the latest non-stride state rides along as a
//! provisional tail, replaced on the next push). The default policy
//! (`stride = 1`, `cap = 0` = unbounded) is the legacy record-everything
//! behaviour.

use crate::core::tensor::TokenBatch;
use std::io::Write;
use std::path::Path;

/// The process-wide default trace policy, read from the
/// `WSFM_TRACE_STRIDE` / `WSFM_TRACE_CAP` environment knobs (defaults
/// `1` / `0` = record everything, the legacy behaviour). Applied by the
/// sampler whenever a run requests a trace, so long traced runs (figure
/// dumps over thousands of steps, cascade trajectories) can be bounded
/// without touching call sites — recording policy never changes the
/// sampled tokens.
pub fn policy_from_env() -> (usize, usize) {
    let get =
        |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    (get("WSFM_TRACE_STRIDE", 1).max(1), get("WSFM_TRACE_CAP", 0))
}

/// A recorded trajectory of token states.
#[derive(Debug, Clone)]
pub struct Trace {
    pub times: Vec<f64>,
    pub states: Vec<TokenBatch>,
    /// Record every `stride`-th offered snapshot (>= 1).
    stride: usize,
    /// Retained-snapshot bound (0 = unbounded).
    cap: usize,
    /// Total snapshots offered via [`Trace::push`].
    offered: usize,
    /// Whether the current tail is a provisional (off-stride) last
    /// snapshot, kept so the final state is always exact.
    tail_provisional: bool,
}

impl Default for Trace {
    fn default() -> Self {
        Trace::with_policy(1, 0)
    }
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    /// A bounded trace: keep every `stride`-th snapshot, at most `cap`
    /// of them (0 = unbounded; bounded caps are floored at 2 so first
    /// and last always fit). The last offered snapshot is always
    /// retained exactly, whatever the policy.
    pub fn with_policy(stride: usize, cap: usize) -> Self {
        Trace {
            times: Vec::new(),
            states: Vec::new(),
            stride: stride.max(1),
            cap: if cap == 0 { 0 } else { cap.max(2) },
            offered: 0,
            tail_provisional: false,
        }
    }

    /// Total snapshots offered (recorded or not) — the unsplit step
    /// count plus one for the initial state.
    pub fn offered(&self) -> usize {
        self.offered
    }

    fn drop_every_other(&mut self) {
        let mut i = 0;
        self.times.retain(|_| {
            let keep = i % 2 == 0;
            i += 1;
            keep
        });
        let mut j = 0;
        self.states.retain(|_| {
            let keep = j % 2 == 0;
            j += 1;
            keep
        });
        self.stride *= 2;
    }

    pub fn push(&mut self, t: f64, state: &TokenBatch) {
        self.push_owned(t, state.clone());
    }

    /// [`Trace::push`] from the raw engine-loop parts, constructing the
    /// [`TokenBatch`] only once (the engine-resident collector's entry).
    pub fn push_raw(&mut self, t: f64, batch: usize, seq_len: usize, tokens: &[i32]) {
        self.push_owned(t, TokenBatch { batch, seq_len, tokens: tokens.to_vec() });
    }

    pub fn push_owned(&mut self, t: f64, state: TokenBatch) {
        // The previous tail, if provisional, existed only to keep "last"
        // exact; this push supersedes it.
        if self.tail_provisional {
            self.times.pop();
            self.states.pop();
            self.tail_provisional = false;
        }
        let on_stride = self.offered % self.stride == 0; // first is always on-stride
        self.offered += 1;
        if on_stride && self.cap != 0 && self.times.len() >= self.cap {
            // Bounded and full: halve resolution (keeps the first exact),
            // then re-check whether this snapshot still lands on the
            // doubled stride.
            self.drop_every_other();
            if (self.offered - 1) % self.stride != 0 {
                self.times.push(t);
                self.states.push(state);
                self.tail_provisional = true;
                return;
            }
        }
        self.times.push(t);
        self.states.push(state);
        self.tail_provisional = !on_stride;
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Pick roughly `k` evenly spaced snapshot indices (always includes the
    /// first and last) — the paper shows "every other" step in Fig. 5.
    pub fn snapshot_indices(&self, k: usize) -> Vec<usize> {
        let n = self.len();
        if n == 0 {
            return vec![];
        }
        if k >= n || k < 2 {
            return (0..n).collect();
        }
        let mut idx: Vec<usize> =
            (0..k).map(|i| (i as f64 * (n - 1) as f64 / (k - 1) as f64).round() as usize).collect();
        idx.dedup();
        idx
    }

    /// Dump a CSV of point states (for the two-moons Fig. 5 panels):
    /// columns `time,row,x,y`.
    pub fn write_points_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "time,row,x,y")?;
        for (ti, state) in self.times.iter().zip(&self.states) {
            for r in 0..state.batch {
                let row = state.row(r);
                writeln!(f, "{ti},{r},{},{}", row[0], row[1])?;
            }
        }
        Ok(())
    }

    /// Dump one row's trajectory as a sequence of token vectors (for image
    /// progress strips): returns (time, tokens) pairs at `k` snapshots.
    pub fn row_snapshots(&self, row: usize, k: usize) -> Vec<(f64, Vec<i32>)> {
        self.snapshot_indices(k)
            .into_iter()
            .map(|i| (self.times[i], self.states[i].row(row).to_vec()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_trace(steps: usize) -> Trace {
        let mut tr = Trace::new();
        for i in 0..=steps {
            let mut tb = TokenBatch::zeros(2, 2);
            tb.tokens = vec![i as i32; 4];
            tr.push(i as f64 / steps as f64, &tb);
        }
        tr
    }

    #[test]
    fn push_and_len() {
        let tr = toy_trace(10);
        assert_eq!(tr.len(), 11);
        assert!(!tr.is_empty());
    }

    #[test]
    fn snapshot_indices_include_ends() {
        let tr = toy_trace(20);
        let idx = tr.snapshot_indices(5);
        assert_eq!(*idx.first().unwrap(), 0);
        assert_eq!(*idx.last().unwrap(), 20);
        assert!(idx.len() <= 5);
        // Small traces return everything.
        assert_eq!(toy_trace(2).snapshot_indices(10), vec![0, 1, 2]);
    }

    #[test]
    fn row_snapshots_track_rows() {
        let tr = toy_trace(4);
        let snaps = tr.row_snapshots(1, 3);
        assert_eq!(snaps.len(), 3);
        assert_eq!(snaps[0].1, vec![0, 0]);
        assert_eq!(snaps[2].1, vec![4, 4]);
    }

    #[test]
    fn stride_policy_records_every_nth_with_exact_first_and_last() {
        // Offer 10 snapshots (t = 0..9) at stride 2: the even indices are
        // recorded, and the off-stride final state rides along exactly.
        let mut tr = Trace::with_policy(2, 0);
        for i in 0..10 {
            let mut tb = TokenBatch::zeros(1, 2);
            tb.tokens = vec![i, i];
            tr.push(i as f64, &tb);
        }
        assert_eq!(tr.offered(), 10);
        assert_eq!(tr.times, vec![0.0, 2.0, 4.0, 6.0, 8.0, 9.0]);
        // row_snapshots reads the recorded points (k >= len returns all).
        let snaps = tr.row_snapshots(0, 100);
        assert_eq!(snaps.len(), 6);
        assert_eq!(snaps[0], (0.0, vec![0, 0]), "first offered state is exact");
        assert_eq!(snaps[5], (9.0, vec![9, 9]), "last offered state is exact");
        assert_eq!(snaps[2], (4.0, vec![4, 4]), "interior points sit on the stride");
        // One more push replaces the provisional tail with an on-stride
        // entry — no duplicate of t=9 survives.
        let mut tb = TokenBatch::zeros(1, 2);
        tb.tokens = vec![10, 10];
        tr.push(10.0, &tb);
        assert_eq!(tr.times, vec![0.0, 2.0, 4.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn cap_bounds_memory_while_keeping_ends_exact() {
        // A long (cascade-length) run through a cap-8 trace: retained
        // snapshots never exceed cap + 1 (the provisional tail), the
        // first and last states stay exact, and times stay sorted.
        let mut tr = Trace::with_policy(1, 8);
        for i in 0..500 {
            let mut tb = TokenBatch::zeros(1, 1);
            tb.tokens = vec![i];
            tr.push(i as f64, &tb);
            assert!(tr.len() <= 9, "cap breached at step {i}: {}", tr.len());
        }
        assert_eq!(tr.offered(), 500);
        assert_eq!(tr.times[0], 0.0);
        assert_eq!(*tr.times.last().unwrap(), 499.0);
        assert_eq!(tr.states.last().unwrap().tokens, vec![499]);
        assert!(tr.times.windows(2).all(|w| w[0] < w[1]), "{:?}", tr.times);
        // Unbounded default still records everything (legacy behaviour).
        let full = toy_trace(499);
        assert_eq!(full.len(), 500);
    }

    #[test]
    fn points_csv_dump() {
        let tr = toy_trace(2);
        let p = std::env::temp_dir().join(format!("wsfm_trace_{}.csv", std::process::id()));
        tr.write_points_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("time,row,x,y"));
        assert_eq!(text.lines().count(), 1 + 3 * 2); // header + 3 times x 2 rows
        std::fs::remove_file(&p).unwrap();
    }
}
