//! # wsfm — Warm-Start Flow Matching serving stack
//!
//! A three-layer reproduction of *"Warm-Start Flow Matching for Guaranteed
//! Fast Text/Image Generation"* (Kim, 2026):
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request router,
//!   dynamic batcher, draft→refine scheduler, per-request state, metrics,
//!   TCP server, CLI. Python never runs on the request path.
//! * **Layer 2** — JAX denoiser/draft models, AOT-lowered to HLO text at
//!   build time (`python/compile/aot.py`), executed here via PJRT
//!   ([`runtime`]).
//! * **Layer 1** — Pallas kernels (fused attention, fused DFM Euler update)
//!   lowered into the same HLO artifacts.
//!
//! The paper's headline feature — warm-start sampling with a guaranteed
//! `1/(1-t0)` NFE reduction — lives in [`sampler`] and is exercised
//! end-to-end by the [`coordinator`].
//!
//! ## The hot path
//!
//! The NFE guarantee only buys wall-clock if per-step overhead is
//! negligible, so the Euler loop is **engine-resident**: [`sampler`]
//! resolves a `LoopSpec` and ships the whole run to the engine thread in
//! one channel round-trip (`runtime::engine::Req::RunLoop`), where
//! per-artifact scratch buffers make the steady state allocation-free and
//! categorical sampling fans out over a scoped-thread worker pool
//! ([`core::workers`]) with stateless per-`(step, row)` RNG substreams —
//! bitwise-reproducible for a given seed regardless of worker count or of
//! where the loop runs. See EXPERIMENTS.md §Perf.
//!
//! ## The serving pipeline
//!
//! Above the hot loop, the [`coordinator`] is a staged pipeline: the
//! admission thread only validates, batches, and flushes; flushed bundles
//! cross bounded channels to a DRAFT stage (warm-start init tokens,
//! `draft_workers` threads with per-thread draft-model caches) and a
//! REFINE stage (`fleet.refine_workers` threads driving the
//! engine-resident loop against the executor fleet), capped at
//! `pipeline_depth` bundles in flight. Drafting bundle N+1 overlaps
//! refining bundle N, and deadline flushes never wait on execution. All
//! bundle randomness is a stateless substream of
//! `(config.seed, bundle key, request seeds)`, so tokens are
//! bitwise-identical across pipeline settings, including the serial
//! `pipeline_depth = 1` path. See EXPERIMENTS.md §Serving.
//!
//! ## The engine fleet
//!
//! One engine thread is one execution stream; concurrent bundles
//! serialize on it regardless of pipeline depth. [`fleet`] replicates the
//! execution layer: `fleet.replicas` full engine replicas (each its own
//! engine thread + artifact cache) behind a [`fleet::FleetHandle`] that
//! implements `Executor`, routing every dispatch deterministically —
//! least-loaded first, artifact affinity breaking ties (avoid duplicate
//! compiles), lowest index last. The REFINE stage runs
//! `fleet.refine_workers` threads so independent bundles refine
//! concurrently on distinct replicas. Replicas are panic-isolated: a dead
//! engine thread surfaces the typed `EngineDead`, its work re-routes to a
//! healthy replica, and only an entirely dead pool surfaces the typed
//! `FleetDown`. Because all bundle RNG is stateless, outputs are
//! bitwise-identical for any `(replicas, refine_workers, pipeline_depth,
//! draft_workers)`. See EXPERIMENTS.md §Fleet.
//!
//! ## The adaptive warm-start controller
//!
//! The paper's `1/(1-t0)` speed-up is per-draft-quality, so [`control`]
//! chooses each bundle's `t0` from the draft it actually produced:
//! `static` mode runs the request's `t0` verbatim, `prior` maps the
//! draft-model kind's prior onto a discrete grid, `scored` takes the
//! better of an n-gram self-consistency score and an adjacent-position
//! correlation energy score over the drafted batch. Every adaptive
//! choice clamps to `[t0_min, t0_max]` (and up to the artifact's
//! trained t0), so no bundle ever exceeds the static-`t0_min` NFE
//! budget — the guarantee keeps a hard floor. Decisions are pure
//! functions of (bundle contents, config), preserving the bitwise
//! determinism contract. See EXPERIMENTS.md §Control.
//!
//! ## Cascade refinement
//!
//! The controller decides *where to start*; [`cascade`] decides *where
//! to stop*. Refinement runs as an ordered ladder of **resumable engine
//! segments** (`core::schedule::Schedule::segment`, windowed
//! `runtime::engine::LoopSpec`s): after each segment the intermediate
//! state can be scored with the [`control`] proxies and, if the quality
//! gate passes, the bundle exits early — the remaining segments are
//! never paid for. RNG substreams key on the *absolute* step index, so
//! a run split into any segments (even hopping fleet replicas between
//! them; artifact affinity keeps resumes local) is bitwise-identical to
//! the unsplit run, and total NFE can only shrink: the paper's
//! `guaranteed_nfe(steps_cold, t0_min)` floor holds in every mode.
//! `cascade.mode = off` (default) is the single-segment path verbatim.
//! See EXPERIMENTS.md §Cascade.
//!
//! ## Continuous cross-bundle batching
//!
//! Per-bundle refinement leaves the engine under-filled whenever bundles
//! are small or staggered. [`coordinator::ComposedRefiner`] is a
//! step-level batch composer over the REFINE stage: rows from every
//! in-flight bundle (and cascade segment) merge into shared engine
//! steps, grouped by `(domain, tag, seq_len)` family and sorted so rows
//! on the same `(t, h, warp)` coordinates share one forward pass. Rows
//! retire as their segments complete and newly drafted bundles admit at
//! the next step boundary — continuous batching in the vLLM sense, at
//! flow-matching-step granularity. Because every token draw keys on
//! `(run_seed, absolute step, row position)` and composition only
//! changes *grouping*, never values, composed outputs are
//! bitwise-identical to the per-bundle path; a failed composed dispatch
//! fails the whole cohort over to that path, keeping the fault envelope.
//! `composer.enabled = false` (default) is the per-bundle loop verbatim.
//! See EXPERIMENTS.md §Batching.
//!
//! ## Fault tolerance
//!
//! The failure-side envelope: every request resolves to ok, a degraded
//! draft, or a typed error — never a hang. [`faults`] provides
//! deterministic chaos (an `Executor`-wrapping `FaultyExec` whose
//! panic/wedge/error faults fire from stateless
//! `Pcg64::substream(fault_seed, call_index, site)` draws, so failure
//! tests pin exact outcomes per seed). The engine watchdog
//! (`robustness.call_timeout_ms`) turns a wedged-but-alive engine into a
//! typed `EngineTimeout`, which the [`fleet`] treats like `EngineDead`:
//! quarantine and re-route, with per-slot generation tags discarding any
//! stale late reply. A fleet health loop resurrects quarantined replicas
//! (fresh engine thread, artifact re-preload, probe-gated readmission)
//! under capped exponential backoff with a consecutive-failure circuit
//! breaker. When REFINE exhausts its reroutes, the [`coordinator`] serves
//! the bundle's already-computed draft tokens with `degraded: true` on
//! the wire — the paper's "drafts are already decent" claim as a
//! graceful-degradation contract. See EXPERIMENTS.md §Robustness.
//!
//! ## Observability
//!
//! The paper's claim is a *measurable* speed-up, so the serving stack
//! carries its own evidence: [`obs`] holds a bounded span journal
//! (typed, fixed-size records — admit, batcher-wait, draft,
//! refine-segment, gate-eval, engine-call, composed-step — in
//! preallocated per-kind rings; recording never allocates) and a
//! sequence-numbered event journal for every fleet/fault lifecycle
//! transition (quarantine, respawn, reroute, watchdog timeout, artifact
//! swap/rollback, degraded response, codec switch). A live stats
//! surface rides the wire — `{"cmd":"stats"}` returns a typed
//! [`metrics::MetricsSnapshot`] on either codec, `{"cmd":"trace"}`
//! returns one request's span path, and `wsfm stats` renders
//! Prometheus-style text — while `"timing": true` on a generate request
//! opts into a per-response breakdown (queue wait, draft, per-segment
//! refine, gate evals, chosen t0, NFE vs the guarantee floor, replica
//! ids, reroute count): the per-sample evidence for the guaranteed-NFE
//! claim. Observation never perturbs outputs — the determinism sweeps
//! run with tracing on and off — and everything is strictly bounded by
//! `config.obs` ring caps. See EXPERIMENTS.md §Observability.
//!
//! ## The decision ledger and guarantee audit
//!
//! The guarantee is per-request, so [`obs::ledger`] records it
//! per-bundle: every delivered bundle (refined or degraded) appends one
//! `DecisionRecord` — what was requested, what the controller and
//! cascade decided and why (proxy scores, chosen t0, gate verdicts,
//! per-stage NFE), what it cost (realized NFE vs the `guaranteed_nfe`
//! floor), and the exact RNG inputs (config seed, bundle seed,
//! per-request seeds and output hashes). An in-line auditor checks each
//! record against the serving invariants (never over the floor unless
//! degraded; stage sums consistent; early exits gate-passed; degraded
//! bills zero) and bumps the `guarantee_violations` counter — pinned to
//! 0 by the CI chaos matrix. Sliding per-`(domain, draft)` windows
//! detect drift of the proxy scores against the controller's
//! calibration table. Records ring-buffer in memory
//! (`obs.ledger.cap`) and optionally stream to an append-only JSONL
//! sink (`obs.ledger.path`; a crash loses at most the torn final
//! line). `wsfm audit` analyzes a recorded ledger offline;
//! `wsfm replay` re-executes it — recorded decisions injected in place
//! of live control — and asserts bitwise-identical outputs
//! ([`coordinator::replay`]). See EXPERIMENTS.md §Audit.
//!
//! ## The wire and the artifact contract
//!
//! The TCP protocol is a pluggable codec ([`server::codec`]): requests
//! and responses are typed structs ([`server::protocol`]), and a
//! per-connection [`server::codec::Codec`] decides the framing — the
//! legacy newline-delimited JSON (byte-pinned by golden tests; what a
//! hello-free client always gets) or length-prefixed binary frames,
//! negotiated by a `{"cmd":"hello","codecs":[...]}` handshake against
//! `wire.codecs`. Seeds and counters ride the wire as exact integers
//! (`util::json::Json::U64`) — a `u64::MAX` seed round-trips losslessly
//! on both codecs. On the artifact side, `manifest.json` is a versioned
//! contract ([`runtime::artifact`]): schema v2 stamps every artifact
//! with an FNV-1a 64 content hash (emitted by `python/compile/aot.py`,
//! recomputed by `wsfm verify-artifacts`), and
//! [`fleet::FleetHandle::swap_artifacts`] hot-swaps the whole fleet to a
//! new verified manifest all-or-nothing — build + preload + probe every
//! replacement first, then publish under an epoch tag that concurrent
//! respawns respect, so the fleet never serves mixed contracts. See
//! EXPERIMENTS.md §Wire.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured results.

pub mod cascade;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod draft;
pub mod eval;
pub mod faults;
pub mod fleet;
pub mod harness;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod util;

/// Crate-wide result type (anyhow-based; the only external deps are `xla`
/// and `anyhow` — everything else is implemented in-tree, DESIGN.md §2).
pub type Result<T> = anyhow::Result<T>;
