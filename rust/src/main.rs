//! `wsfm` — CLI entrypoint for the Warm-Start Flow Matching serving stack.
//!
//! Subcommands:
//! * `serve`       — start the TCP serving front-end.
//! * `generate`    — one-shot local generation (no server).
//! * `stats`       — fetch a running server's live metrics snapshot.
//! * `info`        — artifact/manifest inventory.
//! * `selfcheck`   — validate artifacts + run a smoke execution.
//! * `audit`       — offline analysis of a decision-ledger JSONL file.
//! * `replay`      — re-execute a recorded ledger, assert bitwise-identical outputs.
//! * `bench-table1..4` — regenerate the paper's tables (see EXPERIMENTS.md).
//! * `figures`     — dump the paper's figure data (Fig 4/5/6/7/10/14).

use anyhow::{bail, Context, Result};
use wsfm::config::WsfmConfig;
use wsfm::coordinator::request::{DraftSpec, GenRequest};
use wsfm::coordinator::Service;
use wsfm::core::schedule::WarpMode;
use wsfm::fleet::FleetHandle;
use wsfm::harness;
use wsfm::runtime::{EngineHandle, Manifest};
use wsfm::server::TcpServer;
use wsfm::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
wsfm — Warm-Start Flow Matching serving stack

USAGE: wsfm <subcommand> [options]

SUBCOMMANDS:
  serve          start the TCP server (negotiated json/binary wire codecs)
  generate       one-shot local generation
  stats          fetch live stats from a running server (Prometheus text)
  info           print the artifact inventory
  selfcheck      validate artifacts and run a smoke execution
  verify-artifacts  check manifest content hashes against the files on disk
  audit          analyze a decision-ledger JSONL file (guarantees, drift)
  replay         re-execute a recorded ledger, assert bitwise-identical outputs
  bench-table1   two-moons SKL/NFE table (paper Table 1, Figs 4/5)
  bench-table2   text8 NLL/entropy/time table (paper Table 2, Fig 10)
  bench-table3   wiki perplexity table (paper Table 3, Fig 14)
  bench-table4   image FID/time table (paper Table 4, Figs 6-9)
  figures        dump all figure data

Run `wsfm <subcommand> --help` for options.";

fn run(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "serve" => cmd_serve(rest),
        "generate" => cmd_generate(rest),
        "stats" => cmd_stats(rest),
        "info" => cmd_info(rest),
        "selfcheck" => cmd_selfcheck(rest),
        "verify-artifacts" => cmd_verify_artifacts(rest),
        "audit" => cmd_audit(rest),
        "replay" => cmd_replay(rest),
        "bench-table1" => harness::table1::main(rest),
        "bench-table2" => harness::table2::main(rest),
        "bench-table3" => harness::table3::main(rest),
        "bench-table4" => harness::table4::main(rest),
        "figures" => harness::figures::main(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn load_config(args: &wsfm::util::cli::Args) -> Result<WsfmConfig> {
    let mut cfg = if args.get("config").is_empty() {
        WsfmConfig::default()
    } else {
        WsfmConfig::from_file(std::path::Path::new(args.get("config")))?
    };
    if !args.get("artifacts").is_empty() {
        cfg.artifacts_dir = args.get("artifacts").into();
    }
    if !args.get("listen").is_empty() {
        cfg.listen_addr = args.get("listen").to_string();
    }
    Ok(cfg)
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm serve", "start the TCP serving front-end")
        .opt("config", "", "JSON config file")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("listen", "", "listen address (overrides config)")
        .opt("preload", "", "comma list of domains to precompile (e.g. text8)");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let cfg = load_config(&args)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    manifest.selfcheck()?;
    // The executor fleet: `fleet.replicas` engine threads (each with its
    // own artifact cache) behind one least-loaded routing handle, with
    // the robustness envelope armed (call watchdog + replica
    // resurrection per `cfg.robustness`).
    let fleet = FleetHandle::spawn_with(manifest.clone(), cfg.fleet.replicas, &cfg.robustness)?;

    if !args.get("preload").is_empty() {
        for domain in args.get("preload").split(',') {
            let names: Vec<String> =
                manifest.for_domain(domain).iter().map(|a| a.name.clone()).collect();
            if names.is_empty() {
                bail!("no artifacts for preload domain {domain:?}");
            }
            println!(
                "preloading {} artifacts for {domain} on {} replica(s)...",
                names.len(),
                fleet.replicas()
            );
            fleet.preload(&names)?;
        }
    }

    let service = Service::start(fleet.clone(), manifest.clone(), cfg.clone());
    // Wire the fleet into the observability hub: lifecycle transitions
    // (quarantine/respawn/reroute/swap) land in the event journal and
    // engine calls record spans, 1:1 with the fleet counters.
    fleet.attach_obs(service.metrics.obs.clone());
    let server =
        TcpServer::bind_with(&cfg.listen_addr, service.clone(), manifest, cfg.wire.clone())?
            .with_fleet(fleet.clone());
    println!("wsfm serving on {} (artifacts: {:?})", server.local_addr, cfg.artifacts_dir);
    println!("wire: codecs={:?} default={}", cfg.wire.codecs, cfg.wire.default);
    if cfg.pipeline_depth > 1 {
        println!(
            "pipeline: depth={} draft_workers={} refine_workers={} (DRAFT overlaps REFINE)",
            cfg.pipeline_depth, cfg.draft_workers, cfg.fleet.refine_workers
        );
    } else {
        println!("pipeline: depth=1 (serial admission+execution)");
    }
    println!("fleet: {} engine replica(s), least-loaded routing", fleet.replicas());
    if cfg.obs.enabled {
        println!(
            "obs: tracing on (span cap {}/kind, event cap {}) — `wsfm stats`, \
             {{\"cmd\":\"stats\"}}, {{\"cmd\":\"trace\",\"request_id\":N}}",
            cfg.obs.span_cap, cfg.obs.event_cap
        );
    } else {
        println!("obs: tracing off (obs.enabled=false)");
    }
    if cfg.obs.ledger.enabled {
        println!(
            "ledger: on (cap {}{}) — per-bundle decision records, guarantee auditor, \
             drift windows; analyze with `wsfm audit` / `wsfm replay`",
            cfg.obs.ledger.cap,
            if cfg.obs.ledger.path.is_empty() {
                ", in-memory".to_string()
            } else {
                format!(", sink {:?}", cfg.obs.ledger.path)
            }
        );
    } else {
        println!("ledger: off (obs.ledger.enabled=false)");
    }
    println!(
        "control: mode={} t0 in [{}, {}] grid {:?}{}",
        cfg.control.mode,
        cfg.control.t0_min,
        cfg.control.t0_max,
        cfg.control.grid,
        if cfg.control.calibration.is_empty() { "" } else { " (calibrated)" }
    );
    if cfg.cascade.mode != "off" {
        println!(
            "cascade: mode={} ladder {:?} gate_threshold={}",
            cfg.cascade.mode, cfg.cascade.ladder, cfg.cascade.gate_threshold
        );
    } else {
        println!("cascade: off (single-segment refinement)");
    }
    if cfg.composer.enabled {
        println!(
            "composer: continuous cross-bundle batching, max_rows={} \
             (rows_per_step / batch_occupancy in the metrics report)",
            if cfg.composer.max_rows == 0 { "unbounded".into() } else { cfg.composer.max_rows.to_string() }
        );
    } else {
        println!("composer: off (per-bundle refinement)");
    }
    server.run()?;
    println!("server stopped; final metrics:\n{}", service.metrics.report());
    println!("fleet: {}", fleet.summary());
    service.shutdown();
    fleet.shutdown();
    Ok(())
}

fn cmd_generate(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm generate", "one-shot local generation")
        .opt("artifacts", "artifacts", "artifacts directory")
        .req("domain", "domain (two_moons|text8|wiki|img_gray|img_color)")
        .opt("tag", "cold", "step tag (cold|ws_t080|ws_good_t095|...)")
        .opt("draft", "noise", "draft model (noise|lstm|pca|good|fair|poor)")
        .opt("n", "4", "number of samples")
        .opt("t0", "0.0", "warm-start time")
        .opt("steps", "128", "cold-run step count")
        .opt("warp", "literal", "update rule (literal|exact)")
        .opt("seed", "0", "rng seed")
        .flag("decode", "decode tokens to text (text domains)");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;

    let manifest = Manifest::load(std::path::Path::new(args.get("artifacts")))?;
    let engine = EngineHandle::spawn(manifest.clone())?;
    let metrics = wsfm::metrics::ServingMetrics::default();
    // Local one-shots use config-seed 0; determinism comes from the
    // request seed via the bundle-substream derivation.
    let scheduler = wsfm::coordinator::Scheduler::new(&engine, &manifest, &metrics, 0);

    let req = GenRequest {
        id: 0,
        domain: args.get("domain").to_string(),
        tag: args.get("tag").to_string(),
        draft: DraftSpec::parse(args.get("draft"))?,
        n_samples: args.get_usize("n").map_err(|m| anyhow::anyhow!(m))?,
        t0: args.get_f64("t0").map_err(|m| anyhow::anyhow!(m))?,
        steps_cold: args.get_usize("steps").map_err(|m| anyhow::anyhow!(m))?,
        warp_mode: WarpMode::parse(args.get("warp"))?,
        seed: args.get_u64("seed").map_err(|m| anyhow::anyhow!(m))?,
        timing: false,
        submitted: std::time::Instant::now(),
    };
    let resp = scheduler.run_single(req.clone())?;
    println!(
        "generated {} samples  nfe={}  t0_used={}  draft={:?} refine={:?} total={:?}",
        resp.samples.len(),
        resp.nfe,
        resp.t0_used,
        resp.draft_time,
        resp.refine_time,
        resp.total_time
    );
    if args.flag("decode") && req.domain == "text8" {
        let tok = wsfm::data::tokenizer::CharTokenizer;
        for (i, s) in resp.samples.iter().enumerate() {
            println!("--- sample {i} ---\n{}", tok.decode(s));
        }
    } else if args.flag("decode") && req.domain == "wiki" {
        let vocab = std::fs::read_to_string(manifest.dir.join("wiki_vocab.json"))?;
        let tok = wsfm::data::tokenizer::WordTokenizer::from_json(&vocab)?;
        for (i, s) in resp.samples.iter().enumerate() {
            println!("--- sample {i} ---\n{}", tok.decode(s));
        }
    } else {
        for (i, s) in resp.samples.iter().enumerate() {
            let shown: Vec<i32> = s.iter().take(16).copied().collect();
            println!("sample {i}: {shown:?}{}", if s.len() > 16 { " ..." } else { "" });
        }
    }
    engine.shutdown();
    Ok(())
}

fn cmd_stats(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm stats", "fetch a running server's live metrics snapshot")
        .opt("addr", "127.0.0.1:7871", "server address")
        .opt("codec", "json", "wire codec to use (json|binary)")
        .opt("trace", "", "also fetch the span trace for this request id")
        .flag("json", "print the raw stats JSON instead of Prometheus-style text");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let mut client = wsfm::server::Client::connect(args.get("addr"))?;
    if args.get("codec") != "json" {
        client.negotiate(&[args.get("codec")])?;
    }
    let snapshot = client.stats()?;
    if args.flag("json") {
        println!("{}", snapshot.to_json());
    } else {
        print!("{}", snapshot.render_prometheus());
    }
    // Event-journal eviction means `{"cmd":"trace"}` histories have a
    // sequence gap: seqs [0, obs_events_evicted) are gone from the ring.
    if snapshot.serving.obs_events_evicted > 0 {
        eprintln!(
            "warning: {} journal event(s) evicted (cap reached) — event seqs 0..{} are \
             no longer retrievable; raise obs.event_cap to keep longer histories",
            snapshot.serving.obs_events_evicted, snapshot.serving.obs_events_evicted
        );
    }
    if !args.get("trace").is_empty() {
        let id: u64 = args.get("trace").parse().context("bad --trace request id")?;
        for s in client.trace(id)? {
            println!(
                "trace {id}: {:<14} bundle={} detail={} start_us={} dur_us={}",
                s.kind.name(),
                s.bundle_id,
                s.detail,
                s.start_us,
                s.dur_us
            );
        }
    }
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let cli =
        Cli::new("wsfm info", "artifact inventory").opt("artifacts", "artifacts", "artifacts directory");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let manifest = Manifest::load(std::path::Path::new(args.get("artifacts")))?;
    println!("artifacts dir: {:?}", manifest.dir);
    println!("domains:");
    for d in manifest.domain_names() {
        let tags = manifest.step_tags(&d);
        let arts = manifest.for_domain(&d);
        let first = arts.first().context("empty domain")?;
        println!("  {d:<10} N={:<4} V={:<4} tags={:?}", first.seq_len, first.vocab, tags);
    }
    println!("total artifacts: {}", manifest.artifacts.len());
    Ok(())
}

fn cmd_verify_artifacts(rest: &[String]) -> Result<()> {
    let cli = Cli::new(
        "wsfm verify-artifacts",
        "check every manifest content hash against the bytes on disk",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .flag("strict", "also fail if any artifact carries no content hash (schema v1)");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let manifest = Manifest::load(std::path::Path::new(args.get("artifacts")))?;
    println!(
        "manifest schema v{} — {} artifacts",
        manifest.schema_version,
        manifest.artifacts.len()
    );
    let report = manifest.verify_hashes()?;
    println!("{report}");
    for (name, declared, actual) in &report.mismatches {
        println!("  MISMATCH {name}: declared {declared:016x}, on disk {actual:016x}");
    }
    if !report.ok() {
        bail!("{} artifact(s) do not match their declared content hash", report.mismatches.len());
    }
    if args.flag("strict") && report.unhashed > 0 {
        bail!("{} artifact(s) carry no content hash (strict mode)", report.unhashed);
    }
    println!("all declared hashes match");
    Ok(())
}

fn cmd_selfcheck(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm selfcheck", "validate artifacts, smoke-run one step")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("domain", "two_moons", "domain to smoke-run")
        .opt("config", "", "JSON config file (fleet.replicas; controller grid for --calibrate)")
        .flag("calibrate", "run the control calibration pass and write control_calibration.json");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let manifest = Manifest::load(std::path::Path::new(args.get("artifacts")))?;
    manifest.selfcheck()?;
    println!("manifest ok: {} artifacts", manifest.artifacts.len());
    let cfg = if args.get("config").is_empty() {
        WsfmConfig::default()
    } else {
        WsfmConfig::from_file(std::path::Path::new(args.get("config")))?
    };

    if args.flag("calibrate") {
        let table = wsfm::control::calibrate_two_moons(&cfg.control)?;
        println!("control calibration (fixed-seed two-moons reference drafts):");
        println!("  {:>10}  {:>6}", "min_score", "t0");
        for &(min_score, t0) in &table {
            println!("  {min_score:>10.4}  {t0:>6.2}");
        }
        let json = wsfm::util::json::Json::obj(vec![(
            "calibration",
            wsfm::util::json::Json::arr(table.iter().map(|&(s, t)| {
                wsfm::util::json::Json::obj(vec![
                    ("min_score", wsfm::util::json::Json::num(s)),
                    ("t0", wsfm::util::json::Json::num(t)),
                ])
            })),
        )]);
        let path = manifest.dir.join("control_calibration.json");
        std::fs::write(&path, format!("{json}\n"))?;
        println!("wrote {path:?} — merge its calibration array into config under \"control\"");
    }

    let domain = args.get("domain");
    let batches = manifest.step_batches(domain, "cold");
    let b = *batches.first().context("no cold artifacts for domain")?;
    // Smoke the executor fleet exactly as `serve` would run it —
    // including the watchdog + resurrection envelope.
    let fleet = FleetHandle::spawn_with(manifest.clone(), cfg.fleet.replicas, &cfg.robustness)?;
    let metrics = wsfm::metrics::ServingMetrics::default();
    let scheduler = wsfm::coordinator::Scheduler::new(&fleet, &manifest, &metrics, 0);
    let req = GenRequest {
        id: 0,
        domain: domain.to_string(),
        tag: "cold".into(),
        draft: DraftSpec::Noise,
        n_samples: b,
        t0: 0.0,
        steps_cold: 8,
        warp_mode: WarpMode::Exact,
        seed: 0,
        timing: false,
        submitted: std::time::Instant::now(),
    };
    let resp = scheduler.run_single(req)?;
    println!(
        "smoke run ok: {} samples of len {} in {:?} ({} NFE)",
        resp.samples.len(),
        resp.samples[0].len(),
        resp.total_time,
        resp.nfe
    );
    // Serving metrics incl. the pipeline gauges/histograms
    // (inflight_bundles, draft_queue_wait, flush_lag).
    println!("serving metrics:\n{}", metrics.report());
    // Fleet routing/health counters plus per-replica engine stats
    // (microsecond-resolution compile/exec counters per replica).
    println!("fleet: {}", fleet.summary());
    fleet.shutdown();
    Ok(())
}

/// Parse a calibration table for drift banding: either the
/// `control_calibration.json` that `wsfm selfcheck --calibrate` writes
/// (top-level `calibration` array) or a full config file
/// (`control.calibration`).
fn load_calibration(path: &str) -> Result<Vec<(f64, f64)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let j = wsfm::util::json::Json::parse(&text).context("calibration JSON")?;
    let arr = match j.get("calibration").as_arr() {
        Some(a) => a,
        None => j
            .get("control")
            .get("calibration")
            .as_arr()
            .context("no calibration array (expected `calibration` or `control.calibration`)")?,
    };
    arr.iter()
        .map(|e| {
            Ok((
                e.get("min_score").as_f64().context("calibration entry min_score")?,
                e.get("t0").as_f64().context("calibration entry t0")?,
            ))
        })
        .collect()
}

fn cmd_audit(rest: &[String]) -> Result<()> {
    let cli = Cli::new(
        "wsfm audit <ledger.jsonl>",
        "offline decision-ledger analysis: guarantee audit + drift detection",
    )
    .opt("calibration", "", "calibration JSON for drift banding (selfcheck --calibrate output)");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let path = args.positional.first().context("usage: wsfm audit <ledger.jsonl>")?;
    let (records, torn) = wsfm::obs::ledger::read_ledger(std::path::Path::new(path))?;
    if torn {
        eprintln!("warning: dropped a torn final line (crash mid-write); records before it are intact");
    }
    if records.is_empty() {
        println!("ledger {path:?} holds no records");
        return Ok(());
    }
    print!("{}", wsfm::obs::ledger::render_audit(&records));

    // Re-run the guarantee auditor record by record so every violation is
    // named, not just counted.
    let failures: Vec<String> =
        records.iter().filter_map(|r| wsfm::obs::ledger::audit(r).err()).collect();

    // Drift view: re-feed the records through a fresh ledger's windows —
    // identical banding to what the live server computes.
    let calibration = if args.get("calibration").is_empty() {
        Vec::new()
    } else {
        load_calibration(args.get("calibration"))?
    };
    let scratch = wsfm::obs::ledger::Ledger::new(true, records.len().max(1));
    for r in &records {
        scratch.append(r.clone());
    }
    println!("\ndrift (windowed proxy scores / nfe_saved per domain × draft):");
    for cell in scratch.drift_report(&calibration) {
        println!(
            "  {:<12} {:<8} status={:<8} score: n={} mean={:.4} var={:.4} p50={:.4} p95={:.4}{} \
             | nfe_saved: mean={:.2} p95={:.2}",
            cell.domain,
            cell.draft,
            cell.status,
            cell.score.count,
            cell.score.mean,
            cell.score.var,
            cell.score.p50,
            cell.score.p95,
            match cell.band {
                Some(b) => format!(" band={b}"),
                None => String::new(),
            },
            cell.nfe_saved.mean,
            cell.nfe_saved.p95,
        );
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("VIOLATION: {f}");
        }
        bail!("{} of {} record(s) violate the serving guarantees", failures.len(), records.len());
    }
    println!("\nall {} record(s) pass the guarantee audit", records.len());
    Ok(())
}

fn cmd_replay(rest: &[String]) -> Result<()> {
    let cli = Cli::new(
        "wsfm replay <ledger.jsonl>",
        "re-execute recorded bundles and assert bitwise-identical outputs",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .flag("strict", "also fail when records are skipped (artifacts unavailable)");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let path = args.positional.first().context("usage: wsfm replay <ledger.jsonl>")?;
    let (records, torn) = wsfm::obs::ledger::read_ledger(std::path::Path::new(path))?;
    if torn {
        eprintln!("warning: dropped a torn final line (crash mid-write); records before it are intact");
    }
    if records.is_empty() {
        println!("ledger {path:?} holds no records; nothing to replay");
        return Ok(());
    }
    // Replay needs the artifacts the records were served from. A missing
    // artifact set is a skip, not a failure, unless --strict: fixture
    // ledgers must stay checkable in environments without build outputs.
    let manifest = match Manifest::load(std::path::Path::new(args.get("artifacts"))) {
        Ok(m) => m,
        Err(e) if !args.flag("strict") => {
            println!("artifacts unavailable ({e:#}); skipping replay of {} record(s)", records.len());
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = EngineHandle::spawn(manifest.clone())?;
    let report = wsfm::coordinator::replay::replay_records(&engine, &manifest, &records);
    print!("{}", report.render());
    engine.shutdown();
    if !report.is_clean() {
        bail!("{} record(s) did not replay bitwise-identically", report.mismatched.len());
    }
    if args.flag("strict") && !report.skipped_unavailable.is_empty() {
        bail!("{} record(s) skipped with --strict", report.skipped_unavailable.len());
    }
    println!("replay ok: every re-executed bundle reproduced its recorded outputs bitwise");
    Ok(())
}
