//! Wire protocol: one JSON object per line.
//!
//! Request:
//! ```json
//! {"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm",
//!  "n_samples":2,"t0":0.8,"steps":1024,"warp":"literal","seed":7,
//!  "decode":true}
//! ```
//! Other commands: `{"cmd":"metrics"}`, `{"cmd":"info"}`, `{"cmd":"ping"}`.
//!
//! Response (generate):
//! ```json
//! {"ok":true,"id":3,"nfe":205,"queue_us":120,"draft_us":900,
//!  "refine_us":52000,"total_us":53100,"samples":[[1,2,...]],
//!  "texts":["the old city ..."]}
//! ```
//! Errors: `{"ok":false,"error":"...","busy":true?}`.

use crate::coordinator::request::{DraftSpec, GenRequest, GenResponse};
use crate::core::schedule::WarpMode;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Parsed wire command.
#[derive(Debug)]
pub enum WireRequest {
    Generate { request: GenRequest, decode: bool },
    Metrics,
    Info,
    Ping,
    Shutdown,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line.trim()).context("malformed json")?;
    let cmd = j.get("cmd").as_str().context("missing cmd")?;
    match cmd {
        "ping" => Ok(WireRequest::Ping),
        "metrics" => Ok(WireRequest::Metrics),
        "info" => Ok(WireRequest::Info),
        "shutdown" => Ok(WireRequest::Shutdown),
        "generate" => {
            let domain = j.get("domain").as_str().context("missing domain")?.to_string();
            let tag = j.get("tag").as_str().unwrap_or("cold").to_string();
            let draft = DraftSpec::parse(j.get("draft").as_str().unwrap_or("noise"))?;
            let n_samples = j.get("n_samples").as_usize().unwrap_or(1);
            let t0 = j.get("t0").as_f64().unwrap_or(0.0);
            let steps_cold = j.get("steps").as_usize().unwrap_or(128);
            let warp_mode = WarpMode::parse(j.get("warp").as_str().unwrap_or("literal"))?;
            let seed = j.get("seed").as_f64().unwrap_or(0.0) as u64;
            let decode = j.get("decode").as_bool().unwrap_or(false);
            let request = GenRequest {
                id: 0,
                domain,
                tag,
                draft,
                n_samples,
                t0,
                steps_cold,
                warp_mode,
                seed,
                submitted: Instant::now(),
            };
            request.validate()?;
            Ok(WireRequest::Generate { request, decode })
        }
        other => bail!("unknown cmd {other:?}"),
    }
}

/// Render a successful generate response. `texts` is optional decoded
/// output (char/word domains).
///
/// Cascade stage accounting (`stages_used`, per-stage `nfe_stages`,
/// `early_exit`) is emitted only when the bundle ran under a cascade
/// mode, and the degradation marker (`degraded: true` plus
/// `degraded_reason`) only when refinement failed and the coordinator
/// served draft tokens — with `cascade.mode = off` and refinement
/// healthy the response stays **byte-for-byte** the pre-cascade wire
/// format (pinned by tests).
pub fn render_response(resp: &GenResponse, texts: Option<Vec<String>>) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(resp.id as f64)),
        ("nfe", Json::num(resp.nfe as f64)),
        ("t0_used", Json::num(resp.t0_used)),
        ("queue_us", Json::num(resp.queue_wait.as_micros() as f64)),
        ("draft_us", Json::num(resp.draft_time.as_micros() as f64)),
        ("refine_us", Json::num(resp.refine_time.as_micros() as f64)),
        ("total_us", Json::num(resp.total_time.as_micros() as f64)),
    ];
    if let Some(c) = &resp.cascade {
        fields.push(("stages_used", Json::num(c.stages_used as f64)));
        fields.push((
            "nfe_stages",
            Json::arr(c.nfe_per_stage.iter().map(|&n| Json::num(n as f64))),
        ));
        fields.push(("early_exit", Json::Bool(c.early_exit)));
    }
    if let Some(reason) = &resp.degraded {
        fields.push(("degraded", Json::Bool(true)));
        fields.push(("degraded_reason", Json::str(reason)));
    }
    fields.push((
        "samples",
        Json::arr(
            resp.samples.iter().map(|row| Json::arr(row.iter().map(|&t| Json::num(t as f64)))),
        ),
    ));
    if let Some(ts) = texts {
        fields.push(("texts", Json::arr(ts.into_iter().map(Json::str))));
    }
    Json::obj(fields).to_string()
}

/// Render an error (busy = backpressure).
pub fn render_error(msg: &str, busy: bool) -> String {
    let mut fields = vec![("ok", Json::Bool(false)), ("error", Json::str(msg))];
    if busy {
        fields.push(("busy", Json::Bool(true)));
    }
    Json::obj(fields).to_string()
}

/// Render the typed BUSY backpressure response: `QueueFull` at admission
/// is not a failure but a flow-control signal, so it carries a
/// machine-readable `retry_after_ms` hint (derived from the batcher's
/// flush interval) alongside `busy: true`.
pub fn render_busy(retry_after: std::time::Duration) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str("server busy: admission queue full")),
        ("busy", Json::Bool(true)),
        ("retry_after_ms", Json::num((retry_after.as_millis().max(1)) as f64)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_generate_full() {
        let line = r#"{"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm","n_samples":2,"t0":0.8,"steps":1024,"warp":"literal","seed":7,"decode":true}"#;
        match parse_request(line).unwrap() {
            WireRequest::Generate { request, decode } => {
                assert_eq!(request.domain, "text8");
                assert_eq!(request.tag, "ws_t080");
                assert_eq!(request.n_samples, 2);
                assert!((request.t0 - 0.8).abs() < 1e-9);
                assert_eq!(request.steps_cold, 1024);
                assert_eq!(request.seed, 7);
                assert!(decode);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn parse_defaults() {
        let line = r#"{"cmd":"generate","domain":"two_moons"}"#;
        match parse_request(line).unwrap() {
            WireRequest::Generate { request, decode } => {
                assert_eq!(request.tag, "cold");
                assert_eq!(request.n_samples, 1);
                assert_eq!(request.t0, 0.0);
                assert!(!decode);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn parse_other_cmds_and_errors() {
        assert!(matches!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), WireRequest::Ping));
        assert!(matches!(parse_request(r#"{"cmd":"metrics"}"#).unwrap(), WireRequest::Metrics));
        assert!(matches!(parse_request(r#"{"cmd":"info"}"#).unwrap(), WireRequest::Info));
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no":"cmd"}"#).is_err());
        assert!(parse_request(r#"{"cmd":"explode"}"#).is_err());
        // Invalid t0 rejected at parse time.
        assert!(parse_request(r#"{"cmd":"generate","domain":"x","t0":1.5}"#).is_err());
    }

    fn resp_without_cascade() -> GenResponse {
        GenResponse {
            id: 3,
            samples: vec![vec![1, 2], vec![3, 4]],
            nfe: 205,
            t0_used: 0.8,
            cascade: None,
            queue_wait: Duration::from_micros(120),
            draft_time: Duration::from_micros(900),
            refine_time: Duration::from_micros(52_000),
            total_time: Duration::from_micros(53_100),
            degraded: None,
        }
    }

    #[test]
    fn render_roundtrip() {
        let line = render_response(&resp_without_cascade(), Some(vec!["ab".into()]));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("nfe").as_usize(), Some(205));
        assert_eq!(j.get("t0_used").as_f64(), Some(0.8));
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("texts").as_arr().unwrap()[0].as_str(), Some("ab"));
    }

    #[test]
    fn cascade_off_wire_is_byte_for_byte_the_legacy_format() {
        // Pin (b): a response produced under cascade.mode = off carries
        // no cascade fields at all — the exact pre-cascade byte layout.
        let line = render_response(&resp_without_cascade(), None);
        assert!(!line.contains("stages_used"), "{line}");
        assert!(!line.contains("nfe_stages"), "{line}");
        assert!(!line.contains("early_exit"), "{line}");
        assert!(!line.contains("degraded"), "{line}");
        let expected = concat!(
            r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
            r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
            r#""samples":[[1,2],[3,4]]}"#
        );
        assert_eq!(line, expected, "off-mode wire bytes changed");
    }

    #[test]
    fn cascade_response_carries_stage_accounting() {
        use crate::coordinator::request::CascadeInfo;
        let mut resp = resp_without_cascade();
        resp.cascade =
            Some(CascadeInfo { stages_used: 2, nfe_per_stage: vec![150, 55], early_exit: true });
        let line = render_response(&resp, None);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("stages_used").as_usize(), Some(2));
        let stages = j.get("nfe_stages").as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].as_usize(), Some(150));
        assert_eq!(stages[1].as_usize(), Some(55));
        assert_eq!(j.get("early_exit").as_bool(), Some(true));
        // Per-stage NFEs sum to the headline nfe.
        assert_eq!(j.get("nfe").as_usize(), Some(205));
    }

    #[test]
    fn degraded_response_carries_marker_and_reason() {
        let mut resp = resp_without_cascade();
        resp.degraded = Some("refine failed: all fleet replicas are down".into());
        resp.nfe = 0;
        let line = render_response(&resp, None);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true), "degraded is still a success");
        assert_eq!(j.get("degraded").as_bool(), Some(true));
        assert!(
            j.get("degraded_reason").as_str().unwrap().contains("fleet replicas"),
            "{line}"
        );
        assert_eq!(j.get("nfe").as_usize(), Some(0), "draft tokens cost zero refine NFE");
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 2, "draft samples still served");
    }

    #[test]
    fn render_error_busy() {
        let line = render_error("queue full", true);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("busy").as_bool(), Some(true));
    }

    #[test]
    fn render_busy_carries_retry_hint() {
        let line = render_busy(Duration::from_millis(7));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("busy").as_bool(), Some(true));
        assert_eq!(j.get("retry_after_ms").as_usize(), Some(7));
        assert!(j.get("error").as_str().unwrap().contains("busy"));
        // Sub-millisecond hints round up to 1 ms, never 0.
        let j = Json::parse(&render_busy(Duration::from_micros(10))).unwrap();
        assert_eq!(j.get("retry_after_ms").as_usize(), Some(1));
    }
}
