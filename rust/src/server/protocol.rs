//! Codec-agnostic wire types + the legacy JSON-lines encoding.
//!
//! This module defines *what* travels over the wire — [`WireRequest`] and
//! [`WireResponse`] — while `server::codec` defines *how* it is framed
//! (JSON lines or length-prefixed binary). The JSON render/parse helpers
//! here are the legacy one-object-per-line format, pinned byte-for-byte
//! by golden tests in `server::codec`.
//!
//! Request:
//! ```json
//! {"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm",
//!  "n_samples":2,"t0":0.8,"steps":1024,"warp":"literal","seed":7,
//!  "decode":true}
//! ```
//! Other commands: `{"cmd":"metrics"}`, `{"cmd":"info"}`, `{"cmd":"ping"}`,
//! the observability surface `{"cmd":"stats"}` (typed metrics snapshot) and
//! `{"cmd":"trace","request_id":7}` (span journal lookup),
//! and the codec hello `{"cmd":"hello","codecs":["binary","json"]}`.
//!
//! A generate request may opt into a per-response timing breakdown with
//! `"timing":true`; the response then carries a `"timing"` object. Both
//! the flag and the object are **absent** from the wire unless requested,
//! so the legacy byte-pinned encodings are unchanged.
//!
//! Response (generate):
//! ```json
//! {"ok":true,"id":3,"nfe":205,"queue_us":120,"draft_us":900,
//!  "refine_us":52000,"total_us":53100,"samples":[[1,2,...]],
//!  "texts":["the old city ..."]}
//! ```
//! Errors: `{"ok":false,"error":"...","busy":true?}`.

use crate::coordinator::request::{CascadeInfo, DraftSpec, GenRequest, GenResponse, TimingInfo};
use crate::core::schedule::WarpMode;
use crate::metrics::MetricsSnapshot;
use crate::obs::{SpanKind, SpanRecord};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// Parsed wire command.
#[derive(Debug, Clone, PartialEq)]
pub enum WireRequest {
    Generate { request: GenRequest, decode: bool },
    Metrics,
    Info,
    Ping,
    Shutdown,
    /// Typed metrics snapshot (serving + optional fleet) — the PR-9
    /// observability surface, machine-readable on both codecs.
    Stats,
    /// Span-journal lookup for one wire request id. Unknown ids get a
    /// typed error reply, never a hang.
    Trace { request_id: u64 },
    /// Codec negotiation: client's supported codec names in preference
    /// order. Absent hello ⇒ the connection stays on the server's
    /// default codec (legacy JSON), so old clients work unchanged.
    Hello { codecs: Vec<String> },
}

/// Typed wire response — everything the server can say. Each variant has
/// a pinned legacy JSON encoding (see the render helpers) and a binary
/// encoding in `server::codec`.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResponse {
    Generate { resp: GenResponse, texts: Option<Vec<String>> },
    Error { msg: String, busy: bool },
    /// Typed backpressure: flow control, not failure.
    Busy { retry_after_ms: u64 },
    Pong,
    Metrics { report: String, samples_per_sec: f64, completed: u64, rejected: u64 },
    Info { domains: Vec<String>, artifacts: usize },
    /// Typed metrics snapshot: the structured counterpart of the legacy
    /// string-valued `Metrics` reply.
    Stats { snapshot: MetricsSnapshot },
    /// Every retained span for one request, joined across its bundle and
    /// sorted by start time.
    Trace { request_id: u64, spans: Vec<SpanRecord> },
    ShutdownAck,
    /// Negotiation accept: the codec every subsequent message uses.
    HelloAck { codec: String },
}

/// Parse one JSON request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line.trim()).context("malformed json")?;
    let cmd = j.get("cmd").as_str().context("missing cmd")?;
    match cmd {
        "ping" => Ok(WireRequest::Ping),
        "metrics" => Ok(WireRequest::Metrics),
        "info" => Ok(WireRequest::Info),
        "shutdown" => Ok(WireRequest::Shutdown),
        "stats" => Ok(WireRequest::Stats),
        "trace" => {
            let request_id = j.get("request_id").as_u64().context("trace missing request_id")?;
            Ok(WireRequest::Trace { request_id })
        }
        "hello" => {
            let codecs = j
                .get("codecs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|c| c.as_str().map(str::to_string))
                .collect();
            Ok(WireRequest::Hello { codecs })
        }
        "generate" => {
            let domain = j.get("domain").as_str().context("missing domain")?.to_string();
            let tag = j.get("tag").as_str().unwrap_or("cold").to_string();
            let draft = DraftSpec::parse(j.get("draft").as_str().unwrap_or("noise"))?;
            let n_samples = j.get("n_samples").as_u64().map(|n| n as usize).unwrap_or(1);
            let t0 = j.get("t0").as_f64().unwrap_or(0.0);
            let steps_cold = j.get("steps").as_u64().map(|n| n as usize).unwrap_or(128);
            let warp_mode = WarpMode::parse(j.get("warp").as_str().unwrap_or("literal"))?;
            // Integer-preserving: seeds above 2^53 must not round
            // through f64 (`as_f64() as u64` silently corrupted them).
            let seed = j.get("seed").as_u64().unwrap_or(0);
            let decode = j.get("decode").as_bool().unwrap_or(false);
            let mut request =
                GenRequest::from_wire(domain, tag, draft, n_samples, t0, steps_cold, warp_mode, seed)?;
            // Opt-in timing breakdown; absent ⇒ false, keeping legacy
            // request lines parsing (and rendering) unchanged.
            request.timing = j.get("timing").as_bool().unwrap_or(false);
            Ok(WireRequest::Generate { request, decode })
        }
        other => bail!("unknown cmd {other:?}"),
    }
}

/// Render one request as a legacy JSON line (client side).
pub fn render_request(req: &WireRequest) -> String {
    match req {
        WireRequest::Ping => r#"{"cmd":"ping"}"#.to_string(),
        WireRequest::Metrics => r#"{"cmd":"metrics"}"#.to_string(),
        WireRequest::Info => r#"{"cmd":"info"}"#.to_string(),
        WireRequest::Shutdown => r#"{"cmd":"shutdown"}"#.to_string(),
        WireRequest::Stats => r#"{"cmd":"stats"}"#.to_string(),
        WireRequest::Trace { request_id } => Json::obj(vec![
            ("cmd", Json::str("trace")),
            ("request_id", Json::u64(*request_id)),
        ])
        .to_string(),
        WireRequest::Hello { codecs } => Json::obj(vec![
            ("cmd", Json::str("hello")),
            ("codecs", Json::arr(codecs.iter().map(|c| Json::str(c.clone())))),
        ])
        .to_string(),
        WireRequest::Generate { request: r, decode } => {
            let mut fields = vec![
                ("cmd", Json::str("generate")),
                ("domain", Json::str(r.domain.clone())),
                ("tag", Json::str(r.tag.clone())),
                ("draft", Json::str(r.draft.name())),
                ("n_samples", Json::u64(r.n_samples as u64)),
                ("t0", Json::num(r.t0)),
                ("steps", Json::u64(r.steps_cold as u64)),
                ("warp", Json::str(r.warp_mode.name())),
                ("seed", Json::u64(r.seed)),
                ("decode", Json::Bool(*decode)),
            ];
            // Only emitted when set: a non-timing request line stays
            // byte-identical to the pre-PR-9 encoding.
            if r.timing {
                fields.push(("timing", Json::Bool(true)));
            }
            Json::obj(fields).to_string()
        }
    }
}

/// Render a successful generate response. `texts` is optional decoded
/// output (char/word domains).
///
/// Cascade stage accounting (`stages_used`, per-stage `nfe_stages`,
/// `early_exit`) is emitted only when the bundle ran under a cascade
/// mode, and the degradation marker (`degraded: true` plus
/// `degraded_reason`) only when refinement failed and the coordinator
/// served draft tokens — with `cascade.mode = off` and refinement
/// healthy the response stays **byte-for-byte** the pre-cascade wire
/// format (pinned by tests).
pub fn render_response(resp: &GenResponse, texts: Option<&[String]>) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", Json::u64(resp.id)),
        ("nfe", Json::u64(resp.nfe as u64)),
        ("t0_used", Json::num(resp.t0_used)),
        ("queue_us", Json::u64(resp.queue_wait.as_micros() as u64)),
        ("draft_us", Json::u64(resp.draft_time.as_micros() as u64)),
        ("refine_us", Json::u64(resp.refine_time.as_micros() as u64)),
        ("total_us", Json::u64(resp.total_time.as_micros() as u64)),
    ];
    if let Some(c) = &resp.cascade {
        fields.push(("stages_used", Json::u64(c.stages_used as u64)));
        fields.push((
            "nfe_stages",
            Json::arr(c.nfe_per_stage.iter().map(|&n| Json::u64(n as u64))),
        ));
        fields.push(("early_exit", Json::Bool(c.early_exit)));
    }
    if let Some(reason) = &resp.degraded {
        fields.push(("degraded", Json::Bool(true)));
        fields.push(("degraded_reason", Json::str(reason)));
    }
    // Present only on `"timing":true` requests — requests that don't opt
    // in keep the exact legacy byte layout (pinned below and in codec).
    if let Some(t) = &resp.timing {
        fields.push(("timing", timing_to_json(t)));
    }
    fields.push((
        "samples",
        Json::arr(
            resp.samples.iter().map(|row| Json::arr(row.iter().map(|&t| Json::num(t as f64)))),
        ),
    ));
    if let Some(ts) = texts {
        fields.push(("texts", Json::arr(ts.iter().map(|t| Json::str(t.clone())))));
    }
    Json::obj(fields).to_string()
}

/// JSON encoding of the opt-in per-response timing breakdown.
fn timing_to_json(t: &TimingInfo) -> Json {
    Json::obj(vec![
        ("nfe_floor", Json::u64(t.nfe_floor as u64)),
        (
            "segments",
            Json::arr(
                t.segments
                    .iter()
                    .map(|&(nfe, us)| Json::arr(vec![Json::u64(nfe as u64), Json::u64(us)])),
            ),
        ),
        ("gate_us", Json::arr(t.gate_us.iter().map(|&us| Json::u64(us)))),
        ("replicas", Json::arr(t.replicas.iter().map(|&r| Json::u64(r as u64)))),
        ("reroutes", Json::u64(t.reroutes as u64)),
    ])
}

fn timing_from_json(j: &Json) -> TimingInfo {
    TimingInfo {
        nfe_floor: j.get("nfe_floor").as_usize().unwrap_or(0),
        segments: j
            .get("segments")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|pair| {
                let p = pair.as_arr().unwrap_or(&[]);
                (
                    p.first().and_then(Json::as_usize).unwrap_or(0),
                    p.get(1).and_then(Json::as_u64).unwrap_or(0),
                )
            })
            .collect(),
        gate_us: j.get("gate_us").as_arr().unwrap_or(&[]).iter().filter_map(Json::as_u64).collect(),
        replicas: j
            .get("replicas")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|r| r.as_u64().map(|v| v as u32))
            .collect(),
        reroutes: j.get("reroutes").as_u64().unwrap_or(0) as u32,
    }
}

/// JSON encoding of one trace span (kind as its human-readable name).
fn span_to_json(s: &SpanRecord) -> Json {
    Json::obj(vec![
        ("request_id", Json::u64(s.request_id)),
        ("bundle_id", Json::u64(s.bundle_id)),
        ("kind", Json::str(s.kind.name())),
        ("detail", Json::u64(s.detail as u64)),
        ("start_us", Json::u64(s.start_us)),
        ("dur_us", Json::u64(s.dur_us)),
    ])
}

fn span_from_json(j: &Json) -> Result<SpanRecord> {
    let name = j.get("kind").as_str().context("span missing kind")?;
    let kind = (0..SpanKind::COUNT as u8)
        .filter_map(SpanKind::from_u8)
        .find(|k| k.name() == name)
        .with_context(|| format!("unknown span kind {name:?}"))?;
    Ok(SpanRecord {
        request_id: j.get("request_id").as_u64().unwrap_or(0),
        bundle_id: j.get("bundle_id").as_u64().unwrap_or(0),
        kind,
        detail: j.get("detail").as_u64().unwrap_or(0) as u32,
        start_us: j.get("start_us").as_u64().unwrap_or(0),
        dur_us: j.get("dur_us").as_u64().unwrap_or(0),
    })
}

/// Render an error (busy = backpressure).
pub fn render_error(msg: &str, busy: bool) -> String {
    let mut fields = vec![("ok", Json::Bool(false)), ("error", Json::str(msg))];
    if busy {
        fields.push(("busy", Json::Bool(true)));
    }
    Json::obj(fields).to_string()
}

/// Render the typed BUSY backpressure response: `QueueFull` at admission
/// is not a failure but a flow-control signal, so it carries a
/// machine-readable `retry_after_ms` hint (derived from the batcher's
/// flush interval) alongside `busy: true`.
pub fn render_busy(retry_after: Duration) -> String {
    render_busy_ms((retry_after.as_millis().max(1)) as u64)
}

fn render_busy_ms(retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str("server busy: admission queue full")),
        ("busy", Json::Bool(true)),
        ("retry_after_ms", Json::u64(retry_after_ms)),
    ])
    .to_string()
}

/// Render any [`WireResponse`] as its pinned legacy JSON line.
pub fn render_wire_response(resp: &WireResponse) -> String {
    match resp {
        WireResponse::Generate { resp, texts } => render_response(resp, texts.as_deref()),
        WireResponse::Error { msg, busy } => render_error(msg, *busy),
        WireResponse::Busy { retry_after_ms } => render_busy_ms((*retry_after_ms).max(1)),
        WireResponse::Pong => {
            Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]).to_string()
        }
        WireResponse::Metrics { report, samples_per_sec, completed, rejected } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", Json::str(report.clone())),
            ("samples_per_sec", Json::num(*samples_per_sec)),
            ("completed", Json::u64(*completed)),
            ("rejected", Json::u64(*rejected)),
        ])
        .to_string(),
        WireResponse::Info { domains, artifacts } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("domains", Json::arr(domains.iter().map(|d| Json::str(d.clone())))),
            ("artifacts", Json::u64(*artifacts as u64)),
        ])
        .to_string(),
        WireResponse::Stats { snapshot } => {
            Json::obj(vec![("ok", Json::Bool(true)), ("stats", snapshot.to_json())]).to_string()
        }
        WireResponse::Trace { request_id, spans } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("request_id", Json::u64(*request_id)),
            ("spans", Json::arr(spans.iter().map(span_to_json))),
        ])
        .to_string(),
        WireResponse::ShutdownAck => Json::obj(vec![("ok", Json::Bool(true))]).to_string(),
        WireResponse::HelloAck { codec } => {
            Json::obj(vec![("ok", Json::Bool(true)), ("codec", Json::str(codec.clone()))])
                .to_string()
        }
    }
}

/// Parse one JSON response line back into the typed [`WireResponse`]
/// (client side). Inverse of [`render_wire_response`] up to the
/// microsecond granularity the encoding itself carries.
pub fn parse_response(line: &str) -> Result<WireResponse> {
    let j = Json::parse(line.trim()).context("malformed json")?;
    let ok = j.get("ok").as_bool().context("missing ok")?;
    if !ok {
        let msg = j.get("error").as_str().unwrap_or("?").to_string();
        let busy = j.get("busy").as_bool().unwrap_or(false);
        if busy && !j.get("retry_after_ms").is_null() {
            return Ok(WireResponse::Busy {
                retry_after_ms: j.get("retry_after_ms").as_u64().unwrap_or(1).max(1),
            });
        }
        return Ok(WireResponse::Error { msg, busy });
    }
    if j.get("pong").as_bool() == Some(true) {
        return Ok(WireResponse::Pong);
    }
    if let Some(codec) = j.get("codec").as_str() {
        return Ok(WireResponse::HelloAck { codec: codec.to_string() });
    }
    if let Some(report) = j.get("metrics").as_str() {
        return Ok(WireResponse::Metrics {
            report: report.to_string(),
            samples_per_sec: j.get("samples_per_sec").as_f64().unwrap_or(0.0),
            completed: j.get("completed").as_u64().unwrap_or(0),
            rejected: j.get("rejected").as_u64().unwrap_or(0),
        });
    }
    if !j.get("stats").is_null() {
        return Ok(WireResponse::Stats { snapshot: MetricsSnapshot::from_json(j.get("stats")) });
    }
    if !j.get("spans").is_null() {
        let spans = j
            .get("spans")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(span_from_json)
            .collect::<Result<Vec<_>>>()?;
        return Ok(WireResponse::Trace {
            request_id: j.get("request_id").as_u64().context("trace reply missing request_id")?,
            spans,
        });
    }
    if !j.get("domains").is_null() {
        let domains = j
            .get("domains")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_str().map(str::to_string))
            .collect();
        return Ok(WireResponse::Info {
            domains,
            artifacts: j.get("artifacts").as_usize().unwrap_or(0),
        });
    }
    if !j.get("samples").is_null() {
        let samples = j
            .get("samples")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                row.as_arr().unwrap_or(&[]).iter().map(|v| v.as_i64().unwrap_or(0) as i32).collect()
            })
            .collect();
        let cascade = if !j.get("stages_used").is_null() {
            Some(CascadeInfo {
                stages_used: j.get("stages_used").as_usize().unwrap_or(0),
                nfe_per_stage: j
                    .get("nfe_stages")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|n| n.as_usize())
                    .collect(),
                early_exit: j.get("early_exit").as_bool().unwrap_or(false),
            })
        } else {
            None
        };
        let texts = if j.get("texts").is_null() {
            None
        } else {
            Some(
                j.get("texts")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|t| t.as_str().map(str::to_string))
                    .collect(),
            )
        };
        let resp = GenResponse {
            id: j.get("id").as_u64().unwrap_or(0),
            samples,
            nfe: j.get("nfe").as_usize().unwrap_or(0),
            t0_used: j.get("t0_used").as_f64().unwrap_or(0.0),
            cascade,
            queue_wait: Duration::from_micros(j.get("queue_us").as_u64().unwrap_or(0)),
            draft_time: Duration::from_micros(j.get("draft_us").as_u64().unwrap_or(0)),
            refine_time: Duration::from_micros(j.get("refine_us").as_u64().unwrap_or(0)),
            total_time: Duration::from_micros(j.get("total_us").as_u64().unwrap_or(0)),
            degraded: j.get("degraded_reason").as_str().map(str::to_string),
            timing: if j.get("timing").is_null() {
                None
            } else {
                Some(timing_from_json(j.get("timing")))
            },
        };
        return Ok(WireResponse::Generate { resp, texts });
    }
    Ok(WireResponse::ShutdownAck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_generate_full() {
        let line = r#"{"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm","n_samples":2,"t0":0.8,"steps":1024,"warp":"literal","seed":7,"decode":true}"#;
        match parse_request(line).unwrap() {
            WireRequest::Generate { request, decode } => {
                assert_eq!(request.domain, "text8");
                assert_eq!(request.tag, "ws_t080");
                assert_eq!(request.n_samples, 2);
                assert!((request.t0 - 0.8).abs() < 1e-9);
                assert_eq!(request.steps_cold, 1024);
                assert_eq!(request.seed, 7);
                assert!(decode);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn parse_defaults() {
        let line = r#"{"cmd":"generate","domain":"two_moons"}"#;
        match parse_request(line).unwrap() {
            WireRequest::Generate { request, decode } => {
                assert_eq!(request.tag, "cold");
                assert_eq!(request.n_samples, 1);
                assert_eq!(request.t0, 0.0);
                assert!(!decode);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    /// Satellite pin: seeds above 2^53 survive the wire exactly. The old
    /// `as_f64() as u64` path would have rounded u64::MAX to 2^64 (and
    /// then saturated), corrupting the request's reproducibility seed.
    #[test]
    fn parse_seed_is_exact_at_u64_max() {
        let line = format!(
            r#"{{"cmd":"generate","domain":"text8","seed":{}}}"#,
            u64::MAX
        );
        match parse_request(&line).unwrap() {
            WireRequest::Generate { request, .. } => {
                assert_eq!(request.seed, u64::MAX);
                // And the client-side encoding round-trips it.
                let back = render_request(&WireRequest::Generate {
                    request: request.clone(),
                    decode: false,
                });
                assert!(back.contains(&u64::MAX.to_string()), "{back}");
                match parse_request(&back).unwrap() {
                    WireRequest::Generate { request: again, .. } => {
                        assert_eq!(again.seed, u64::MAX)
                    }
                    other => panic!("wrong parse: {other:?}"),
                }
            }
            other => panic!("wrong parse: {other:?}"),
        }
        // 2^53 + 1: the first integer f64 silently mangles.
        let line = r#"{"cmd":"generate","domain":"x","seed":9007199254740993}"#;
        match parse_request(line).unwrap() {
            WireRequest::Generate { request, .. } => {
                assert_eq!(request.seed, 9_007_199_254_740_993)
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn parse_hello() {
        let line = r#"{"cmd":"hello","codecs":["binary","json"]}"#;
        match parse_request(line).unwrap() {
            WireRequest::Hello { codecs } => assert_eq!(codecs, vec!["binary", "json"]),
            other => panic!("wrong parse: {other:?}"),
        }
        // Hello with no codec list parses as an empty offer.
        match parse_request(r#"{"cmd":"hello"}"#).unwrap() {
            WireRequest::Hello { codecs } => assert!(codecs.is_empty()),
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn parse_other_cmds_and_errors() {
        assert!(matches!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), WireRequest::Ping));
        assert!(matches!(parse_request(r#"{"cmd":"metrics"}"#).unwrap(), WireRequest::Metrics));
        assert!(matches!(parse_request(r#"{"cmd":"info"}"#).unwrap(), WireRequest::Info));
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no":"cmd"}"#).is_err());
        assert!(parse_request(r#"{"cmd":"explode"}"#).is_err());
        // Invalid t0 rejected at parse time.
        assert!(parse_request(r#"{"cmd":"generate","domain":"x","t0":1.5}"#).is_err());
    }

    fn resp_without_cascade() -> GenResponse {
        GenResponse {
            id: 3,
            samples: vec![vec![1, 2], vec![3, 4]],
            nfe: 205,
            t0_used: 0.8,
            cascade: None,
            queue_wait: Duration::from_micros(120),
            draft_time: Duration::from_micros(900),
            refine_time: Duration::from_micros(52_000),
            total_time: Duration::from_micros(53_100),
            degraded: None,
            timing: None,
        }
    }

    #[test]
    fn render_roundtrip() {
        let line = render_response(&resp_without_cascade(), Some(&["ab".to_string()]));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("nfe").as_usize(), Some(205));
        assert_eq!(j.get("t0_used").as_f64(), Some(0.8));
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("texts").as_arr().unwrap()[0].as_str(), Some("ab"));
    }

    #[test]
    fn cascade_off_wire_is_byte_for_byte_the_legacy_format() {
        // Pin (b): a response produced under cascade.mode = off carries
        // no cascade fields at all — the exact pre-cascade byte layout.
        let line = render_response(&resp_without_cascade(), None);
        assert!(!line.contains("stages_used"), "{line}");
        assert!(!line.contains("nfe_stages"), "{line}");
        assert!(!line.contains("early_exit"), "{line}");
        assert!(!line.contains("degraded"), "{line}");
        assert!(!line.contains("timing"), "non-opted response must omit timing: {line}");
        let expected = concat!(
            r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
            r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
            r#""samples":[[1,2],[3,4]]}"#
        );
        assert_eq!(line, expected, "off-mode wire bytes changed");
    }

    #[test]
    fn cascade_response_carries_stage_accounting() {
        use crate::coordinator::request::CascadeInfo;
        let mut resp = resp_without_cascade();
        resp.cascade =
            Some(CascadeInfo { stages_used: 2, nfe_per_stage: vec![150, 55], early_exit: true });
        let line = render_response(&resp, None);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("stages_used").as_usize(), Some(2));
        let stages = j.get("nfe_stages").as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].as_usize(), Some(150));
        assert_eq!(stages[1].as_usize(), Some(55));
        assert_eq!(j.get("early_exit").as_bool(), Some(true));
        // Per-stage NFEs sum to the headline nfe.
        assert_eq!(j.get("nfe").as_usize(), Some(205));
    }

    #[test]
    fn degraded_response_carries_marker_and_reason() {
        let mut resp = resp_without_cascade();
        resp.degraded = Some("refine failed: all fleet replicas are down".into());
        resp.nfe = 0;
        let line = render_response(&resp, None);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true), "degraded is still a success");
        assert_eq!(j.get("degraded").as_bool(), Some(true));
        assert!(
            j.get("degraded_reason").as_str().unwrap().contains("fleet replicas"),
            "{line}"
        );
        assert_eq!(j.get("nfe").as_usize(), Some(0), "draft tokens cost zero refine NFE");
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 2, "draft samples still served");
    }

    #[test]
    fn render_error_busy() {
        let line = render_error("queue full", true);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("busy").as_bool(), Some(true));
    }

    #[test]
    fn render_busy_carries_retry_hint() {
        let line = render_busy(Duration::from_millis(7));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("busy").as_bool(), Some(true));
        assert_eq!(j.get("retry_after_ms").as_usize(), Some(7));
        assert!(j.get("error").as_str().unwrap().contains("busy"));
        // Sub-millisecond hints round up to 1 ms, never 0.
        let j = Json::parse(&render_busy(Duration::from_micros(10))).unwrap();
        assert_eq!(j.get("retry_after_ms").as_usize(), Some(1));
    }

    /// Every response variant parses back to itself from its JSON line
    /// (micro-granularity is all the encoding carries, so equality is
    /// exact on re-parsed values).
    #[test]
    fn json_response_parse_inverts_render() {
        let cases = vec![
            WireResponse::Pong,
            WireResponse::ShutdownAck,
            WireResponse::HelloAck { codec: "binary".into() },
            WireResponse::Error { msg: "nope".into(), busy: false },
            WireResponse::Error { msg: "overload".into(), busy: true },
            WireResponse::Busy { retry_after_ms: 9 },
            WireResponse::Metrics {
                report: "r\nmultiline".into(),
                samples_per_sec: 12.5,
                completed: 3,
                rejected: 1,
            },
            WireResponse::Info { domains: vec!["text8".into(), "wiki".into()], artifacts: 7 },
            WireResponse::Generate { resp: resp_without_cascade(), texts: None },
            WireResponse::Generate {
                resp: GenResponse {
                    cascade: Some(CascadeInfo {
                        stages_used: 2,
                        nfe_per_stage: vec![150, 55],
                        early_exit: false,
                    }),
                    degraded: Some("draft fallback".into()),
                    ..resp_without_cascade()
                },
                texts: Some(vec!["ab".into()]),
            },
            WireResponse::Generate {
                resp: GenResponse { timing: Some(timing_fixture()), ..resp_without_cascade() },
                texts: None,
            },
            WireResponse::Stats { snapshot: MetricsSnapshot::default() },
            WireResponse::Trace { request_id: 7, spans: vec![] },
            WireResponse::Trace { request_id: 9, spans: span_fixtures() },
        ];
        for want in cases {
            let line = render_wire_response(&want);
            let got = parse_response(&line).unwrap();
            assert_eq!(got, want, "parse(render(x)) != x for {line}");
        }
    }

    fn timing_fixture() -> TimingInfo {
        TimingInfo {
            nfe_floor: 55,
            segments: vec![(150, 41_000), (55, 11_000)],
            gate_us: vec![12, 9],
            replicas: vec![0, 2],
            reroutes: 1,
        }
    }

    fn span_fixtures() -> Vec<SpanRecord> {
        vec![
            SpanRecord {
                request_id: 9,
                bundle_id: 4,
                kind: SpanKind::Admit,
                detail: 0,
                start_us: 10,
                dur_us: 3,
            },
            SpanRecord {
                request_id: 0,
                bundle_id: 4,
                kind: SpanKind::EngineCall,
                detail: 2,
                start_us: 40,
                dur_us: 1_200,
            },
        ]
    }

    #[test]
    fn parse_stats_and_trace_requests() {
        assert!(matches!(parse_request(r#"{"cmd":"stats"}"#).unwrap(), WireRequest::Stats));
        match parse_request(r#"{"cmd":"trace","request_id":12}"#).unwrap() {
            WireRequest::Trace { request_id } => assert_eq!(request_id, 12),
            other => panic!("wrong parse: {other:?}"),
        }
        // A trace probe without a request id is a typed parse error, not
        // a silently-defaulted lookup of request 0.
        let err = parse_request(r#"{"cmd":"trace"}"#).unwrap_err();
        assert!(format!("{err:#}").contains("request_id"), "{err:#}");
        // Round-trip through the client-side renderers.
        assert_eq!(render_request(&WireRequest::Stats), r#"{"cmd":"stats"}"#);
        let line = render_request(&WireRequest::Trace { request_id: 12 });
        assert_eq!(line, r#"{"cmd":"trace","request_id":12}"#);
        assert_eq!(parse_request(&line).unwrap(), WireRequest::Trace { request_id: 12 });
    }

    #[test]
    fn timing_flag_is_opt_in_on_the_request_line() {
        let req = GenRequest::from_wire(
            "text8".into(),
            "ws_t080".into(),
            DraftSpec::Lstm,
            1,
            0.8,
            128,
            WarpMode::Literal,
            7,
        )
        .unwrap();
        // Off (the default): the rendered line carries no timing key —
        // byte-compatible with every pre-PR-9 client and server.
        let line =
            render_request(&WireRequest::Generate { request: req.clone(), decode: false });
        assert!(!line.contains("timing"), "{line}");
        // On: the flag renders and parses back.
        let mut on = req;
        on.timing = true;
        let line = render_request(&WireRequest::Generate { request: on, decode: false });
        assert!(line.contains(r#""timing":true"#), "{line}");
        match parse_request(&line).unwrap() {
            WireRequest::Generate { request, .. } => assert!(request.timing),
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn timing_breakdown_renders_and_parses_exactly() {
        let resp =
            GenResponse { timing: Some(timing_fixture()), ..resp_without_cascade() };
        let line = render_response(&resp, None);
        let j = Json::parse(&line).unwrap();
        let t = j.get("timing");
        assert_eq!(t.get("nfe_floor").as_usize(), Some(55));
        let segs = t.get("segments").as_arr().unwrap();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].as_arr().unwrap()[0].as_usize(), Some(150));
        assert_eq!(segs[0].as_arr().unwrap()[1].as_u64(), Some(41_000));
        assert_eq!(t.get("reroutes").as_u64(), Some(1));
        match parse_response(&line).unwrap() {
            WireResponse::Generate { resp: got, .. } => {
                assert_eq!(got.timing, Some(timing_fixture()))
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn trace_reply_carries_named_span_kinds() {
        let line = render_wire_response(&WireResponse::Trace {
            request_id: 9,
            spans: span_fixtures(),
        });
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("request_id").as_u64(), Some(9));
        let spans = j.get("spans").as_arr().unwrap();
        assert_eq!(spans[0].get("kind").as_str(), Some("admit"));
        assert_eq!(spans[1].get("kind").as_str(), Some("engine_call"));
        assert_eq!(spans[1].get("detail").as_u64(), Some(2));
        // An unknown kind name is a typed parse error on the client.
        let bad = line.replace("engine_call", "warp_core");
        assert!(parse_response(&bad).is_err());
    }
}
