//! Blocking line-protocol client (used by examples, integration tests, and
//! the load-generator in `examples/serve_text.rs`).
//!
//! BUSY responses are flow control, not failures: [`Client::generate`]
//! surfaces them as the typed [`Busy`] error carrying the server's
//! `retry_after_ms` hint, and [`Client::generate_retry`] honors the hint
//! with capped exponential backoff and deterministic jitter drawn from
//! the stateless RNG substreams ([`crate::core::rng::Pcg64::substream`]) —
//! concurrent clients with distinct seeds desynchronize instead of
//! stampeding the admission queue in lockstep.

use crate::core::rng::Pcg64;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Typed BUSY rejection: the server applied backpressure and suggested
/// when to retry. Downcast from [`Client::generate`]'s error to tell
/// flow control apart from real failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    /// The server's `retry_after_ms` hint (>= 1).
    pub retry_after_ms: u64,
}

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server busy (retry after {} ms)", self.retry_after_ms)
    }
}

impl std::error::Error for Busy {}

/// Typed give-up: [`Client::generate_retry`] exhausted its total
/// wall-clock `deadline` while the server kept answering BUSY. Distinct
/// from [`Busy`] (one rejection, retryable) — this is the client-side
/// latency budget saying stop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryDeadline {
    /// Total wall-clock spent (ms) when the budget ran out.
    pub waited_ms: u64,
    /// BUSY retries performed before giving up.
    pub attempts: u32,
}

impl std::fmt::Display for RetryDeadline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gave up after {} ms and {} busy retries (retry deadline exceeded)",
            self.waited_ms, self.attempts
        )
    }
}

impl std::error::Error for RetryDeadline {}

/// Backoff policy for BUSY retries: the sleep before retry `attempt`
/// starts from the server's live `retry_after_ms` hint, doubles per
/// attempt, is capped at `cap`, and is jittered into `[delay/2, delay]`
/// by a stateless substream of `seed` — fully deterministic per
/// `(seed, attempt, hint)`, no shared RNG state across clients.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries after the initial attempt (0 = surface BUSY immediately).
    pub max_retries: u32,
    /// Upper bound on any single backoff sleep.
    pub cap: Duration,
    /// Jitter substream seed; give concurrent clients distinct seeds.
    pub seed: u64,
    /// Total wall-clock budget across all attempts and sleeps: a retry
    /// whose backoff would cross it gives up with the typed
    /// [`RetryDeadline`] instead of sleeping. `None` = retries bounded
    /// only by `max_retries`.
    pub deadline: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 8, cap: Duration::from_millis(250), seed: 0, deadline: None }
    }
}

/// Substream lane for retry-jitter draws (distinct from the sampler's
/// step/row coordinates by construction: policy-local seed space).
const JITTER_LANE: u64 = 0xB0FF;

impl RetryPolicy {
    /// The backoff before 0-based retry `attempt`, given the server's
    /// most recent `retry_after_ms` hint.
    pub fn backoff(&self, attempt: u32, hint_ms: u64) -> Duration {
        let cap_ms = (self.cap.as_millis() as u64).max(1);
        let exp = hint_ms.max(1).saturating_mul(1u64 << attempt.min(16)).min(cap_ms);
        let half = (exp / 2).max(1);
        let mut rng = Pcg64::substream(self.seed, attempt as u64, JITTER_LANE);
        let jittered = half + rng.below((exp - half + 1).min(u32::MAX as u64) as u32) as u64;
        Duration::from_millis(jittered.min(cap_ms))
    }
}

/// One connection to a `wsfm serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Parsed generate reply.
#[derive(Debug, Clone)]
pub struct GenerateReply {
    pub nfe: usize,
    pub total_us: u64,
    pub queue_us: u64,
    pub samples: Vec<Vec<i32>>,
    pub texts: Vec<String>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(600)))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON line, read one JSON line.
    pub fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            bail!("server closed connection");
        }
        Ok(Json::parse(&reply)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(r#"{"cmd":"ping"}"#)?;
        Ok(j.get("pong").as_bool().unwrap_or(false))
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"metrics"}"#)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(r#"{"cmd":"shutdown"}"#)?;
        Ok(())
    }

    /// Issue a generate command.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
        decode: bool,
    ) -> Result<GenerateReply> {
        let req = Json::obj(vec![
            ("cmd", Json::str("generate")),
            ("domain", Json::str(domain)),
            ("tag", Json::str(tag)),
            ("draft", Json::str(draft)),
            ("n_samples", Json::num(n_samples as f64)),
            ("t0", Json::num(t0)),
            ("steps", Json::num(steps as f64)),
            ("seed", Json::num(seed as f64)),
            ("decode", Json::Bool(decode)),
        ]);
        let j = self.roundtrip(&req.to_string())?;
        if j.get("ok").as_bool() != Some(true) {
            if j.get("busy").as_bool().unwrap_or(false) {
                // Typed flow-control signal: callers (and generate_retry)
                // downcast to Busy and back off by the server's hint.
                let retry_after_ms = j.get("retry_after_ms").as_usize().unwrap_or(1).max(1) as u64;
                return Err(anyhow::Error::new(Busy { retry_after_ms }));
            }
            bail!("generate failed: {}", j.get("error").as_str().unwrap_or("?"));
        }
        let samples = j
            .get("samples")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| v.as_i64().unwrap_or(0) as i32)
                    .collect()
            })
            .collect();
        let texts = j
            .get("texts")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|t| t.as_str().map(|s| s.to_string()))
            .collect();
        Ok(GenerateReply {
            nfe: j.get("nfe").as_usize().unwrap_or(0),
            total_us: j.get("total_us").as_f64().unwrap_or(0.0) as u64,
            queue_us: j.get("queue_us").as_f64().unwrap_or(0.0) as u64,
            samples,
            texts,
        })
    }

    /// [`Client::generate`] that honors BUSY backpressure: on a [`Busy`]
    /// rejection it sleeps `policy.backoff(attempt, hint)` and retries, up
    /// to `policy.max_retries` times — within `policy.deadline` of total
    /// wall-clock, if set: when the next sleep would cross the budget it
    /// gives up with the typed [`RetryDeadline`] instead. Real failures
    /// (non-BUSY) are never retried. Returns the reply plus how many
    /// retries it took (0 = first try).
    #[allow(clippy::too_many_arguments)]
    pub fn generate_retry(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
        decode: bool,
        policy: &RetryPolicy,
    ) -> Result<(GenerateReply, u32)> {
        let started = Instant::now();
        let mut attempt = 0u32;
        loop {
            match self.generate(domain, tag, draft, n_samples, t0, steps, seed, decode) {
                Ok(reply) => return Ok((reply, attempt)),
                Err(e) => match e.downcast_ref::<Busy>() {
                    Some(busy) if attempt < policy.max_retries => {
                        let delay = policy.backoff(attempt, busy.retry_after_ms);
                        if let Some(deadline) = policy.deadline {
                            let waited = started.elapsed();
                            if waited + delay > deadline {
                                return Err(anyhow::Error::new(RetryDeadline {
                                    waited_ms: waited.as_millis() as u64,
                                    attempts: attempt,
                                }));
                            }
                        }
                        std::thread::sleep(delay);
                        attempt += 1;
                    }
                    _ => return Err(e),
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WsfmConfig;
    use crate::coordinator::testutil::{mock_manifest, TestExec};
    use crate::coordinator::Service;
    use crate::server::TcpServer;
    use std::sync::atomic::Ordering;

    #[test]
    fn backoff_grows_exponentially_capped_and_jittered() {
        let p = RetryPolicy {
            max_retries: 8,
            cap: Duration::from_millis(100),
            seed: 7,
            deadline: None,
        };
        // Every backoff stays within [hint/2 * 2^k floor, cap].
        let mut prev_hi = 0u64;
        for attempt in 0..8 {
            let d = p.backoff(attempt, 5).as_millis() as u64;
            let exp = (5u64 << attempt).min(100);
            assert!(d >= (exp / 2).max(1), "attempt {attempt}: {d} < {}", exp / 2);
            assert!(d <= 100, "attempt {attempt}: {d} beyond cap");
            prev_hi = prev_hi.max(d);
        }
        assert!(prev_hi >= 50, "later attempts should reach the cap region, max seen {prev_hi}");
        // Deterministic per (seed, attempt, hint); distinct seeds jitter
        // differently somewhere in the schedule.
        assert_eq!(p.backoff(3, 5), p.backoff(3, 5));
        let q = RetryPolicy { seed: 8, ..p.clone() };
        assert!(
            (0..8).any(|a| p.backoff(a, 5) != q.backoff(a, 5)),
            "distinct seeds should desynchronize the jitter"
        );
        // A zero/absent hint still sleeps at least 1 ms.
        assert!(p.backoff(0, 0) >= Duration::from_millis(1));
    }

    /// Socket-level satellite pin: against a deliberately saturated
    /// service (tiny admission queue, slow refine), plain `generate`
    /// surfaces typed BUSY errors, while `generate_retry` absorbs them —
    /// every client completes, and the BUSY pressure is visible in the
    /// retry counts.
    #[test]
    fn generate_retry_drains_a_saturated_service() {
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.step_sleep = Duration::from_millis(4); // 5 steps -> ~20 ms/bundle
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.queue_capacity = 2;
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_wait_us = 2_000;
        cfg.pipeline_depth = 2;
        let service = Service::start(exec, manifest, cfg);
        let server = TcpServer::bind(
            "127.0.0.1:0",
            service.clone(),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
        )
        .unwrap();
        let addr = server.local_addr.to_string();
        let stop = server.stop_handle();
        let server_thread = std::thread::spawn(move || server.run());

        let clients: Vec<_> = (0..16u64)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let policy = RetryPolicy {
                        max_retries: 200,
                        cap: Duration::from_millis(25),
                        seed: i, // distinct jitter substreams per client
                        deadline: None,
                    };
                    let mut c = Client::connect(&addr).unwrap();
                    c.generate_retry("mock", "cold", "noise", 1, 0.5, 10, i, false, &policy)
                })
            })
            .collect();

        let mut total_retries = 0u64;
        for c in clients {
            let (reply, retries) = c.join().unwrap().unwrap();
            assert_eq!(reply.samples.len(), 1);
            total_retries += retries as u64;
        }
        // 16 concurrent clients against ~5 admission slots: some must
        // have been told BUSY and retried their way through.
        assert!(total_retries >= 1, "expected BUSY-driven retries under saturation");

        stop.store(true, Ordering::SeqCst);
        let _ = server_thread.join().unwrap();
        service.shutdown();
    }

    /// Satellite pin: the total wall-clock deadline. A raw listener that
    /// answers BUSY forever would make an unbounded policy retry 200
    /// times; with a deadline the client gives up early, with the typed
    /// [`RetryDeadline`] carrying its accounting.
    #[test]
    fn retry_deadline_gives_up_with_a_typed_error() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = &stream;
            let mut line = String::new();
            loop {
                line.clear();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    break; // client hung up
                }
                w.write_all(
                    b"{\"ok\":false,\"error\":\"server busy\",\"busy\":true,\"retry_after_ms\":5}\n",
                )
                .unwrap();
            }
        });

        let policy = RetryPolicy {
            max_retries: u32::MAX, // retries alone would never stop
            cap: Duration::from_millis(10),
            seed: 3,
            deadline: Some(Duration::from_millis(60)),
        };
        let mut c = Client::connect(&addr).unwrap();
        let t = Instant::now();
        let err =
            c.generate_retry("mock", "cold", "noise", 1, 0.5, 10, 0, false, &policy).unwrap_err();
        assert!(t.elapsed() < Duration::from_secs(5), "deadline did not bound the wait");
        let gave_up = err.downcast_ref::<RetryDeadline>().expect("typed deadline error");
        assert!(gave_up.attempts >= 1, "should have retried at least once before giving up");
        assert!(gave_up.waited_ms < 5_000, "implausible waited_ms {}", gave_up.waited_ms);
        drop(c);
        server.join().unwrap();
    }
}
