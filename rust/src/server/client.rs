//! Blocking line-protocol client (used by examples, integration tests, and
//! the load-generator in `examples/serve_text.rs`).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// One connection to a `wsfm serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Parsed generate reply.
#[derive(Debug, Clone)]
pub struct GenerateReply {
    pub nfe: usize,
    pub total_us: u64,
    pub queue_us: u64,
    pub samples: Vec<Vec<i32>>,
    pub texts: Vec<String>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(600)))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON line, read one JSON line.
    pub fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            bail!("server closed connection");
        }
        Ok(Json::parse(&reply)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(r#"{"cmd":"ping"}"#)?;
        Ok(j.get("pong").as_bool().unwrap_or(false))
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.roundtrip(r#"{"cmd":"metrics"}"#)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(r#"{"cmd":"shutdown"}"#)?;
        Ok(())
    }

    /// Issue a generate command.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
        decode: bool,
    ) -> Result<GenerateReply> {
        let req = Json::obj(vec![
            ("cmd", Json::str("generate")),
            ("domain", Json::str(domain)),
            ("tag", Json::str(tag)),
            ("draft", Json::str(draft)),
            ("n_samples", Json::num(n_samples as f64)),
            ("t0", Json::num(t0)),
            ("steps", Json::num(steps as f64)),
            ("seed", Json::num(seed as f64)),
            ("decode", Json::Bool(decode)),
        ]);
        let j = self.roundtrip(&req.to_string())?;
        if j.get("ok").as_bool() != Some(true) {
            let busy = j.get("busy").as_bool().unwrap_or(false);
            let hint = j
                .get("retry_after_ms")
                .as_usize()
                .map(|ms| format!(", retry after {ms} ms"))
                .unwrap_or_default();
            bail!(
                "generate failed{}: {}",
                if busy { format!(" (busy{hint})") } else { String::new() },
                j.get("error").as_str().unwrap_or("?")
            );
        }
        let samples = j
            .get("samples")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| v.as_i64().unwrap_or(0) as i32)
                    .collect()
            })
            .collect();
        let texts = j
            .get("texts")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|t| t.as_str().map(|s| s.to_string()))
            .collect();
        Ok(GenerateReply {
            nfe: j.get("nfe").as_usize().unwrap_or(0),
            total_us: j.get("total_us").as_f64().unwrap_or(0.0) as u64,
            queue_us: j.get("queue_us").as_f64().unwrap_or(0.0) as u64,
            samples,
            texts,
        })
    }
}
