//! Blocking wire client (used by examples, integration tests, and the
//! load-generator in `examples/serve_text.rs`).
//!
//! Every connection starts on the legacy JSON-lines codec; call
//! [`Client::negotiate`] to hello-upgrade to another wire codec (binary
//! frames), or [`Client::connect_env`] to honor the `WSFM_WIRE_CODEC`
//! environment variable (the CI wire-compat matrix hook).
//!
//! BUSY responses are flow control, not failures: [`Client::generate`]
//! surfaces them as the typed [`Busy`] error carrying the server's
//! `retry_after_ms` hint, and [`Client::generate_retry`] honors the hint
//! with capped exponential backoff and deterministic jitter drawn from
//! the stateless RNG substreams ([`crate::core::rng::Pcg64::substream`]) —
//! concurrent clients with distinct seeds desynchronize instead of
//! stampeding the admission queue in lockstep.

use crate::coordinator::request::{DraftSpec, GenRequest, GenResponse};
use crate::core::rng::Pcg64;
use crate::core::schedule::WarpMode;
use crate::metrics::MetricsSnapshot;
use crate::obs::SpanRecord;
use crate::server::codec::{self, Codec, JsonLines};
use crate::server::protocol::{WireRequest, WireResponse};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Typed BUSY rejection: the server applied backpressure and suggested
/// when to retry. Downcast from [`Client::generate`]'s error to tell
/// flow control apart from real failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    /// The server's `retry_after_ms` hint (>= 1).
    pub retry_after_ms: u64,
}

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server busy (retry after {} ms)", self.retry_after_ms)
    }
}

impl std::error::Error for Busy {}

/// Typed give-up: [`Client::generate_retry`] exhausted its total
/// wall-clock `deadline` while the server kept answering BUSY. Distinct
/// from [`Busy`] (one rejection, retryable) — this is the client-side
/// latency budget saying stop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryDeadline {
    /// Total wall-clock spent (ms) when the budget ran out.
    pub waited_ms: u64,
    /// BUSY retries performed before giving up.
    pub attempts: u32,
}

impl std::fmt::Display for RetryDeadline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gave up after {} ms and {} busy retries (retry deadline exceeded)",
            self.waited_ms, self.attempts
        )
    }
}

impl std::error::Error for RetryDeadline {}

/// Backoff policy for BUSY retries: the sleep before retry `attempt`
/// starts from the server's live `retry_after_ms` hint, doubles per
/// attempt, is capped at `cap`, and is jittered into `[delay/2, delay]`
/// by a stateless substream of `seed` — fully deterministic per
/// `(seed, attempt, hint)`, no shared RNG state across clients.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries after the initial attempt (0 = surface BUSY immediately).
    pub max_retries: u32,
    /// Upper bound on any single backoff sleep.
    pub cap: Duration,
    /// Jitter substream seed; give concurrent clients distinct seeds.
    pub seed: u64,
    /// Total wall-clock budget across all attempts and sleeps: a retry
    /// whose backoff would cross it gives up with the typed
    /// [`RetryDeadline`] instead of sleeping. `None` = retries bounded
    /// only by `max_retries`.
    pub deadline: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 8, cap: Duration::from_millis(250), seed: 0, deadline: None }
    }
}

/// Substream lane for retry-jitter draws (distinct from the sampler's
/// step/row coordinates by construction: policy-local seed space).
const JITTER_LANE: u64 = 0xB0FF;

impl RetryPolicy {
    /// The backoff before 0-based retry `attempt`, given the server's
    /// most recent `retry_after_ms` hint.
    pub fn backoff(&self, attempt: u32, hint_ms: u64) -> Duration {
        let cap_ms = (self.cap.as_millis() as u64).max(1);
        let exp = hint_ms.max(1).saturating_mul(1u64 << attempt.min(16)).min(cap_ms);
        let half = (exp / 2).max(1);
        let mut rng = Pcg64::substream(self.seed, attempt as u64, JITTER_LANE);
        let jittered = half + rng.below((exp - half + 1).min(u32::MAX as u64) as u32) as u64;
        Duration::from_millis(jittered.min(cap_ms))
    }
}

/// One connection to a `wsfm serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    codec: Box<dyn Codec>,
}

/// Parsed generate reply.
#[derive(Debug, Clone)]
pub struct GenerateReply {
    pub nfe: usize,
    pub total_us: u64,
    pub queue_us: u64,
    pub samples: Vec<Vec<i32>>,
    pub texts: Vec<String>,
}

impl Client {
    /// Connect on the legacy JSON-lines codec (no hello sent).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(600)))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, codec: Box::new(JsonLines) })
    }

    /// Connect honoring `WSFM_WIRE_CODEC` (the CI wire-compat matrix):
    /// unset or `json` stays on the hello-free legacy path; any other
    /// supported name is negotiated before returning.
    pub fn connect_env(addr: &str) -> Result<Client> {
        let mut client = Self::connect(addr)?;
        match std::env::var("WSFM_WIRE_CODEC") {
            Ok(name) if !name.is_empty() && name != "json" => {
                client.negotiate(&[&name])?;
            }
            _ => {}
        }
        Ok(client)
    }

    /// The active codec's name (`json` until a successful negotiate).
    pub fn codec_name(&self) -> &str {
        self.codec.name()
    }

    /// Give up the client and hand back the raw stream (tests that need
    /// to write hostile bytes under an already-negotiated codec).
    pub fn into_stream(self) -> TcpStream {
        self.writer
    }

    /// Hello-negotiate a wire codec: offers `prefs` (most preferred
    /// first), switches to whatever the server acks, and returns its
    /// name. On a typed refusal (no mutual codec) the connection stays
    /// usable on the current codec.
    pub fn negotiate(&mut self, prefs: &[&str]) -> Result<String> {
        let hello =
            WireRequest::Hello { codecs: prefs.iter().map(|s| s.to_string()).collect() };
        self.codec.write_request(&mut self.writer, &hello)?;
        match self.codec.read_response(&mut self.reader)? {
            WireResponse::HelloAck { codec: name } => {
                if name != self.codec.name() {
                    self.codec = codec::make(&name)
                        .with_context(|| format!("server acked unknown codec {name:?}"))?;
                }
                Ok(name)
            }
            WireResponse::Error { msg, .. } => bail!("negotiate failed: {msg}"),
            other => bail!("unexpected hello reply: {other:?}"),
        }
    }

    fn request(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.codec.write_request(&mut self.writer, req)?;
        self.codec.read_response(&mut self.reader)
    }

    /// Send one raw JSON line, read one JSON line. Legacy escape hatch —
    /// bypasses the active codec, only meaningful before a negotiate.
    pub fn roundtrip(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            bail!("server closed connection");
        }
        Ok(Json::parse(&reply)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(matches!(self.request(&WireRequest::Ping)?, WireResponse::Pong))
    }

    /// Server metrics as a JSON object (`metrics`, `samples_per_sec`,
    /// `completed`, `rejected`) — the same shape regardless of codec.
    pub fn metrics(&mut self) -> Result<Json> {
        match self.request(&WireRequest::Metrics)? {
            WireResponse::Metrics { report, samples_per_sec, completed, rejected } => {
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("metrics", Json::str(report)),
                    ("samples_per_sec", Json::num(samples_per_sec)),
                    ("completed", Json::u64(completed)),
                    ("rejected", Json::u64(rejected)),
                ]))
            }
            other => bail!("unexpected metrics reply: {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.request(&WireRequest::Shutdown)?;
        Ok(())
    }

    /// Typed live stats (`{"cmd":"stats"}`): the full
    /// [`MetricsSnapshot`], identical in shape on either codec. The
    /// `fleet` section is present only when the server was started with
    /// a fleet attached.
    pub fn stats(&mut self) -> Result<MetricsSnapshot> {
        match self.request(&WireRequest::Stats)? {
            WireResponse::Stats { snapshot } => Ok(snapshot),
            other => bail!("unexpected stats reply: {other:?}"),
        }
    }

    /// Span trace for one wire request id
    /// (`{"cmd":"trace","request_id":N}`). An unknown id (or tracing
    /// disabled server-side) surfaces the server's typed error — never a
    /// hang.
    pub fn trace(&mut self, request_id: u64) -> Result<Vec<SpanRecord>> {
        match self.request(&WireRequest::Trace { request_id })? {
            WireResponse::Trace { spans, .. } => Ok(spans),
            WireResponse::Error { msg, .. } => bail!("trace failed: {msg}"),
            other => bail!("unexpected trace reply: {other:?}"),
        }
    }

    /// Generate with the opt-in `"timing":true` flag set, returning the
    /// full typed response (id for a follow-up [`Client::trace`], plus
    /// the per-segment timing breakdown).
    #[allow(clippy::too_many_arguments)]
    pub fn generate_timed(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
    ) -> Result<GenResponse> {
        let mut request = GenRequest::from_wire(
            domain.to_string(),
            tag.to_string(),
            DraftSpec::parse(draft)?,
            n_samples,
            t0,
            steps,
            WarpMode::Literal,
            seed,
        )?;
        request.timing = true;
        match self.request(&WireRequest::Generate { request, decode: false })? {
            WireResponse::Generate { resp, .. } => Ok(resp),
            WireResponse::Busy { retry_after_ms } => {
                Err(anyhow::Error::new(Busy { retry_after_ms: retry_after_ms.max(1) }))
            }
            WireResponse::Error { msg, .. } => bail!("generate failed: {msg}"),
            other => bail!("unexpected generate reply: {other:?}"),
        }
    }

    /// Issue a generate command. `seed` survives the wire exactly — even
    /// above 2^53 — on both codecs.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
        decode: bool,
    ) -> Result<GenerateReply> {
        let request = GenRequest::from_wire(
            domain.to_string(),
            tag.to_string(),
            DraftSpec::parse(draft)?,
            n_samples,
            t0,
            steps,
            WarpMode::Literal,
            seed,
        )?;
        match self.request(&WireRequest::Generate { request, decode })? {
            WireResponse::Generate { resp, texts } => Ok(GenerateReply {
                nfe: resp.nfe,
                total_us: resp.total_time.as_micros() as u64,
                queue_us: resp.queue_wait.as_micros() as u64,
                samples: resp.samples,
                texts: texts.unwrap_or_default(),
            }),
            WireResponse::Busy { retry_after_ms } => {
                // Typed flow-control signal: callers (and generate_retry)
                // downcast to Busy and back off by the server's hint.
                Err(anyhow::Error::new(Busy { retry_after_ms: retry_after_ms.max(1) }))
            }
            WireResponse::Error { msg, busy } => {
                if busy {
                    return Err(anyhow::Error::new(Busy { retry_after_ms: 1 }));
                }
                bail!("generate failed: {msg}")
            }
            other => bail!("unexpected generate reply: {other:?}"),
        }
    }

    /// [`Client::generate`] that honors BUSY backpressure: on a [`Busy`]
    /// rejection it sleeps `policy.backoff(attempt, hint)` and retries, up
    /// to `policy.max_retries` times — within `policy.deadline` of total
    /// wall-clock, if set: when the next sleep would cross the budget it
    /// gives up with the typed [`RetryDeadline`] instead. Real failures
    /// (non-BUSY) are never retried. Returns the reply plus how many
    /// retries it took (0 = first try).
    #[allow(clippy::too_many_arguments)]
    pub fn generate_retry(
        &mut self,
        domain: &str,
        tag: &str,
        draft: &str,
        n_samples: usize,
        t0: f64,
        steps: usize,
        seed: u64,
        decode: bool,
        policy: &RetryPolicy,
    ) -> Result<(GenerateReply, u32)> {
        let started = Instant::now();
        let mut attempt = 0u32;
        loop {
            match self.generate(domain, tag, draft, n_samples, t0, steps, seed, decode) {
                Ok(reply) => return Ok((reply, attempt)),
                Err(e) => match e.downcast_ref::<Busy>() {
                    Some(busy) if attempt < policy.max_retries => {
                        let delay = policy.backoff(attempt, busy.retry_after_ms);
                        if let Some(deadline) = policy.deadline {
                            let waited = started.elapsed();
                            if waited + delay > deadline {
                                return Err(anyhow::Error::new(RetryDeadline {
                                    waited_ms: waited.as_millis() as u64,
                                    attempts: attempt,
                                }));
                            }
                        }
                        std::thread::sleep(delay);
                        attempt += 1;
                    }
                    _ => return Err(e),
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WsfmConfig;
    use crate::coordinator::testutil::{mock_manifest, TestExec};
    use crate::coordinator::Service;
    use crate::server::TcpServer;
    use std::sync::atomic::Ordering;

    #[test]
    fn backoff_grows_exponentially_capped_and_jittered() {
        let p = RetryPolicy {
            max_retries: 8,
            cap: Duration::from_millis(100),
            seed: 7,
            deadline: None,
        };
        // Every backoff stays within [hint/2 * 2^k floor, cap].
        let mut prev_hi = 0u64;
        for attempt in 0..8 {
            let d = p.backoff(attempt, 5).as_millis() as u64;
            let exp = (5u64 << attempt).min(100);
            assert!(d >= (exp / 2).max(1), "attempt {attempt}: {d} < {}", exp / 2);
            assert!(d <= 100, "attempt {attempt}: {d} beyond cap");
            prev_hi = prev_hi.max(d);
        }
        assert!(prev_hi >= 50, "later attempts should reach the cap region, max seen {prev_hi}");
        // Deterministic per (seed, attempt, hint); distinct seeds jitter
        // differently somewhere in the schedule.
        assert_eq!(p.backoff(3, 5), p.backoff(3, 5));
        let q = RetryPolicy { seed: 8, ..p.clone() };
        assert!(
            (0..8).any(|a| p.backoff(a, 5) != q.backoff(a, 5)),
            "distinct seeds should desynchronize the jitter"
        );
        // A zero/absent hint still sleeps at least 1 ms.
        assert!(p.backoff(0, 0) >= Duration::from_millis(1));
    }

    /// Socket-level satellite pin: against a deliberately saturated
    /// service (tiny admission queue, slow refine), plain `generate`
    /// surfaces typed BUSY errors, while `generate_retry` absorbs them —
    /// every client completes, and the BUSY pressure is visible in the
    /// retry counts. Runs under whichever codec `WSFM_WIRE_CODEC`
    /// selects, so the CI matrix exercises retry flow on both wires.
    #[test]
    fn generate_retry_drains_a_saturated_service() {
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.step_sleep = Duration::from_millis(4); // 5 steps -> ~20 ms/bundle
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.queue_capacity = 2;
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_wait_us = 2_000;
        cfg.pipeline_depth = 2;
        let service = Service::start(exec, manifest, cfg);
        let server = TcpServer::bind(
            "127.0.0.1:0",
            service.clone(),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
        )
        .unwrap();
        let addr = server.local_addr.to_string();
        let stop = server.stop_handle();
        let server_thread = std::thread::spawn(move || server.run());

        let clients: Vec<_> = (0..16u64)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let policy = RetryPolicy {
                        max_retries: 200,
                        cap: Duration::from_millis(25),
                        seed: i, // distinct jitter substreams per client
                        deadline: None,
                    };
                    let mut c = Client::connect_env(&addr).unwrap();
                    c.generate_retry("mock", "cold", "noise", 1, 0.5, 10, i, false, &policy)
                })
            })
            .collect();

        let mut total_retries = 0u64;
        for c in clients {
            let (reply, retries) = c.join().unwrap().unwrap();
            assert_eq!(reply.samples.len(), 1);
            total_retries += retries as u64;
        }
        // 16 concurrent clients against ~5 admission slots: some must
        // have been told BUSY and retried their way through.
        assert!(total_retries >= 1, "expected BUSY-driven retries under saturation");

        stop.store(true, Ordering::SeqCst);
        let _ = server_thread.join().unwrap();
        service.shutdown();
    }

    /// Satellite pin: the total wall-clock deadline. A raw listener that
    /// answers BUSY forever would make an unbounded policy retry 200
    /// times; with a deadline the client gives up early, with the typed
    /// [`RetryDeadline`] carrying its accounting.
    #[test]
    fn retry_deadline_gives_up_with_a_typed_error() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = &stream;
            let mut line = String::new();
            loop {
                line.clear();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    break; // client hung up
                }
                w.write_all(
                    b"{\"ok\":false,\"error\":\"server busy\",\"busy\":true,\"retry_after_ms\":5}\n",
                )
                .unwrap();
            }
        });

        let policy = RetryPolicy {
            max_retries: u32::MAX, // retries alone would never stop
            cap: Duration::from_millis(10),
            seed: 3,
            deadline: Some(Duration::from_millis(60)),
        };
        let mut c = Client::connect(&addr).unwrap();
        let t = Instant::now();
        let err =
            c.generate_retry("mock", "cold", "noise", 1, 0.5, 10, 0, false, &policy).unwrap_err();
        assert!(t.elapsed() < Duration::from_secs(5), "deadline did not bound the wait");
        let gave_up = err.downcast_ref::<RetryDeadline>().expect("typed deadline error");
        assert!(gave_up.attempts >= 1, "should have retried at least once before giving up");
        assert!(gave_up.waited_ms < 5_000, "implausible waited_ms {}", gave_up.waited_ms);
        drop(c);
        server.join().unwrap();
    }
}
