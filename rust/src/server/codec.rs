//! Pluggable wire codecs: how [`WireRequest`]/[`WireResponse`] are framed
//! on the socket.
//!
//! Two implementations (negotiated at connect via the client hello
//! `{"cmd":"hello","codecs":[...]}`; absent hello ⇒ the server default,
//! legacy JSON, so old clients work unchanged):
//!
//! - [`JsonLines`] — one JSON object per line, **byte-for-byte** the
//!   pre-codec wire format. Golden tests below pin every response shape
//!   to its exact legacy bytes.
//! - [`Binary`] — length-prefixed frames. Layout:
//!
//!   ```text
//!   [u32 LE payload length] [payload]
//!   payload = [u8 version = 1] [u8 msg tag] [typed fields]
//!   ```
//!
//!   Integers are little-endian, `f64` as LE bit pattern, strings are
//!   `u32 len + UTF-8 bytes`, token rows are `u32 count + count × i32 LE`
//!   — token arrays never round-trip through decimal strings. Frames
//!   above [`MAX_FRAME`] are rejected *before* any allocation, and every
//!   nested count is bounds-checked against the remaining payload, so a
//!   hostile length field cannot allocate unbounded memory or hang the
//!   connection.
//!
//! Both sides of the trait are implemented symmetrically (server reads
//! requests / writes responses; client writes requests / reads
//! responses), which is what lets the property tests drive full lossless
//! round-trips through each codec.

use crate::server::protocol::{
    parse_request, parse_response, render_request, render_wire_response, WireRequest, WireResponse,
};
use crate::coordinator::request::{CascadeInfo, DraftSpec, GenRequest, GenResponse, TimingInfo};
use crate::core::schedule::WarpMode;
use crate::metrics::MetricsSnapshot;
use crate::obs::{SpanKind, SpanRecord};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Read, Write};
use std::time::Duration;

/// Codec names in server preference order.
pub const SUPPORTED: &[&str] = &["json", "binary"];

/// Binary frame version byte.
pub const FRAME_VERSION: u8 = 1;

/// Hard ceiling on one binary frame's payload (64 MiB). Checked against
/// the length prefix before any payload allocation.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// One decoded inbound message, or a decode failure the server should
/// surface as a typed error response.
#[derive(Debug)]
pub enum Decoded {
    Request(WireRequest),
    /// Undecodable input. `fatal` means framing is lost and the
    /// connection must close after the error reply (binary framing
    /// violations); non-fatal errors (a malformed JSON line, a bad field
    /// inside a well-framed binary message) keep the connection open.
    Malformed { msg: String, fatal: bool },
}

/// A wire framing: both directions of the protocol.
pub trait Codec: Send {
    fn name(&self) -> &'static str;
    /// Server side: read the next request. `Ok(None)` = clean EOF.
    fn read_request(&mut self, r: &mut dyn BufRead) -> Result<Option<Decoded>>;
    /// Server side: write one response.
    fn write_response(&mut self, w: &mut dyn Write, resp: &WireResponse) -> Result<()>;
    /// Client side: write one request.
    fn write_request(&mut self, w: &mut dyn Write, req: &WireRequest) -> Result<()>;
    /// Client side: read one response.
    fn read_response(&mut self, r: &mut dyn BufRead) -> Result<WireResponse>;
}

/// Construct a codec by negotiated name.
pub fn make(name: &str) -> Option<Box<dyn Codec>> {
    match name {
        "json" => Some(Box::new(JsonLines)),
        "binary" => Some(Box::new(Binary)),
        _ => None,
    }
}

/// Pick the codec for a hello: first client-preference name the server
/// side also enables. `None` when the offers don't intersect.
pub fn negotiate<'a>(server: &[String], client: &'a [String]) -> Option<&'a str> {
    client.iter().map(String::as_str).find(|c| server.iter().any(|s| s == c))
}

// ---------------------------------------------------------------------------
// JSON lines (legacy)
// ---------------------------------------------------------------------------

/// The legacy one-JSON-object-per-line framing.
pub struct JsonLines;

impl Codec for JsonLines {
    fn name(&self) -> &'static str {
        "json"
    }

    fn read_request(&mut self, r: &mut dyn BufRead) -> Result<Option<Decoded>> {
        // Skip blank lines (legacy behavior); EOF ends the connection.
        loop {
            let mut line = String::new();
            if r.read_line(&mut line)? == 0 {
                return Ok(None);
            }
            if line.trim().is_empty() {
                continue;
            }
            return Ok(Some(match parse_request(&line) {
                Ok(req) => Decoded::Request(req),
                Err(e) => Decoded::Malformed { msg: format!("{e:#}"), fatal: false },
            }));
        }
    }

    fn write_response(&mut self, w: &mut dyn Write, resp: &WireResponse) -> Result<()> {
        w.write_all(render_wire_response(resp).as_bytes())?;
        w.write_all(b"\n")?;
        w.flush()?;
        Ok(())
    }

    fn write_request(&mut self, w: &mut dyn Write, req: &WireRequest) -> Result<()> {
        w.write_all(render_request(req).as_bytes())?;
        w.write_all(b"\n")?;
        w.flush()?;
        Ok(())
    }

    fn read_response(&mut self, r: &mut dyn BufRead) -> Result<WireResponse> {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("server closed connection");
        }
        parse_response(&line)
    }
}

// ---------------------------------------------------------------------------
// Binary (length-prefixed frames)
// ---------------------------------------------------------------------------

// Request tags.
const RQ_PING: u8 = 1;
const RQ_METRICS: u8 = 2;
const RQ_INFO: u8 = 3;
const RQ_SHUTDOWN: u8 = 4;
const RQ_GENERATE: u8 = 5;
const RQ_HELLO: u8 = 6;
const RQ_STATS: u8 = 7;
const RQ_TRACE: u8 = 8;
// Response tags.
const RS_PONG: u8 = 1;
const RS_METRICS: u8 = 2;
const RS_INFO: u8 = 3;
const RS_SHUTDOWN_ACK: u8 = 4;
const RS_GENERATE: u8 = 5;
const RS_ERROR: u8 = 6;
const RS_BUSY: u8 = 7;
const RS_HELLO_ACK: u8 = 8;
const RS_STATS: u8 = 9;
const RS_TRACE: u8 = 10;

/// Fixed byte width of one span record in an RS_TRACE payload
/// (request_id u64 + bundle_id u64 + kind u8 + detail u32 + start/dur u64).
const SPAN_WIRE_BYTES: usize = 8 + 8 + 1 + 4 + 8 + 8;

/// Length-prefixed binary framing.
pub struct Binary;

impl Binary {
    /// Encode one request's frame payload (version byte + tag + fields).
    pub fn encode_request(req: &WireRequest) -> Vec<u8> {
        let mut p = vec![FRAME_VERSION];
        match req {
            WireRequest::Ping => p.push(RQ_PING),
            WireRequest::Metrics => p.push(RQ_METRICS),
            WireRequest::Info => p.push(RQ_INFO),
            WireRequest::Shutdown => p.push(RQ_SHUTDOWN),
            WireRequest::Stats => p.push(RQ_STATS),
            WireRequest::Trace { request_id } => {
                p.push(RQ_TRACE);
                put_u64(&mut p, *request_id);
            }
            WireRequest::Hello { codecs } => {
                p.push(RQ_HELLO);
                put_u32(&mut p, codecs.len() as u32);
                for c in codecs {
                    put_str(&mut p, c);
                }
            }
            WireRequest::Generate { request: r, decode } => {
                p.push(RQ_GENERATE);
                put_str(&mut p, &r.domain);
                put_str(&mut p, &r.tag);
                put_str(&mut p, r.draft.name());
                put_u32(&mut p, r.n_samples as u32);
                put_f64(&mut p, r.t0);
                put_u32(&mut p, r.steps_cold as u32);
                p.push(match r.warp_mode {
                    WarpMode::Literal => 0,
                    WarpMode::Exact => 1,
                });
                put_u64(&mut p, r.seed);
                p.push(*decode as u8);
                p.push(r.timing as u8);
            }
        }
        p
    }

    /// Decode one request frame payload.
    pub fn decode_request(payload: &[u8]) -> Result<WireRequest> {
        let mut rd = Rd { b: payload, i: 0 };
        let ver = rd.u8().context("missing frame version")?;
        if ver != FRAME_VERSION {
            bail!("unsupported frame version {ver}");
        }
        let tag = rd.u8().context("missing message tag")?;
        let req = match tag {
            RQ_PING => WireRequest::Ping,
            RQ_METRICS => WireRequest::Metrics,
            RQ_INFO => WireRequest::Info,
            RQ_SHUTDOWN => WireRequest::Shutdown,
            RQ_STATS => WireRequest::Stats,
            RQ_TRACE => WireRequest::Trace { request_id: rd.u64()? },
            RQ_HELLO => {
                let n = rd.count(1)?;
                let mut codecs = Vec::with_capacity(n);
                for _ in 0..n {
                    codecs.push(rd.str()?);
                }
                WireRequest::Hello { codecs }
            }
            RQ_GENERATE => {
                let domain = rd.str()?;
                let tag_s = rd.str()?;
                let draft = DraftSpec::parse(&rd.str()?)?;
                let n_samples = rd.u32()? as usize;
                let t0 = rd.f64()?;
                let steps_cold = rd.u32()? as usize;
                let warp_mode = match rd.u8()? {
                    0 => WarpMode::Literal,
                    1 => WarpMode::Exact,
                    w => bail!("bad warp byte {w}"),
                };
                let seed = rd.u64()?;
                let decode = rd.u8()? != 0;
                let timing = rd.u8()? != 0;
                let mut request = GenRequest::from_wire(
                    domain, tag_s, draft, n_samples, t0, steps_cold, warp_mode, seed,
                )?;
                request.timing = timing;
                return rd.finish(WireRequest::Generate { request, decode });
            }
            other => bail!("unknown request tag {other}"),
        };
        rd.finish(req)
    }

    /// Encode one response's frame payload.
    pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
        let mut p = vec![FRAME_VERSION];
        match resp {
            WireResponse::Pong => p.push(RS_PONG),
            WireResponse::ShutdownAck => p.push(RS_SHUTDOWN_ACK),
            WireResponse::HelloAck { codec } => {
                p.push(RS_HELLO_ACK);
                put_str(&mut p, codec);
            }
            WireResponse::Error { msg, busy } => {
                p.push(RS_ERROR);
                put_str(&mut p, msg);
                p.push(*busy as u8);
            }
            WireResponse::Busy { retry_after_ms } => {
                p.push(RS_BUSY);
                put_u64(&mut p, *retry_after_ms);
            }
            WireResponse::Metrics { report, samples_per_sec, completed, rejected } => {
                p.push(RS_METRICS);
                put_str(&mut p, report);
                put_f64(&mut p, *samples_per_sec);
                put_u64(&mut p, *completed);
                put_u64(&mut p, *rejected);
            }
            WireResponse::Info { domains, artifacts } => {
                p.push(RS_INFO);
                put_u32(&mut p, domains.len() as u32);
                for d in domains {
                    put_str(&mut p, d);
                }
                put_u64(&mut p, *artifacts as u64);
            }
            // The snapshot is deeply nested (histograms, per-replica
            // series); its canonical JSON object rides inside the binary
            // frame as one string. `to_json`/`from_json` round-trip
            // exactly (durations as integer ns), so no precision is lost
            // and the two codecs can never disagree on field semantics.
            WireResponse::Stats { snapshot } => {
                p.push(RS_STATS);
                put_str(&mut p, &snapshot.to_json().to_string());
            }
            WireResponse::Trace { request_id, spans } => {
                p.push(RS_TRACE);
                put_u64(&mut p, *request_id);
                put_u32(&mut p, spans.len() as u32);
                for s in spans {
                    put_u64(&mut p, s.request_id);
                    put_u64(&mut p, s.bundle_id);
                    p.push(s.kind as u8);
                    put_u32(&mut p, s.detail);
                    put_u64(&mut p, s.start_us);
                    put_u64(&mut p, s.dur_us);
                }
            }
            WireResponse::Generate { resp, texts } => {
                p.push(RS_GENERATE);
                put_u64(&mut p, resp.id);
                put_u64(&mut p, resp.nfe as u64);
                put_f64(&mut p, resp.t0_used);
                put_u64(&mut p, resp.queue_wait.as_micros() as u64);
                put_u64(&mut p, resp.draft_time.as_micros() as u64);
                put_u64(&mut p, resp.refine_time.as_micros() as u64);
                put_u64(&mut p, resp.total_time.as_micros() as u64);
                match &resp.cascade {
                    None => p.push(0),
                    Some(c) => {
                        p.push(1);
                        put_u32(&mut p, c.stages_used as u32);
                        put_u32(&mut p, c.nfe_per_stage.len() as u32);
                        for &n in &c.nfe_per_stage {
                            put_u32(&mut p, n as u32);
                        }
                        p.push(c.early_exit as u8);
                    }
                }
                match &resp.degraded {
                    None => p.push(0),
                    Some(reason) => {
                        p.push(1);
                        put_str(&mut p, reason);
                    }
                }
                match &resp.timing {
                    None => p.push(0),
                    Some(t) => {
                        p.push(1);
                        put_u64(&mut p, t.nfe_floor as u64);
                        put_u32(&mut p, t.segments.len() as u32);
                        for &(nfe, us) in &t.segments {
                            put_u32(&mut p, nfe as u32);
                            put_u64(&mut p, us);
                        }
                        put_u32(&mut p, t.gate_us.len() as u32);
                        for &us in &t.gate_us {
                            put_u64(&mut p, us);
                        }
                        put_u32(&mut p, t.replicas.len() as u32);
                        for &r in &t.replicas {
                            put_u32(&mut p, r);
                        }
                        put_u32(&mut p, t.reroutes);
                    }
                }
                put_u32(&mut p, resp.samples.len() as u32);
                for row in &resp.samples {
                    put_u32(&mut p, row.len() as u32);
                    for &t in row {
                        p.extend_from_slice(&t.to_le_bytes());
                    }
                }
                match texts {
                    None => p.push(0),
                    Some(ts) => {
                        p.push(1);
                        put_u32(&mut p, ts.len() as u32);
                        for t in ts {
                            put_str(&mut p, t);
                        }
                    }
                }
            }
        }
        p
    }

    /// Decode one response frame payload.
    pub fn decode_response(payload: &[u8]) -> Result<WireResponse> {
        let mut rd = Rd { b: payload, i: 0 };
        let ver = rd.u8().context("missing frame version")?;
        if ver != FRAME_VERSION {
            bail!("unsupported frame version {ver}");
        }
        let tag = rd.u8().context("missing message tag")?;
        let resp = match tag {
            RS_PONG => WireResponse::Pong,
            RS_SHUTDOWN_ACK => WireResponse::ShutdownAck,
            RS_HELLO_ACK => WireResponse::HelloAck { codec: rd.str()? },
            RS_ERROR => WireResponse::Error { msg: rd.str()?, busy: rd.u8()? != 0 },
            RS_BUSY => WireResponse::Busy { retry_after_ms: rd.u64()? },
            RS_METRICS => WireResponse::Metrics {
                report: rd.str()?,
                samples_per_sec: rd.f64()?,
                completed: rd.u64()?,
                rejected: rd.u64()?,
            },
            RS_INFO => {
                let n = rd.count(1)?;
                let mut domains = Vec::with_capacity(n);
                for _ in 0..n {
                    domains.push(rd.str()?);
                }
                WireResponse::Info { domains, artifacts: rd.u64()? as usize }
            }
            RS_STATS => {
                let json = rd.str()?;
                let j = crate::util::json::Json::parse(&json)
                    .context("corrupt stats json inside binary frame")?;
                WireResponse::Stats { snapshot: MetricsSnapshot::from_json(&j) }
            }
            RS_TRACE => {
                let request_id = rd.u64()?;
                let n = rd.count(SPAN_WIRE_BYTES)?;
                let mut spans = Vec::with_capacity(n);
                for _ in 0..n {
                    let span_request_id = rd.u64()?;
                    let bundle_id = rd.u64()?;
                    let kind_byte = rd.u8()?;
                    let kind = SpanKind::from_u8(kind_byte)
                        .with_context(|| format!("unknown span kind byte {kind_byte}"))?;
                    spans.push(SpanRecord {
                        request_id: span_request_id,
                        bundle_id,
                        kind,
                        detail: rd.u32()?,
                        start_us: rd.u64()?,
                        dur_us: rd.u64()?,
                    });
                }
                WireResponse::Trace { request_id, spans }
            }
            RS_GENERATE => {
                let id = rd.u64()?;
                let nfe = rd.u64()? as usize;
                let t0_used = rd.f64()?;
                let queue_wait = Duration::from_micros(rd.u64()?);
                let draft_time = Duration::from_micros(rd.u64()?);
                let refine_time = Duration::from_micros(rd.u64()?);
                let total_time = Duration::from_micros(rd.u64()?);
                let cascade = if rd.u8()? != 0 {
                    let stages_used = rd.u32()? as usize;
                    let n = rd.count(4)?;
                    let mut nfe_per_stage = Vec::with_capacity(n);
                    for _ in 0..n {
                        nfe_per_stage.push(rd.u32()? as usize);
                    }
                    Some(CascadeInfo { stages_used, nfe_per_stage, early_exit: rd.u8()? != 0 })
                } else {
                    None
                };
                let degraded = if rd.u8()? != 0 { Some(rd.str()?) } else { None };
                let timing = if rd.u8()? != 0 {
                    let nfe_floor = rd.u64()? as usize;
                    let n_segs = rd.count(12)?;
                    let mut segments = Vec::with_capacity(n_segs);
                    for _ in 0..n_segs {
                        let nfe = rd.u32()? as usize;
                        segments.push((nfe, rd.u64()?));
                    }
                    let n_gates = rd.count(8)?;
                    let mut gate_us = Vec::with_capacity(n_gates);
                    for _ in 0..n_gates {
                        gate_us.push(rd.u64()?);
                    }
                    let n_reps = rd.count(4)?;
                    let mut replicas = Vec::with_capacity(n_reps);
                    for _ in 0..n_reps {
                        replicas.push(rd.u32()?);
                    }
                    Some(TimingInfo { nfe_floor, segments, gate_us, replicas, reroutes: rd.u32()? })
                } else {
                    None
                };
                let n_rows = rd.count(4)?;
                let mut samples = Vec::with_capacity(n_rows);
                for _ in 0..n_rows {
                    let n = rd.count(4)?;
                    let mut row = Vec::with_capacity(n);
                    for _ in 0..n {
                        row.push(i32::from_le_bytes(rd.take(4)?.try_into().unwrap()));
                    }
                    samples.push(row);
                }
                let texts = if rd.u8()? != 0 {
                    let n = rd.count(1)?;
                    let mut ts = Vec::with_capacity(n);
                    for _ in 0..n {
                        ts.push(rd.str()?);
                    }
                    Some(ts)
                } else {
                    None
                };
                let resp = GenResponse {
                    id,
                    samples,
                    nfe,
                    t0_used,
                    cascade,
                    queue_wait,
                    draft_time,
                    refine_time,
                    total_time,
                    degraded,
                    timing,
                };
                return rd.finish(WireResponse::Generate { resp, texts });
            }
            other => bail!("unknown response tag {other}"),
        };
        rd.finish(resp)
    }

    fn write_frame(w: &mut dyn Write, payload: &[u8]) -> Result<()> {
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(payload)?;
        w.flush()?;
        Ok(())
    }

    /// Read one frame's payload. `Ok(None)` = clean EOF at a frame
    /// boundary; a length prefix above [`MAX_FRAME`] errors *without*
    /// allocating the claimed size.
    fn read_frame(r: &mut dyn BufRead) -> Result<Option<Vec<u8>>> {
        let mut len_buf = [0u8; 4];
        // Distinguish clean EOF (no bytes) from truncation mid-length.
        let mut filled = 0;
        while filled < 4 {
            let n = r.read(&mut len_buf[filled..])?;
            if n == 0 {
                if filled == 0 {
                    return Ok(None);
                }
                bail!("truncated frame: EOF inside length prefix ({filled}/4 bytes)");
            }
            filled += n;
        }
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            bail!("frame length {len} exceeds maximum {MAX_FRAME}");
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).context("truncated frame payload")?;
        Ok(Some(payload))
    }
}

impl Codec for Binary {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn read_request(&mut self, r: &mut dyn BufRead) -> Result<Option<Decoded>> {
        let payload = match Binary::read_frame(r) {
            Ok(None) => return Ok(None),
            Ok(Some(p)) => p,
            // Framing is lost (oversized/truncated length): the server
            // sends a typed error and closes; it cannot resync.
            Err(e) => return Ok(Some(Decoded::Malformed { msg: format!("{e:#}"), fatal: true })),
        };
        Ok(Some(match Binary::decode_request(&payload) {
            Ok(req) => Decoded::Request(req),
            // Frame boundaries are intact; only this message was bad.
            Err(e) => Decoded::Malformed { msg: format!("{e:#}"), fatal: false },
        }))
    }

    fn write_response(&mut self, w: &mut dyn Write, resp: &WireResponse) -> Result<()> {
        Binary::write_frame(w, &Binary::encode_response(resp))
    }

    fn write_request(&mut self, w: &mut dyn Write, req: &WireRequest) -> Result<()> {
        Binary::write_frame(w, &Binary::encode_request(req))
    }

    fn read_response(&mut self, r: &mut dyn BufRead) -> Result<WireResponse> {
        match Binary::read_frame(r)? {
            None => bail!("server closed connection"),
            Some(payload) => Binary::decode_response(&payload),
        }
    }
}

// -- binary primitives ------------------------------------------------------

fn put_u32(p: &mut Vec<u8>, v: u32) {
    p.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(p: &mut Vec<u8>, v: u64) {
    p.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(p: &mut Vec<u8>, v: f64) {
    p.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_str(p: &mut Vec<u8>, s: &str) {
    put_u32(p, s.len() as u32);
    p.extend_from_slice(s.as_bytes());
}

/// Bounds-checked payload reader. Every `count` is validated against the
/// bytes actually remaining before any `Vec::with_capacity`, so a forged
/// count cannot become an allocation bomb.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated frame: wanted {n} bytes, {} left", self.b.len() - self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// Read a u32 element count and check `count * min_elem_size` fits in
    /// the remaining payload.
    fn count(&mut self, min_elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.b.len() - self.i;
        if n.saturating_mul(min_elem_size) > remaining {
            bail!("corrupt frame: count {n} exceeds remaining {remaining} bytes");
        }
        Ok(n)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        Ok(std::str::from_utf8(self.take(n)?).context("invalid utf-8 in frame")?.to_string())
    }
    /// Require the payload to be fully consumed (catches messages with
    /// trailing garbage, which would mean a codec mismatch).
    fn finish<T>(&mut self, v: T) -> Result<T> {
        if self.i != self.b.len() {
            bail!("corrupt frame: {} trailing bytes", self.b.len() - self.i);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg64;
    use crate::util::prop::{check, Strategy};
    use std::io::Cursor;

    fn resp_fixture() -> GenResponse {
        GenResponse {
            id: 3,
            samples: vec![vec![1, 2], vec![3, 4]],
            nfe: 205,
            t0_used: 0.8,
            cascade: None,
            queue_wait: Duration::from_micros(120),
            draft_time: Duration::from_micros(900),
            refine_time: Duration::from_micros(52_000),
            total_time: Duration::from_micros(53_100),
            degraded: None,
            timing: None,
        }
    }

    fn json_bytes(resp: &WireResponse) -> String {
        let mut buf = Vec::new();
        JsonLines.write_response(&mut buf, resp).unwrap();
        String::from_utf8(buf).unwrap()
    }

    // -- goldens: the full legacy JSON wire surface, byte-exact ---------

    #[test]
    fn golden_generate_ok() {
        assert_eq!(
            json_bytes(&WireResponse::Generate { resp: resp_fixture(), texts: None }),
            concat!(
                r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
                r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
                r#""samples":[[1,2],[3,4]]}"#,
                "\n"
            )
        );
    }

    #[test]
    fn golden_generate_with_texts() {
        assert_eq!(
            json_bytes(&WireResponse::Generate {
                resp: resp_fixture(),
                texts: Some(vec!["ab".into(), "cd".into()]),
            }),
            concat!(
                r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
                r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
                r#""samples":[[1,2],[3,4]],"texts":["ab","cd"]}"#,
                "\n"
            )
        );
    }

    #[test]
    fn golden_generate_cascade() {
        let resp = GenResponse {
            cascade: Some(CascadeInfo {
                stages_used: 2,
                nfe_per_stage: vec![150, 55],
                early_exit: true,
            }),
            ..resp_fixture()
        };
        assert_eq!(
            json_bytes(&WireResponse::Generate { resp, texts: None }),
            concat!(
                r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
                r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
                r#""stages_used":2,"nfe_stages":[150,55],"early_exit":true,"#,
                r#""samples":[[1,2],[3,4]]}"#,
                "\n"
            )
        );
    }

    #[test]
    fn golden_generate_degraded() {
        let resp = GenResponse {
            nfe: 0,
            degraded: Some("refine failed: all fleet replicas are down".into()),
            ..resp_fixture()
        };
        assert_eq!(
            json_bytes(&WireResponse::Generate { resp, texts: None }),
            concat!(
                r#"{"ok":true,"id":3,"nfe":0,"t0_used":0.8,"queue_us":120,"#,
                r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
                r#""degraded":true,"degraded_reason":"refine failed: all fleet replicas are down","#,
                r#""samples":[[1,2],[3,4]]}"#,
                "\n"
            )
        );
    }

    #[test]
    fn golden_error_and_busy() {
        assert_eq!(
            json_bytes(&WireResponse::Error { msg: "unknown cmd \"explode\"".into(), busy: false }),
            "{\"ok\":false,\"error\":\"unknown cmd \\\"explode\\\"\"}\n"
        );
        assert_eq!(
            json_bytes(&WireResponse::Error { msg: "overload".into(), busy: true }),
            r#"{"ok":false,"error":"overload","busy":true}"#.to_string() + "\n"
        );
        assert_eq!(
            json_bytes(&WireResponse::Busy { retry_after_ms: 7 }),
            concat!(
                r#"{"ok":false,"error":"server busy: admission queue full","#,
                r#""busy":true,"retry_after_ms":7}"#,
                "\n"
            )
        );
    }

    #[test]
    fn golden_ping_metrics_info_shutdown() {
        assert_eq!(json_bytes(&WireResponse::Pong), "{\"ok\":true,\"pong\":true}\n");
        assert_eq!(
            json_bytes(&WireResponse::Metrics {
                report: "report text".into(),
                samples_per_sec: 12.5,
                completed: 3,
                rejected: 1,
            }),
            concat!(
                r#"{"ok":true,"metrics":"report text","samples_per_sec":12.5,"#,
                r#""completed":3,"rejected":1}"#,
                "\n"
            )
        );
        assert_eq!(
            json_bytes(&WireResponse::Info {
                domains: vec!["text8".into(), "two_moons".into()],
                artifacts: 12,
            }),
            "{\"ok\":true,\"domains\":[\"text8\",\"two_moons\"],\"artifacts\":12}\n"
        );
        assert_eq!(json_bytes(&WireResponse::ShutdownAck), "{\"ok\":true}\n");
    }

    #[test]
    fn golden_request_lines() {
        let mut buf = Vec::new();
        JsonLines.write_request(&mut buf, &WireRequest::Ping).unwrap();
        assert_eq!(buf, b"{\"cmd\":\"ping\"}\n");
        let req = GenRequest::from_wire(
            "text8".into(),
            "ws_t080".into(),
            DraftSpec::Lstm,
            2,
            0.8,
            1024,
            WarpMode::Literal,
            7,
        )
        .unwrap();
        let mut buf = Vec::new();
        JsonLines
            .write_request(&mut buf, &WireRequest::Generate { request: req, decode: true })
            .unwrap();
        assert_eq!(
            String::from_utf8(buf).unwrap(),
            concat!(
                r#"{"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm","#,
                r#""n_samples":2,"t0":0.8,"steps":1024,"warp":"literal","seed":7,"decode":true}"#,
                "\n"
            )
        );
    }

    // -- goldens: the PR-9 observability surface ------------------------

    fn stats_fixture() -> MetricsSnapshot {
        use crate::metrics::{FleetSnapshot, ServingSnapshot};
        MetricsSnapshot {
            serving: ServingSnapshot {
                completed: 3,
                samples_per_sec: 12.5,
                ..ServingSnapshot::default()
            },
            fleet: Some(FleetSnapshot {
                replicas: 2,
                replica_inflight: vec![0, 1],
                replica_dispatched: vec![5, 6],
                fleet_reroutes: 1,
                ..FleetSnapshot::default()
            }),
        }
    }

    fn trace_fixture() -> WireResponse {
        WireResponse::Trace {
            request_id: 9,
            spans: vec![
                SpanRecord {
                    request_id: 9,
                    bundle_id: 4,
                    kind: SpanKind::Admit,
                    detail: 0,
                    start_us: 10,
                    dur_us: 3,
                },
                SpanRecord {
                    request_id: 0,
                    bundle_id: 4,
                    kind: SpanKind::EngineCall,
                    detail: 2,
                    start_us: 40,
                    dur_us: 1_200,
                },
            ],
        }
    }

    /// Pin the exact JSON-lines bytes of a stats reply: field order and
    /// numeric rendering are part of the wire contract now.
    #[test]
    fn golden_stats_line() {
        const ZERO_VAL: &str = r#"{"count":0,"mean":0,"p50":0,"p95":0,"min":0,"max":0}"#;
        const ZERO_LAT: &str =
            r#"{"count":0,"mean_ns":0,"p50_ns":0,"p95_ns":0,"p99_ns":0,"max_ns":0}"#;
        let want = format!(
            concat!(
                r#"{{"ok":true,"stats":{{"serving":{{"admitted":0,"rejected":0,"#,
                r#""completed":3,"batches":0,"denoiser_calls":0,"draft_calls":0,"#,
                r#""draft_models_resolved":0,"padded_rows":0,"inflight_bundles":0,"#,
                r#""nfe_saved":0,"cascade_early_exits":0,"early_flushes":0,"#,
                r#""degraded":0,"batch_occupancy":0,"wire_hellos":0,"#,
                r#""wire_codec_switches":0,"wire_malformed":0,"samples_total":0,"#,
                r#""samples_per_sec":12.5,"samples_per_sec_windowed":0,"#,
                r#""obs_spans_recorded":0,"obs_events_recorded":0,"#,
                r#""chosen_t0":{v},"rows_per_step":{v},"cascade_stage_nfe":{v},"#,
                r#""gate_eval":{l},"queue_wait":{l},"draft_queue_wait":{l},"#,
                r#""flush_lag":{l},"flush_early":{l},"batch_exec":{l},"#,
                r#""request_latency":{l}}},"#,
                r#""fleet":{{"replicas":2,"replica_inflight":[0,1],"#,
                r#""replica_dispatched":[5,6],"replica_unhealthy":0,"#,
                r#""fleet_reroutes":1,"replica_respawns":0,"respawn_failures":0,"#,
                r#""engine_timeouts":0,"artifact_swaps":0,"#,
                r#""artifact_swap_rollbacks":0}}}}}}"#,
                "\n"
            ),
            v = ZERO_VAL,
            l = ZERO_LAT,
        );
        assert_eq!(json_bytes(&WireResponse::Stats { snapshot: stats_fixture() }), want);
    }

    #[test]
    fn golden_stats_and_trace_request_lines() {
        let mut buf = Vec::new();
        JsonLines.write_request(&mut buf, &WireRequest::Stats).unwrap();
        assert_eq!(buf, b"{\"cmd\":\"stats\"}\n");
        let mut buf = Vec::new();
        JsonLines.write_request(&mut buf, &WireRequest::Trace { request_id: 7 }).unwrap();
        assert_eq!(buf, b"{\"cmd\":\"trace\",\"request_id\":7}\n");
    }

    #[test]
    fn golden_trace_line() {
        assert_eq!(
            json_bytes(&trace_fixture()),
            concat!(
                r#"{"ok":true,"request_id":9,"spans":["#,
                r#"{"request_id":9,"bundle_id":4,"kind":"admit","detail":0,"#,
                r#""start_us":10,"dur_us":3},"#,
                r#"{"request_id":0,"bundle_id":4,"kind":"engine_call","detail":2,"#,
                r#""start_us":40,"dur_us":1200}]}"#,
                "\n"
            )
        );
    }

    /// The opt-in timing breakdown renders only when present — a
    /// non-opted generate response stays byte-identical to the legacy
    /// golden above (`golden_generate_ok` pins that side).
    #[test]
    fn golden_generate_with_timing() {
        let resp = GenResponse {
            timing: Some(TimingInfo {
                nfe_floor: 205,
                segments: vec![(150, 41_000), (55, 11_000)],
                gate_us: vec![12],
                replicas: vec![0, 2],
                reroutes: 1,
            }),
            ..resp_fixture()
        };
        assert_eq!(
            json_bytes(&WireResponse::Generate { resp, texts: None }),
            concat!(
                r#"{"ok":true,"id":3,"nfe":205,"t0_used":0.8,"queue_us":120,"#,
                r#""draft_us":900,"refine_us":52000,"total_us":53100,"#,
                r#""timing":{"nfe_floor":205,"segments":[[150,41000],[55,11000]],"#,
                r#""gate_us":[12],"replicas":[0,2],"reroutes":1},"#,
                r#""samples":[[1,2],[3,4]]}"#,
                "\n"
            )
        );
    }

    // -- negotiation ----------------------------------------------------

    #[test]
    fn negotiate_picks_first_client_preference() {
        let server: Vec<String> = vec!["json".into(), "binary".into()];
        assert_eq!(negotiate(&server, &["binary".into(), "json".into()]), Some("binary"));
        assert_eq!(negotiate(&server, &["json".into()]), Some("json"));
        assert_eq!(negotiate(&server, &["zstd".into(), "json".into()]), Some("json"));
        assert_eq!(negotiate(&server, &["zstd".into()]), None);
        assert_eq!(negotiate(&server, &[]), None);
        let json_only: Vec<String> = vec!["json".into()];
        assert_eq!(negotiate(&json_only, &["binary".into()]), None);
    }

    #[test]
    fn make_resolves_supported_names() {
        for name in SUPPORTED {
            assert_eq!(make(name).unwrap().name(), *name);
        }
        assert!(make("zstd").is_none());
    }

    // -- binary round-trips ---------------------------------------------

    fn roundtrip_response(want: &WireResponse) {
        let payload = Binary::encode_response(want);
        let got = Binary::decode_response(&payload).unwrap();
        assert_eq!(&got, want);
        // And through the full framed stream path.
        let mut buf = Vec::new();
        Binary.write_response(&mut buf, want).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(&Binary.read_response(&mut cur).unwrap(), want);
    }

    #[test]
    fn binary_roundtrips_every_response_type() {
        roundtrip_response(&WireResponse::Pong);
        roundtrip_response(&WireResponse::ShutdownAck);
        roundtrip_response(&WireResponse::HelloAck { codec: "binary".into() });
        roundtrip_response(&WireResponse::Error { msg: "no \"such\" cmd".into(), busy: false });
        roundtrip_response(&WireResponse::Error { msg: "overload".into(), busy: true });
        roundtrip_response(&WireResponse::Busy { retry_after_ms: u64::MAX });
        roundtrip_response(&WireResponse::Metrics {
            report: "multi\nline ünïcode".into(),
            samples_per_sec: 1234.5678,
            completed: u64::MAX,
            rejected: 0,
        });
        roundtrip_response(&WireResponse::Info {
            domains: vec!["text8".into(), "wiki".into()],
            artifacts: 12,
        });
        roundtrip_response(&WireResponse::Generate { resp: resp_fixture(), texts: None });
        roundtrip_response(&WireResponse::Generate {
            resp: GenResponse {
                cascade: Some(CascadeInfo {
                    stages_used: 3,
                    nfe_per_stage: vec![100, 50, 25],
                    early_exit: true,
                }),
                degraded: Some("draft fallback".into()),
                ..resp_fixture()
            },
            texts: Some(vec!["ab".into(), String::new(), "☃".into()]),
        });
        // Empty-everything edge.
        roundtrip_response(&WireResponse::Generate {
            resp: GenResponse { samples: vec![], ..resp_fixture() },
            texts: Some(vec![]),
        });
        // PR-9 observability surface: stats + trace survive the binary
        // framing exactly, including a timing-bearing generate response.
        roundtrip_response(&WireResponse::Stats { snapshot: stats_fixture() });
        roundtrip_response(&WireResponse::Stats { snapshot: MetricsSnapshot::default() });
        roundtrip_response(&trace_fixture());
        roundtrip_response(&WireResponse::Trace { request_id: 1, spans: vec![] });
        roundtrip_response(&WireResponse::Generate {
            resp: GenResponse {
                timing: Some(TimingInfo {
                    nfe_floor: 205,
                    segments: vec![(150, 41_000), (55, 11_000)],
                    gate_us: vec![12, 9],
                    replicas: vec![0, 2],
                    reroutes: 1,
                }),
                ..resp_fixture()
            },
            texts: None,
        });
        // Empty timing vectors (cascade off, no gates) round-trip too.
        roundtrip_response(&WireResponse::Generate {
            resp: GenResponse { timing: Some(TimingInfo::default()), ..resp_fixture() },
            texts: None,
        });
    }

    #[test]
    fn binary_roundtrips_every_request_type() {
        let cases = vec![
            WireRequest::Ping,
            WireRequest::Metrics,
            WireRequest::Info,
            WireRequest::Shutdown,
            WireRequest::Hello { codecs: vec!["binary".into(), "json".into()] },
            WireRequest::Hello { codecs: vec![] },
            WireRequest::Stats,
            WireRequest::Trace { request_id: u64::MAX },
            WireRequest::Generate {
                request: {
                    let mut r = GenRequest::from_wire(
                        "text8".into(),
                        "ws_t080".into(),
                        DraftSpec::Lstm,
                        1,
                        0.8,
                        128,
                        WarpMode::Literal,
                        7,
                    )
                    .unwrap();
                    r.timing = true; // opt-in flag survives the frame
                    r
                },
                decode: false,
            },
            WireRequest::Generate {
                request: GenRequest::from_wire(
                    "text8".into(),
                    "ws_t080".into(),
                    DraftSpec::Lstm,
                    2,
                    0.8,
                    1024,
                    WarpMode::Exact,
                    u64::MAX, // seed precision survives binary too
                )
                .unwrap(),
                decode: true,
            },
        ];
        for want in cases {
            let payload = Binary::encode_request(&want);
            let got = Binary::decode_request(&payload).unwrap();
            assert_eq!(got, want);
            let mut buf = Vec::new();
            Binary.write_request(&mut buf, &want).unwrap();
            let mut cur = Cursor::new(buf);
            match Binary.read_request(&mut cur).unwrap().unwrap() {
                Decoded::Request(r) => assert_eq!(r, want),
                Decoded::Malformed { msg, .. } => panic!("malformed: {msg}"),
            }
        }
    }

    // -- property: random generate responses round-trip losslessly ------

    struct GenRespStrategy;

    impl Strategy for GenRespStrategy {
        type Value = WireResponse;
        fn generate(&self, rng: &mut Pcg64) -> WireResponse {
            let n_rows = rng.below(5) as usize;
            let row_len = rng.below(64) as usize;
            let samples = (0..n_rows)
                .map(|_| (0..row_len).map(|_| rng.next_u32() as i32).collect())
                .collect();
            let cascade = if rng.below(2) == 1 {
                let stages = 1 + rng.below(4) as usize;
                Some(CascadeInfo {
                    stages_used: stages,
                    nfe_per_stage: (0..stages).map(|_| rng.below(500) as usize).collect(),
                    early_exit: rng.below(2) == 1,
                })
            } else {
                None
            };
            let degraded =
                if rng.below(4) == 0 { Some(format!("reason {}", rng.below(100))) } else { None };
            let timing = if rng.below(3) == 0 {
                Some(TimingInfo {
                    nfe_floor: rng.below(500) as usize,
                    segments: (0..rng.below(4))
                        .map(|_| (rng.below(500) as usize, rng.next_u32() as u64))
                        .collect(),
                    gate_us: (0..rng.below(4)).map(|_| rng.next_u32() as u64).collect(),
                    replicas: (0..rng.below(3)).map(|_| rng.below(8) as u32).collect(),
                    reroutes: rng.below(3) as u32,
                })
            } else {
                None
            };
            let texts = if rng.below(2) == 1 {
                Some((0..n_rows).map(|i| format!("text {i} é")).collect())
            } else {
                None
            };
            WireResponse::Generate {
                resp: GenResponse {
                    id: rng.next_u64(),
                    samples,
                    nfe: rng.below(10_000) as usize,
                    t0_used: rng.uniform(),
                    cascade,
                    queue_wait: Duration::from_micros(rng.next_u32() as u64),
                    draft_time: Duration::from_micros(rng.next_u32() as u64),
                    refine_time: Duration::from_micros(rng.next_u32() as u64),
                    total_time: Duration::from_micros(rng.next_u32() as u64),
                    degraded,
                    timing,
                },
                texts,
            }
        }
    }

    #[test]
    fn prop_binary_generate_roundtrip_lossless() {
        check("binary generate round-trip", GenRespStrategy, |resp| {
            let got = Binary::decode_response(&Binary::encode_response(resp))
                .map_err(|e| format!("{e:#}"))?;
            if &got == resp {
                Ok(())
            } else {
                Err(format!("mismatch: {got:?}"))
            }
        });
    }

    /// The JSON codec round-trips the same random responses (it carries
    /// µs-granularity ints and f64s, which is exactly what GenResponse
    /// holds — so equality is exact here too).
    #[test]
    fn prop_json_generate_roundtrip() {
        check("json generate round-trip", GenRespStrategy, |resp| {
            let line = render_wire_response(resp);
            let got = parse_response(&line).map_err(|e| format!("{e:#}"))?;
            if &got == resp {
                Ok(())
            } else {
                Err(format!("mismatch: {got:?} from {line}"))
            }
        });
    }

    // -- hostile input: truncation and oversized frames -----------------

    #[test]
    fn truncated_mid_length_prefix_is_fatal_not_a_hang() {
        let mut cur = Cursor::new(vec![0x10u8, 0x00]); // 2 of 4 length bytes
        match Binary.read_request(&mut cur).unwrap().unwrap() {
            Decoded::Malformed { msg, fatal } => {
                assert!(fatal, "lost framing must close the connection");
                assert!(msg.contains("truncated"), "{msg}");
            }
            other => panic!("expected malformed, got {other:?}"),
        }
        // Clean EOF (zero bytes) is a normal connection end, not an error.
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(Binary.read_request(&mut empty).unwrap().is_none());
    }

    #[test]
    fn truncated_payload_is_fatal() {
        let mut frame = 32u32.to_le_bytes().to_vec();
        frame.extend_from_slice(&[FRAME_VERSION, RQ_PING]); // 2 of 32 bytes
        let mut cur = Cursor::new(frame);
        match Binary.read_request(&mut cur).unwrap().unwrap() {
            Decoded::Malformed { fatal, .. } => assert!(fatal),
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        // Claims a 4 GiB-1 payload; must be rejected from the 4-byte
        // prefix alone (the cursor holds nothing else to allocate from).
        let mut cur = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        match Binary.read_request(&mut cur).unwrap().unwrap() {
            Decoded::Malformed { msg, fatal } => {
                assert!(fatal);
                assert!(msg.contains("exceeds maximum"), "{msg}");
            }
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn forged_count_inside_frame_is_rejected_before_allocating() {
        // A well-framed generate response whose row count claims 2^31
        // rows with only a handful of payload bytes behind it.
        let mut p = vec![FRAME_VERSION, RS_GENERATE];
        put_u64(&mut p, 1); // id
        put_u64(&mut p, 0); // nfe
        put_f64(&mut p, 0.5);
        for _ in 0..4 {
            put_u64(&mut p, 0); // timings
        }
        p.push(0); // no cascade
        p.push(0); // no degraded
        p.push(0); // no timing
        put_u32(&mut p, 0x8000_0000); // forged row count
        let err = Binary::decode_response(&p).unwrap_err();
        assert!(format!("{err:#}").contains("count"), "{err:#}");
    }

    #[test]
    fn bad_field_in_well_framed_request_is_nonfatal() {
        // Unknown draft name inside an intact frame: the connection can
        // keep serving after the error reply.
        let mut p = vec![FRAME_VERSION, RQ_GENERATE];
        put_str(&mut p, "text8");
        put_str(&mut p, "cold");
        put_str(&mut p, "warpdrive"); // not a draft
        put_u32(&mut p, 1);
        put_f64(&mut p, 0.5);
        put_u32(&mut p, 10);
        p.push(0);
        put_u64(&mut p, 1);
        p.push(0);
        let mut frame = (p.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&p);
        let mut cur = Cursor::new(frame);
        match Binary.read_request(&mut cur).unwrap().unwrap() {
            Decoded::Malformed { msg, fatal } => {
                assert!(!fatal, "frame boundary intact — keep the connection");
                assert!(msg.contains("draft"), "{msg}");
            }
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn forged_span_count_in_trace_frame_is_rejected_before_allocating() {
        // A trace reply claiming 100M spans with 4 bytes of payload: the
        // count check (37 bytes per span) rejects it pre-allocation.
        let mut p = vec![FRAME_VERSION, RS_TRACE];
        put_u64(&mut p, 9);
        put_u32(&mut p, 100_000_000);
        put_u32(&mut p, 0); // a few real bytes, nowhere near 100M spans
        let err = Binary::decode_response(&p).unwrap_err();
        assert!(format!("{err:#}").contains("count"), "{err:#}");
        // And an unknown span-kind byte inside a well-formed frame is a
        // typed decode error, not a panic.
        let mut p = vec![FRAME_VERSION, RS_TRACE];
        put_u64(&mut p, 9);
        put_u32(&mut p, 1);
        put_u64(&mut p, 9); // span request_id
        put_u64(&mut p, 4); // bundle_id
        p.push(200); // not a SpanKind
        put_u32(&mut p, 0);
        put_u64(&mut p, 0);
        put_u64(&mut p, 0);
        let err = Binary::decode_response(&p).unwrap_err();
        assert!(format!("{err:#}").contains("span kind"), "{err:#}");
    }

    #[test]
    fn truncated_stats_json_inside_binary_frame_is_rejected() {
        let mut p = vec![FRAME_VERSION, RS_STATS];
        put_str(&mut p, r#"{"serving":{"admitted":"#); // cut mid-object
        let err = Binary::decode_response(&p).unwrap_err();
        assert!(format!("{err:#}").contains("stats json"), "{err:#}");
    }

    #[test]
    fn trailing_garbage_in_frame_is_rejected() {
        let mut p = Binary::encode_request(&WireRequest::Ping);
        p.push(0xFF);
        assert!(Binary::decode_request(&p).unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn wrong_version_byte_is_rejected() {
        let mut p = Binary::encode_request(&WireRequest::Ping);
        p[0] = 9;
        assert!(Binary::decode_request(&p).unwrap_err().to_string().contains("version"));
    }
}
