//! Threaded TCP server: accept loop + one handler thread per connection,
//! all sharing the coordinator [`Service`].

use crate::coordinator::request::GenResponse;
use crate::coordinator::Service;
use crate::data::tokenizer::{CharTokenizer, WordTokenizer};
use crate::runtime::Manifest;
use crate::server::protocol::{parse_request, render_busy, render_error, render_response, WireRequest};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// The server. `run()` blocks until `shutdown` (or a client sends
/// `{"cmd":"shutdown"}`).
pub struct TcpServer {
    pub service: Service,
    pub manifest: Arc<Manifest>,
    word_tok: Option<Arc<WordTokenizer>>,
    stop: Arc<AtomicBool>,
    pub local_addr: std::net::SocketAddr,
    listener: TcpListener,
}

impl TcpServer {
    /// Bind. Pass `addr = "127.0.0.1:0"` for an ephemeral port (tests).
    pub fn bind(addr: &str, service: Service, manifest: Manifest) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?;
        // Word tokenizer for the wiki domain, if its vocab is present.
        let vocab_path = manifest.dir.join("wiki_vocab.json");
        let word_tok = std::fs::read_to_string(&vocab_path)
            .ok()
            .and_then(|t| WordTokenizer::from_json(&t).ok())
            .map(Arc::new);
        Ok(TcpServer {
            service,
            manifest: Arc::new(manifest),
            word_tok,
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
            listener,
        })
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop. Returns when stopped.
    pub fn run(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        crate::info!("listening on {}", self.local_addr);
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    crate::debug!("connection from {peer}");
                    stream.set_nonblocking(false).ok();
                    let service = self.service.clone();
                    let manifest = self.manifest.clone();
                    let word_tok = self.word_tok.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, service, manifest, word_tok, stop) {
                            crate::debug!("connection ended: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn decode_samples(
    domain: &str,
    resp: &GenResponse,
    word_tok: &Option<Arc<WordTokenizer>>,
) -> Option<Vec<String>> {
    match domain {
        "text8" => {
            let tok = CharTokenizer;
            Some(resp.samples.iter().map(|s| tok.decode(s)).collect())
        }
        "wiki" => word_tok.as_ref().map(|t| resp.samples.iter().map(|s| t.decode(s)).collect()),
        _ => None,
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Service,
    manifest: Arc<Manifest>,
    word_tok: Option<Arc<WordTokenizer>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Err(e) => render_error(&format!("{e:#}"), false),
            Ok(WireRequest::Ping) => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]).to_string(),
            Ok(WireRequest::Metrics) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("metrics", Json::str(service.metrics.report())),
                ("samples_per_sec", Json::num(service.metrics.samples.per_second())),
                ("completed", Json::num(service.metrics.requests_completed.get() as f64)),
                ("rejected", Json::num(service.metrics.requests_rejected.get() as f64)),
            ])
            .to_string(),
            Ok(WireRequest::Info) => {
                let domains = manifest.domain_names();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("domains", Json::arr(domains.iter().map(|d| Json::str(d.clone())))),
                    ("artifacts", Json::num(manifest.artifacts.len() as f64)),
                ])
                .to_string()
            }
            Ok(WireRequest::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))]).to_string()
            }
            Ok(WireRequest::Generate { request, decode }) => {
                let domain = request.domain.clone();
                match service.submit(request) {
                    // Typed BUSY: backpressure with a retry-after hint,
                    // not a generic error string.
                    Err(_) => render_busy(service.retry_after()),
                    Ok(rx) => match rx.recv() {
                        Ok(Ok(resp)) => {
                            let texts =
                                if decode { decode_samples(&domain, &resp, &word_tok) } else { None };
                            render_response(&resp, texts)
                        }
                        Ok(Err(msg)) => render_error(&msg, false),
                        Err(_) => render_error("coordinator gone", false),
                    },
                }
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WsfmConfig;
    use crate::coordinator::testutil::{mock_manifest, TestExec};
    use std::time::Duration;

    /// End-to-end BUSY: saturate a tiny admission queue behind a slow
    /// refine and assert the wire response is the typed backpressure
    /// object (`busy: true` + `retry_after_ms`), while every admitted
    /// request still completes.
    #[test]
    fn queue_full_surfaces_typed_busy_response() {
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.step_sleep = Duration::from_millis(20); // 5 steps -> ~100ms/bundle
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.queue_capacity = 2;
        cfg.batcher.max_batch = 1; // dispatch every request immediately
        cfg.batcher.max_wait_us = 5_000;
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 1;
        let service = Service::start(exec, manifest, cfg);

        let server =
            TcpServer::bind("127.0.0.1:0", service.clone(), mock_manifest(&["cold"], &[1, 4], 2, 4))
                .unwrap();
        let addr = server.local_addr.to_string();
        let stop = server.stop_handle();
        let server_thread = std::thread::spawn(move || server.run());

        // 16 concurrent one-shot clients against capacity:
        // 2 inflight (gate) + 1 parked in dispatch + 2 queued = 5 slots.
        let clients: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = crate::server::Client::connect(&addr).unwrap();
                    let line = format!(
                        r#"{{"cmd":"generate","domain":"mock","tag":"cold","draft":"noise","n_samples":1,"t0":0.5,"steps":10,"seed":{i}}}"#
                    );
                    c.roundtrip(&line).unwrap()
                })
            })
            .collect();

        let mut busy = 0;
        let mut ok = 0;
        let mut max_hint_ms = 0usize;
        for c in clients {
            let j = c.join().unwrap();
            if j.get("ok").as_bool() == Some(true) {
                ok += 1;
            } else {
                assert_eq!(j.get("busy").as_bool(), Some(true), "non-busy error: {j}");
                let hint = j.get("retry_after_ms").as_usize().unwrap_or(0);
                assert!(hint >= 1);
                max_hint_ms = max_hint_ms.max(hint);
                busy += 1;
            }
        }
        assert!(busy >= 1, "expected at least one BUSY rejection (ok={ok})");
        assert!(ok >= 1, "expected at least one completion");
        assert_eq!(ok + busy, 16);
        // The hint is occupancy-derived: rejections happened while the
        // pipeline was saturated, so at least one busy slot's flush
        // interval (5 ms) rode on top of the 1 ms floor.
        assert!(max_hint_ms >= 6, "saturated hint should scale with occupancy, got {max_hint_ms}");

        // Once everything drains (inflight gate released, queue empty),
        // the same service hints "retry basically now" instead of the
        // static config value — the fix for the stale BUSY hint.
        let t0 = std::time::Instant::now();
        while service.retry_after() != Duration::from_millis(1) {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "retry_after never drained: {:?}",
                service.retry_after()
            );
            std::thread::sleep(Duration::from_millis(1));
        }

        stop.store(true, Ordering::SeqCst);
        let _ = server_thread.join().unwrap();
        service.shutdown();
    }
}
