//! Threaded TCP server: accept loop + one handler thread per connection,
//! all sharing the coordinator [`Service`]. Each connection speaks a
//! [`Codec`](crate::server::codec::Codec): the configured default
//! (legacy JSON lines unless `wire.default` says otherwise) until a
//! client hello negotiates another one.

use crate::config::WireConfig;
use crate::coordinator::request::GenResponse;
use crate::coordinator::Service;
use crate::data::tokenizer::{CharTokenizer, WordTokenizer};
use crate::fleet::FleetHandle;
use crate::metrics::MetricsSnapshot;
use crate::obs::EventKind;
use crate::runtime::Manifest;
use crate::server::codec::{self, Decoded};
use crate::server::protocol::{WireRequest, WireResponse};
use anyhow::{Context, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// The server. `run()` blocks until `shutdown` (or a client sends
/// `{"cmd":"shutdown"}`).
pub struct TcpServer {
    pub service: Service,
    pub manifest: Arc<Manifest>,
    word_tok: Option<Arc<WordTokenizer>>,
    stop: Arc<AtomicBool>,
    pub local_addr: std::net::SocketAddr,
    listener: TcpListener,
    wire: WireConfig,
    /// Fleet handle for the stats surface (`{"cmd":"stats"}` includes a
    /// fleet section only when one is attached via [`with_fleet`]).
    ///
    /// [`with_fleet`]: TcpServer::with_fleet
    fleet: Option<FleetHandle>,
}

impl TcpServer {
    /// Bind with the default wire config (legacy JSON + binary offered,
    /// connections start on JSON). Pass `addr = "127.0.0.1:0"` for an
    /// ephemeral port (tests).
    pub fn bind(addr: &str, service: Service, manifest: Manifest) -> Result<TcpServer> {
        Self::bind_with(addr, service, manifest, WireConfig::default())
    }

    /// Bind with an explicit `wire.{codecs,default}` config.
    pub fn bind_with(
        addr: &str,
        service: Service,
        manifest: Manifest,
        wire: WireConfig,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?;
        // Word tokenizer for the wiki domain, if its vocab is present.
        let vocab_path = manifest.dir.join("wiki_vocab.json");
        let word_tok = std::fs::read_to_string(&vocab_path)
            .ok()
            .and_then(|t| WordTokenizer::from_json(&t).ok())
            .map(Arc::new);
        Ok(TcpServer {
            service,
            manifest: Arc::new(manifest),
            word_tok,
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
            listener,
            wire,
            fleet: None,
        })
    }

    /// Expose a fleet's metrics on the stats surface. The serving CLI
    /// attaches the same fleet it hands the coordinator, so one stats
    /// reply carries both the serving and per-replica views.
    pub fn with_fleet(mut self, fleet: FleetHandle) -> Self {
        self.fleet = Some(fleet);
        self
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop. Returns when stopped.
    pub fn run(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        crate::info!("listening on {}", self.local_addr);
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    crate::debug!("connection from {peer}");
                    stream.set_nonblocking(false).ok();
                    let service = self.service.clone();
                    let manifest = self.manifest.clone();
                    let word_tok = self.word_tok.clone();
                    let stop = self.stop.clone();
                    let wire = self.wire.clone();
                    let fleet = self.fleet.clone();
                    std::thread::spawn(move || {
                        if let Err(e) =
                            handle_conn(stream, service, manifest, word_tok, stop, wire, fleet)
                        {
                            crate::debug!("connection ended: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

fn decode_samples(
    domain: &str,
    resp: &GenResponse,
    word_tok: &Option<Arc<WordTokenizer>>,
) -> Option<Vec<String>> {
    match domain {
        "text8" => {
            let tok = CharTokenizer;
            Some(resp.samples.iter().map(|s| tok.decode(s)).collect())
        }
        "wiki" => word_tok.as_ref().map(|t| resp.samples.iter().map(|s| t.decode(s)).collect()),
        _ => None,
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Service,
    manifest: Arc<Manifest>,
    word_tok: Option<Arc<WordTokenizer>>,
    stop: Arc<AtomicBool>,
    wire: WireConfig,
    fleet: Option<FleetHandle>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Every connection starts on the configured default codec; a hello
    // can switch it. `wire.default` is validated against the supported
    // set at config load, so `make` cannot miss here.
    let mut active =
        codec::make(&wire.default).with_context(|| format!("unknown codec {:?}", wire.default))?;
    loop {
        let decoded = match active.read_request(&mut reader)? {
            None => break, // clean EOF
            Some(d) => d,
        };
        let mut fatal = false;
        let reply = match decoded {
            Decoded::Malformed { msg, fatal: f } => {
                service.metrics.wire_malformed.inc();
                fatal = f;
                WireResponse::Error { msg, busy: false }
            }
            Decoded::Request(WireRequest::Ping) => WireResponse::Pong,
            Decoded::Request(WireRequest::Metrics) => WireResponse::Metrics {
                report: service.metrics.report(),
                samples_per_sec: service.metrics.samples.per_second(),
                completed: service.metrics.requests_completed.get(),
                rejected: service.metrics.requests_rejected.get(),
            },
            Decoded::Request(WireRequest::Info) => WireResponse::Info {
                domains: manifest.domain_names(),
                artifacts: manifest.artifacts.len(),
            },
            Decoded::Request(WireRequest::Stats) => WireResponse::Stats {
                snapshot: MetricsSnapshot {
                    serving: service.metrics.snapshot(),
                    fleet: fleet.as_ref().map(|f| f.metrics().snapshot()),
                },
            },
            Decoded::Request(WireRequest::Trace { request_id }) => {
                let spans = service.metrics.obs.spans.for_request(request_id);
                if spans.is_empty() {
                    // Typed error, never a hang: unknown id, tracing
                    // disabled, or the spans aged out of the ring.
                    WireResponse::Error {
                        msg: format!(
                            "no trace for request_id {request_id} (unknown id, tracing disabled, or spans evicted)"
                        ),
                        busy: false,
                    }
                } else {
                    WireResponse::Trace { request_id, spans }
                }
            }
            Decoded::Request(WireRequest::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                WireResponse::ShutdownAck
            }
            Decoded::Request(WireRequest::Hello { codecs }) => {
                service.metrics.wire_hellos.inc();
                match codec::negotiate(&wire.codecs, &codecs) {
                    Some(name) => {
                        // Ack in the *current* codec, then switch: the
                        // client reads the ack before re-framing.
                        active.write_response(
                            &mut writer,
                            &WireResponse::HelloAck { codec: name.to_string() },
                        )?;
                        if name != active.name() {
                            service.metrics.wire_codec_switches.inc();
                            service.metrics.obs.event(
                                EventKind::CodecSwitch,
                                None,
                                format!("connection re-framed {} -> {name}", active.name()),
                            );
                            active = codec::make(name)
                                .with_context(|| format!("negotiated codec {name:?}"))?;
                        }
                        continue;
                    }
                    None => WireResponse::Error {
                        msg: format!(
                            "no mutually supported codec (server offers {:?})",
                            wire.codecs
                        ),
                        busy: false,
                    },
                }
            }
            Decoded::Request(WireRequest::Generate { request, decode }) => {
                let domain = request.domain.clone();
                match service.submit(request) {
                    // Typed BUSY: backpressure with a retry-after hint,
                    // not a generic error string. Journaled so admission
                    // rejections are visible in `{"cmd":"trace"}` land.
                    Err(_) => {
                        let retry_after_ms =
                            (service.retry_after().as_millis().max(1)) as u64;
                        service.metrics.obs.event(
                            EventKind::Busy,
                            None,
                            format!("retry_after_ms={retry_after_ms}"),
                        );
                        WireResponse::Busy { retry_after_ms }
                    }
                    Ok(rx) => match rx.recv() {
                        Ok(Ok(resp)) => {
                            let texts =
                                if decode { decode_samples(&domain, &resp, &word_tok) } else { None };
                            WireResponse::Generate { resp, texts }
                        }
                        Ok(Err(msg)) => WireResponse::Error { msg, busy: false },
                        Err(_) => {
                            WireResponse::Error { msg: "coordinator gone".into(), busy: false }
                        }
                    },
                }
            }
        };
        active.write_response(&mut writer, &reply)?;
        if fatal || stop.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WsfmConfig;
    use crate::coordinator::testutil::{mock_manifest, TestExec};
    use crate::server::client::Client;
    use std::io::{BufRead, Read, Write};
    use std::time::Duration;

    fn start_server() -> (String, Arc<AtomicBool>, std::thread::JoinHandle<Result<()>>, Service) {
        let exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_wait_us = 2_000;
        let service = Service::start(exec, manifest, cfg);
        let server =
            TcpServer::bind("127.0.0.1:0", service.clone(), mock_manifest(&["cold"], &[1, 4], 2, 4))
                .unwrap();
        let addr = server.local_addr.to_string();
        let stop = server.stop_handle();
        let thread = std::thread::spawn(move || server.run());
        (addr, stop, thread, service)
    }

    /// Tentpole pin: a client that never sends a hello gets the legacy
    /// JSON wire format **byte-for-byte** — raw socket, exact bytes.
    #[test]
    fn absent_hello_is_byte_identical_legacy_json() {
        let (addr, stop, thread, service) = start_server();
        let mut sock = TcpStream::connect(&addr).unwrap();
        sock.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        let mut reader = std::io::BufReader::new(sock.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "{\"ok\":true,\"pong\":true}\n");
        sock.write_all(b"{\"cmd\":\"info\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "{\"ok\":true,\"domains\":[\"mock\"],\"artifacts\":2}\n");
        // Malformed line: typed error, connection stays open.
        sock.write_all(b"not json\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("{\"ok\":false,\"error\":\"malformed json"), "{line}");
        sock.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "{\"ok\":true,\"pong\":true}\n");
        assert_eq!(service.metrics.wire_malformed.get(), 1);
        assert_eq!(service.metrics.wire_hellos.get(), 0);
        stop.store(true, Ordering::SeqCst);
        drop(reader);
        let _ = TcpStream::connect(&addr); // nudge the accept loop
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// Negotiation: hello → ack (in the old codec) → binary frames both
    /// ways, including a full generate.
    #[test]
    fn hello_negotiates_binary_and_serves_generate() {
        let (addr, stop, thread, service) = start_server();
        let mut c = Client::connect(&addr).unwrap();
        assert_eq!(c.negotiate(&["binary", "json"]).unwrap(), "binary");
        assert_eq!(c.codec_name(), "binary");
        assert!(c.ping().unwrap());
        let reply = c.generate("mock", "cold", "noise", 2, 0.5, 10, 7, false).unwrap();
        assert_eq!(reply.samples.len(), 2);
        assert!(c.metrics().unwrap().get("completed").as_u64().unwrap_or(0) >= 1);
        assert_eq!(service.metrics.wire_hellos.get(), 1);
        assert_eq!(service.metrics.wire_codec_switches.get(), 1);
        stop.store(true, Ordering::SeqCst);
        drop(c);
        let _ = TcpStream::connect(&addr);
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// Edge: a hello offering only unknown codecs gets a typed error and
    /// the connection keeps serving on the current codec.
    #[test]
    fn unknown_codec_hello_errors_and_stays_on_json() {
        let (addr, stop, thread, service) = start_server();
        let mut c = Client::connect(&addr).unwrap();
        let err = c.negotiate(&["zstd", "capnp"]).unwrap_err();
        assert!(format!("{err:#}").contains("no mutually supported codec"), "{err:#}");
        // Still on JSON, still serving.
        assert_eq!(c.codec_name(), "json");
        assert!(c.ping().unwrap());
        stop.store(true, Ordering::SeqCst);
        drop(c);
        let _ = TcpStream::connect(&addr);
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// Edge: on a binary connection, an oversized length prefix gets a
    /// typed error reply and the connection closes (framing is lost) —
    /// no hang, no allocation of the claimed size.
    #[test]
    fn binary_oversized_frame_gets_typed_error_then_close() {
        use crate::server::codec::Binary;
        use crate::server::codec::Codec as _;
        let (addr, stop, thread, service) = start_server();
        let mut c = Client::connect(&addr).unwrap();
        c.negotiate(&["binary"]).unwrap();
        // Hand-write a hostile frame under the negotiated codec.
        let mut sock = c.into_stream();
        sock.write_all(&u32::MAX.to_le_bytes()).unwrap();
        sock.flush().unwrap();
        let mut reader = std::io::BufReader::new(sock.try_clone().unwrap());
        let resp = Binary.read_response(&mut reader).unwrap();
        match resp {
            WireResponse::Error { msg, busy } => {
                assert!(!busy);
                assert!(msg.contains("exceeds maximum"), "{msg}");
            }
            other => panic!("expected error, got {other:?}"),
        }
        // Server closed after the fatal framing error.
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server left bytes after fatal error");
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(&addr);
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// CI matrix hook: the same socket workout under whichever codec
    /// `WSFM_WIRE_CODEC` selects (json when unset).
    #[test]
    fn socket_workout_under_env_codec() {
        let (addr, stop, thread, service) = start_server();
        let mut c = Client::connect_env(&addr).unwrap();
        assert!(c.ping().unwrap());
        let reply = c.generate("mock", "cold", "noise", 1, 0.5, 10, 3, false).unwrap();
        assert_eq!(reply.samples.len(), 1);
        let m = c.metrics().unwrap();
        assert!(m.get("completed").as_u64().unwrap_or(0) >= 1, "{m}");
        // PR-9: the typed stats surface rides the same matrix — both
        // codecs must agree with the legacy metrics counter.
        let snap = c.stats().unwrap();
        assert!(snap.serving.completed >= 1, "{:?}", snap.serving);
        assert_eq!(snap.serving.completed, m.get("completed").as_u64().unwrap());
        stop.store(true, Ordering::SeqCst);
        drop(c);
        let _ = TcpStream::connect(&addr);
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// Tentpole: the live stats + trace surface end to end on BOTH
    /// codecs. A traced generate carries its timing breakdown, its spans
    /// are retrievable by request id, and an unknown id gets a typed
    /// error instead of a hang — on the legacy JSON wire and again after
    /// negotiating binary frames.
    #[test]
    fn stats_and_trace_serve_on_both_codecs() {
        let (addr, stop, thread, service) = start_server();
        for codec in ["json", "binary"] {
            let mut c = Client::connect(&addr).unwrap();
            if codec == "binary" {
                assert_eq!(c.negotiate(&["binary"]).unwrap(), "binary");
            }
            let resp = c.generate_timed("mock", "cold", "noise", 1, 0.5, 10, 7).unwrap();
            let t = resp.timing.as_ref().unwrap_or_else(|| panic!("[{codec}] timing absent"));
            assert!(t.nfe_floor >= resp.nfe, "[{codec}] floor {} < nfe {}", t.nfe_floor, resp.nfe);
            assert!(!t.segments.is_empty(), "[{codec}] no segments");
            // Typed stats: the serving section counts this request; no
            // fleet was attached, so that section is absent.
            let snap = c.stats().unwrap();
            assert!(snap.serving.completed >= 1, "[{codec}] {:?}", snap.serving);
            assert!(snap.serving.obs_spans_recorded >= 1, "[{codec}] no spans recorded");
            assert!(snap.fleet.is_none(), "[{codec}] fleet section without a fleet");
            // Trace by the id the generate reply carried.
            let spans = c.trace(resp.id).unwrap();
            assert!(!spans.is_empty(), "[{codec}] empty trace for id {}", resp.id);
            assert!(
                spans.iter().any(|s| s.kind == crate::obs::SpanKind::Admit),
                "[{codec}] trace missing the admission span: {spans:?}"
            );
            assert!(
                spans.windows(2).all(|w| w[0].start_us <= w[1].start_us),
                "[{codec}] spans not time-ordered"
            );
            // Unknown id: typed error, connection keeps serving.
            let err = c.trace(u64::MAX).unwrap_err();
            assert!(format!("{err:#}").contains("no trace"), "[{codec}] {err:#}");
            assert!(c.ping().unwrap(), "[{codec}] connection died after trace error");
        }
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(&addr);
        let _ = thread.join().unwrap();
        service.shutdown();
    }

    /// End-to-end BUSY: saturate a tiny admission queue behind a slow
    /// refine and assert the wire response is the typed backpressure
    /// object (`busy: true` + `retry_after_ms`), while every admitted
    /// request still completes.
    #[test]
    fn queue_full_surfaces_typed_busy_response() {
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.step_sleep = Duration::from_millis(20); // 5 steps -> ~100ms/bundle
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.queue_capacity = 2;
        cfg.batcher.max_batch = 1; // dispatch every request immediately
        cfg.batcher.max_wait_us = 5_000;
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 1;
        let service = Service::start(exec, manifest, cfg);

        let server =
            TcpServer::bind("127.0.0.1:0", service.clone(), mock_manifest(&["cold"], &[1, 4], 2, 4))
                .unwrap();
        let addr = server.local_addr.to_string();
        let stop = server.stop_handle();
        let server_thread = std::thread::spawn(move || server.run());

        // 16 concurrent one-shot clients against capacity:
        // 2 inflight (gate) + 1 parked in dispatch + 2 queued = 5 slots.
        let clients: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = crate::server::Client::connect(&addr).unwrap();
                    let line = format!(
                        r#"{{"cmd":"generate","domain":"mock","tag":"cold","draft":"noise","n_samples":1,"t0":0.5,"steps":10,"seed":{i}}}"#
                    );
                    c.roundtrip(&line).unwrap()
                })
            })
            .collect();

        let mut busy = 0;
        let mut ok = 0;
        let mut max_hint_ms = 0usize;
        for c in clients {
            let j = c.join().unwrap();
            if j.get("ok").as_bool() == Some(true) {
                ok += 1;
            } else {
                assert_eq!(j.get("busy").as_bool(), Some(true), "non-busy error: {j}");
                let hint = j.get("retry_after_ms").as_usize().unwrap_or(0);
                assert!(hint >= 1);
                max_hint_ms = max_hint_ms.max(hint);
                busy += 1;
            }
        }
        assert!(busy >= 1, "expected at least one BUSY rejection (ok={ok})");
        assert!(ok >= 1, "expected at least one completion");
        assert_eq!(ok + busy, 16);
        // Every BUSY rejection is journaled with its retry hint: the
        // event journal is how post-hoc analysis sees admission pressure.
        let busy_events = service.metrics.obs.events.of_kind(crate::obs::EventKind::Busy);
        assert_eq!(busy_events.len(), busy, "one Busy event per rejection");
        assert!(busy_events.iter().all(|e| e.detail.starts_with("retry_after_ms=")));
        // The hint is occupancy-derived: rejections happened while the
        // pipeline was saturated, so at least one busy slot's flush
        // interval (5 ms) rode on top of the 1 ms floor.
        assert!(max_hint_ms >= 6, "saturated hint should scale with occupancy, got {max_hint_ms}");

        // Once everything drains (inflight gate released, queue empty),
        // the same service hints "retry basically now" instead of the
        // static config value — the fix for the stale BUSY hint.
        let t0 = std::time::Instant::now();
        while service.retry_after() != Duration::from_millis(1) {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "retry_after never drained: {:?}",
                service.retry_after()
            );
            std::thread::sleep(Duration::from_millis(1));
        }

        stop.store(true, Ordering::SeqCst);
        let _ = server_thread.join().unwrap();
        service.shutdown();
    }
}
