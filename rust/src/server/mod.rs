//! TCP serving front-end: newline-delimited JSON protocol over a threaded
//! accept loop (no async runtime in the vendored crate set; execution
//! streams scale via the engine fleet, not per-connection threads, so
//! thread-per-connection with a shared [`crate::coordinator::Service`] is
//! the right shape). BUSY backpressure is typed end to end: the wire
//! response carries `retry_after_ms`, and [`client::RetryPolicy`] turns
//! it into capped, jittered exponential backoff.

pub mod client;
pub mod protocol;
pub mod tcp;

pub use client::{Busy, Client, RetryDeadline, RetryPolicy};
pub use protocol::{parse_request, render_error, render_response, WireRequest};
pub use tcp::TcpServer;
