//! TCP serving front-end: a threaded accept loop (no async runtime in the
//! vendored crate set; execution streams scale via the engine fleet, not
//! per-connection threads, so thread-per-connection with a shared
//! [`crate::coordinator::Service`] is the right shape) speaking a
//! negotiated wire codec ([`codec`]): newline-delimited JSON by default
//! (legacy, byte-pinned) or length-prefixed binary frames after a client
//! hello. BUSY backpressure is typed end to end: the wire response
//! carries `retry_after_ms`, and [`client::RetryPolicy`] turns it into
//! capped, jittered exponential backoff.

pub mod client;
pub mod codec;
pub mod protocol;
pub mod tcp;

pub use client::{Busy, Client, RetryDeadline, RetryPolicy};
pub use codec::{Binary, Codec, Decoded, JsonLines};
pub use protocol::{parse_request, render_error, render_response, WireRequest, WireResponse};
pub use tcp::TcpServer;
