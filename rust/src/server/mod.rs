//! TCP serving front-end: newline-delimited JSON protocol over a threaded
//! accept loop (no async runtime in the vendored crate set — and the
//! engine serializes on one PJRT stream anyway, so thread-per-connection
//! with a shared [`crate::coordinator::Service`] is the right shape).

pub mod client;
pub mod protocol;
pub mod tcp;

pub use client::Client;
pub use protocol::{parse_request, render_error, render_response, WireRequest};
pub use tcp::TcpServer;
