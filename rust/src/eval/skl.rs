//! Symmetric KL divergence between sample sets on the two-moons grid
//! (paper Table 1's metric).
//!
//! Both sample sets are histogrammed onto a coarsened grid (with add-one
//! smoothing so the divergence stays finite), then
//! `SKL = KL(P||Q) + KL(Q||P)` is computed over the bins.

use crate::data::two_moons::GRID;

/// 2D histogram over the token grid, coarsened by `bin` cells per axis.
#[derive(Debug, Clone)]
pub struct GridHistogram {
    pub bins_per_axis: usize,
    pub counts: Vec<f64>,
    pub total: f64,
}

impl GridHistogram {
    pub fn new(bin: usize) -> Self {
        assert!(bin > 0 && GRID % bin == 0, "bin must divide {GRID}");
        let bins = GRID / bin;
        GridHistogram { bins_per_axis: bins, counts: vec![0.0; bins * bins], total: 0.0 }
    }

    pub fn add(&mut self, p: [i32; 2]) {
        let bin = GRID / self.bins_per_axis;
        let x = (p[0].clamp(0, GRID as i32 - 1) as usize) / bin;
        let y = (p[1].clamp(0, GRID as i32 - 1) as usize) / bin;
        self.counts[y * self.bins_per_axis + x] += 1.0;
        self.total += 1.0;
    }

    pub fn add_all(&mut self, pts: &[[i32; 2]]) {
        for &p in pts {
            self.add(p);
        }
    }

    /// Smoothed probability of bin `i`.
    fn prob(&self, i: usize, alpha: f64) -> f64 {
        (self.counts[i] + alpha) / (self.total + alpha * self.counts.len() as f64)
    }
}

/// Symmetric KL between two histograms (natural log).
pub fn symmetric_kl(p: &GridHistogram, q: &GridHistogram, alpha: f64) -> f64 {
    assert_eq!(p.counts.len(), q.counts.len(), "histogram shapes differ");
    let mut kl_pq = 0.0;
    let mut kl_qp = 0.0;
    for i in 0..p.counts.len() {
        let pi = p.prob(i, alpha);
        let qi = q.prob(i, alpha);
        kl_pq += pi * (pi / qi).ln();
        kl_qp += qi * (qi / pi).ln();
    }
    kl_pq + kl_qp
}

/// Convenience: SKL between two point sets with the default binning used in
/// the Table 1 harness (32x32 bins, alpha = 0.5).
pub fn skl_points(a: &[[i32; 2]], b: &[[i32; 2]]) -> f64 {
    let mut ha = GridHistogram::new(4);
    let mut hb = GridHistogram::new(4);
    ha.add_all(a);
    hb.add_all(b);
    symmetric_kl(&ha, &hb, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg64;
    use crate::data::two_moons;

    #[test]
    fn identical_sets_have_near_zero_skl() {
        let mut rng = Pcg64::new(0);
        let pts = two_moons::sample_batch(4000, &mut rng);
        let d = skl_points(&pts, &pts);
        assert!(d.abs() < 1e-9, "{d}");
    }

    #[test]
    fn same_distribution_small_skl() {
        let mut rng = Pcg64::new(1);
        let a = two_moons::sample_batch(5000, &mut rng);
        let b = two_moons::sample_batch(5000, &mut rng);
        let d = skl_points(&a, &b);
        assert!(d < 0.3, "same-dist SKL should be small, got {d}");
    }

    #[test]
    fn different_distributions_large_skl() {
        let mut rng = Pcg64::new(2);
        let a = two_moons::sample_batch(4000, &mut rng);
        // Uniform noise.
        let b: Vec<[i32; 2]> =
            (0..4000).map(|_| [rng.below(128) as i32, rng.below(128) as i32]).collect();
        let d = skl_points(&a, &b);
        assert!(d > 1.0, "uniform-vs-moons SKL should be large, got {d}");
    }

    #[test]
    fn skl_is_symmetric() {
        let mut rng = Pcg64::new(3);
        let a = two_moons::sample_batch(2000, &mut rng);
        let b = two_moons::draft_batch(two_moons::DraftKind::Poor, 2000, &mut rng);
        let d1 = skl_points(&a, &b);
        let d2 = skl_points(&b, &a);
        assert!((d1 - d2).abs() < 1e-9);
    }

    #[test]
    fn draft_quality_ordering_in_skl() {
        // Mirrors paper Fig. 4: SKL(target, good) < SKL(target, fair) <
        // SKL(target, poor).
        let mut rng = Pcg64::new(4);
        let target = two_moons::sample_batch(6000, &mut rng);
        let good = two_moons::draft_batch(two_moons::DraftKind::Good, 6000, &mut rng);
        let fair = two_moons::draft_batch(two_moons::DraftKind::Fair, 6000, &mut rng);
        let poor = two_moons::draft_batch(two_moons::DraftKind::Poor, 6000, &mut rng);
        let dg = skl_points(&target, &good);
        let df = skl_points(&target, &fair);
        let dp = skl_points(&target, &poor);
        assert!(dg < df && df < dp, "SKL ordering violated: {dg} {df} {dp}");
    }

    #[test]
    #[should_panic]
    fn bad_bin_panics() {
        GridHistogram::new(7); // 7 does not divide 128
    }
}
