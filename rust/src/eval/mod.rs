//! Evaluation metrics reproducing the paper's protocol (DESIGN.md §2):
//! symmetric KL for two-moons (Table 1), n-gram-LM NLL / perplexity /
//! entropy for text (Tables 2-3, substituting for GPT-J-6B), and Fréchet
//! distance over fixed features for images (Table 4, substituting for FID).

pub mod fid;
pub mod ngram;
pub mod skl;
pub mod stats;
