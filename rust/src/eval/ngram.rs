//! Kneser-Ney-smoothed n-gram language model — the evaluator LM.
//!
//! Substitutes for the paper's GPT-J-6B proxy-true-model (DESIGN.md §2):
//! trained on the *held-out* corpus (never seen by any generator), it scores
//! generated samples with per-token NLL, perplexity, and predictive entropy
//! (the paper's Tables 2-3 metrics).
//!
//! Interpolated absolute-discounting KN over orders 1..=N with hash-map
//! context tables; vocabulary-smoothed at the unigram floor so every token
//! has nonzero mass.

use std::collections::HashMap;

/// KN-smoothed n-gram LM.
#[derive(Debug)]
pub struct NgramLM {
    pub order: usize,
    pub vocab: usize,
    discount: f64,
    /// counts[k] maps a length-k context to (token -> count, total).
    counts: Vec<HashMap<Vec<i32>, ContextRow>>,
}

#[derive(Debug, Default, Clone)]
struct ContextRow {
    tokens: HashMap<i32, f64>,
    total: f64,
}

impl NgramLM {
    pub fn fit(stream: &[i32], order: usize, vocab: usize) -> Self {
        assert!(order >= 1, "order must be >= 1");
        assert!(vocab > 0);
        let mut counts: Vec<HashMap<Vec<i32>, ContextRow>> = vec![HashMap::new(); order];
        for i in 0..stream.len() {
            let tok = stream[i];
            for k in 0..order {
                if i < k {
                    continue;
                }
                let ctx: Vec<i32> = stream[i - k..i].to_vec();
                let row = counts[k].entry(ctx).or_default();
                *row.tokens.entry(tok).or_insert(0.0) += 1.0;
                row.total += 1.0;
            }
        }
        NgramLM { order, vocab, discount: 0.75, counts }
    }

    /// P(tok | ctx) via interpolated absolute discounting, recursing down
    /// to a uniform-smoothed unigram.
    pub fn prob(&self, ctx: &[i32], tok: i32) -> f64 {
        let k = ctx.len().min(self.order - 1);
        let ctx = &ctx[ctx.len() - k..];
        self.prob_rec(ctx, tok)
    }

    fn prob_rec(&self, ctx: &[i32], tok: i32) -> f64 {
        if ctx.is_empty() {
            // Unigram with add-one smoothing over the full vocabulary.
            let row = self.counts[0].get(&Vec::new());
            let (c, total) = match row {
                Some(r) => (r.tokens.get(&tok).copied().unwrap_or(0.0), r.total),
                None => (0.0, 0.0),
            };
            return (c + 1.0) / (total + self.vocab as f64);
        }
        let k = ctx.len();
        match self.counts[k].get(ctx) {
            Some(row) if row.total > 0.0 => {
                let c = row.tokens.get(&tok).copied().unwrap_or(0.0);
                let d = self.discount;
                let distinct = row.tokens.len() as f64;
                let p_cont = self.prob_rec(&ctx[1..], tok);
                ((c - d).max(0.0) + d * distinct * p_cont) / row.total
            }
            _ => self.prob_rec(&ctx[1..], tok),
        }
    }

    /// Per-token negative log-likelihood (nats) of a sequence.
    pub fn nll(&self, seq: &[i32]) -> f64 {
        if seq.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for i in 0..seq.len() {
            let lo = i.saturating_sub(self.order - 1);
            let p = self.prob(&seq[lo..i], seq[i]);
            total += -p.max(1e-12).ln();
        }
        total / seq.len() as f64
    }

    /// Perplexity = exp(mean NLL).
    pub fn perplexity(&self, seq: &[i32]) -> f64 {
        self.nll(seq).exp()
    }

    /// Mean predictive entropy (nats) along a sequence: H(P(.|ctx_i)).
    ///
    /// This is the paper's "entropy of the model's next-token prediction
    /// probability" diversity proxy, computed under the evaluator.
    pub fn predictive_entropy(&self, seq: &[i32]) -> f64 {
        if seq.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for i in 0..seq.len() {
            let lo = i.saturating_sub(self.order - 1);
            let ctx = &seq[lo..i];
            let mut h = 0.0;
            for tok in 0..self.vocab as i32 {
                let p = self.prob(ctx, tok);
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            total += h;
        }
        total / seq.len() as f64
    }

    /// Corpus-level metrics over many sequences: (mean NLL, perplexity,
    /// mean predictive entropy in bits).
    pub fn evaluate(&self, seqs: &[Vec<i32>]) -> TextMetrics {
        let mut nll_sum = 0.0;
        let mut ent_sum = 0.0;
        for s in seqs {
            nll_sum += self.nll(s);
            ent_sum += self.predictive_entropy(s);
        }
        let n = seqs.len().max(1) as f64;
        let nll = nll_sum / n;
        TextMetrics {
            nll,
            perplexity: nll.exp(),
            entropy_bits: (ent_sum / n) / std::f64::consts::LN_2,
        }
    }
}

/// Text evaluation result (Tables 2-3 columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TextMetrics {
    pub nll: f64,
    pub perplexity: f64,
    pub entropy_bits: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_stream() -> Vec<i32> {
        // "abab...ab" with occasional "c": strong bigram structure.
        let mut s = Vec::new();
        for i in 0..500 {
            s.push(0);
            s.push(1);
            if i % 10 == 0 {
                s.push(2);
            }
        }
        s
    }

    #[test]
    fn probabilities_sum_to_one() {
        let lm = NgramLM::fit(&toy_stream(), 3, 5);
        for ctx in [vec![], vec![0], vec![0, 1], vec![4, 4]] {
            let total: f64 = (0..5).map(|t| lm.prob(&ctx, t)).sum();
            assert!((total - 1.0).abs() < 1e-9, "ctx {ctx:?} sums to {total}");
        }
    }

    #[test]
    fn learns_bigram_structure() {
        let lm = NgramLM::fit(&toy_stream(), 3, 5);
        // After 'a'(0), 'b'(1) is overwhelmingly likely.
        assert!(lm.prob(&[0], 1) > 0.9);
        assert!(lm.prob(&[0], 0) < 0.05);
    }

    #[test]
    fn in_distribution_nll_lower_than_noise() {
        let stream = toy_stream();
        let lm = NgramLM::fit(&stream, 3, 5);
        let good: Vec<i32> = stream[..100].to_vec();
        let noise: Vec<i32> = (0..100).map(|i| (i * 7 % 5) as i32).collect();
        assert!(lm.nll(&good) < lm.nll(&noise));
    }

    #[test]
    fn perplexity_is_exp_nll() {
        let lm = NgramLM::fit(&toy_stream(), 2, 5);
        let seq = vec![0, 1, 0, 1];
        assert!((lm.perplexity(&seq) - lm.nll(&seq).exp()).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_upper_bound() {
        let lm = NgramLM::fit(&toy_stream(), 2, 5);
        let seq = vec![0, 1, 0, 1, 2];
        let h = lm.predictive_entropy(&seq);
        assert!(h >= 0.0 && h <= (5.0f64).ln() + 1e-9, "h = {h}");
    }

    #[test]
    fn unseen_tokens_have_nonzero_prob() {
        let lm = NgramLM::fit(&toy_stream(), 3, 10);
        // Token 9 never appears.
        assert!(lm.prob(&[0, 1], 9) > 0.0);
        assert!(lm.prob(&[], 9) > 0.0);
    }

    #[test]
    fn evaluate_aggregates() {
        let lm = NgramLM::fit(&toy_stream(), 2, 5);
        let m = lm.evaluate(&[vec![0, 1, 0, 1], vec![2, 0, 1, 0]]);
        assert!(m.nll > 0.0);
        assert!(m.perplexity > 1.0);
        assert!(m.entropy_bits > 0.0);
    }

    #[test]
    fn empty_sequence_is_zero() {
        let lm = NgramLM::fit(&toy_stream(), 2, 5);
        assert_eq!(lm.nll(&[]), 0.0);
        assert_eq!(lm.predictive_entropy(&[]), 0.0);
    }
}
