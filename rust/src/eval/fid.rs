//! Fréchet distance over fixed random-conv features — the FID substitute
//! (paper Table 4; DESIGN.md §2).
//!
//! The paper computes FID with Inception features. No pretrained Inception
//! exists in this offline image, so we use the standard substitute for
//! small synthetic imagery: a *fixed* (seeded) random convolutional feature
//! extractor shared by every system under comparison, followed by the exact
//! Fréchet formula
//!
//! ```text
//! d^2 = |mu_a - mu_b|^2 + tr(Ca + Cb - 2 (Ca Cb)^{1/2})
//! ```
//!
//! with the matrix square root from [`super::stats`]. Relative orderings —
//! which Table 4 is about — are preserved under any fixed feature map that
//! separates the distributions.

use crate::core::rng::Pcg64;
use crate::eval::stats::{mean_cov, sqrtm_psd, Mat};

/// Fixed random 3x3-conv + pooling feature extractor.
#[derive(Debug, Clone)]
pub struct FeatureExtractor {
    pub side: usize,
    pub channels: usize,
    pub n_filters: usize,
    /// `[n_filters][channels * 9]` kernels.
    kernels: Vec<Vec<f64>>,
    bias: Vec<f64>,
}

impl FeatureExtractor {
    /// Deterministic extractor (same seed => same features everywhere).
    pub fn new(side: usize, channels: usize, n_filters: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let kernels = (0..n_filters)
            .map(|_| (0..channels * 9).map(|_| rng.normal() / 3.0).collect())
            .collect();
        let bias = (0..n_filters).map(|_| rng.normal() * 0.1).collect();
        FeatureExtractor { side, channels, n_filters, kernels, bias }
    }

    /// Feature vector: per-filter ReLU conv with 2x2-quadrant mean AND
    /// second-moment pooling (`n_filters * 8` dims), plus per-channel mean,
    /// variance, and gradient energy. The second moments are what separate
    /// blurry PCA drafts from sharp data — mean-only pooling cannot
    /// (EXPERIMENTS.md §Perf iteration log).
    pub fn features(&self, tokens: &[i32]) -> Vec<f64> {
        let s = self.side;
        let c = self.channels;
        assert_eq!(tokens.len(), s * s * c, "token count mismatch");
        // Dequantize to [0, 1] (V = 32).
        let img: Vec<f64> = tokens.iter().map(|&t| t as f64 / 31.0).collect();
        let mut feats = Vec::with_capacity(self.n_filters * 8 + 3 * c);
        let half = s / 2;
        for (f, kern) in self.kernels.iter().enumerate() {
            // Pooled quadrant accumulators (mean + mean-square).
            let mut quad = [0.0f64; 4];
            let mut quad2 = [0.0f64; 4];
            let mut qn = [0.0f64; 4];
            for y in 0..s {
                for x in 0..s {
                    // 3x3 conv with zero padding.
                    let mut acc = self.bias[f];
                    for dy in 0..3usize {
                        for dx in 0..3usize {
                            let yy = y as isize + dy as isize - 1;
                            let xx = x as isize + dx as isize - 1;
                            if yy < 0 || xx < 0 || yy >= s as isize || xx >= s as isize {
                                continue;
                            }
                            for ch in 0..c {
                                let pix = img[((yy as usize) * s + xx as usize) * c + ch];
                                acc += pix * kern[(dy * 3 + dx) * c + ch];
                            }
                        }
                    }
                    let v = acc.max(0.0); // ReLU
                    let q = (y >= half) as usize * 2 + (x >= half) as usize;
                    quad[q] += v;
                    quad2[q] += v * v;
                    qn[q] += 1.0;
                }
            }
            for q in 0..4 {
                let n = qn[q].max(1.0);
                feats.push(quad[q] / n);
                feats.push(quad2[q] / n);
            }
        }
        // Per-channel mean, variance and horizontal gradient energy ground
        // the features in raw intensity + sharpness.
        for ch in 0..c {
            let vals: Vec<f64> = (0..s * s).map(|i| img[i * c + ch]).collect();
            let mean: f64 = vals.iter().sum::<f64>() / vals.len() as f64;
            let var: f64 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            let mut grad = 0.0;
            for y in 0..s {
                for x in 1..s {
                    let d = vals[y * s + x] - vals[y * s + x - 1];
                    grad += d * d;
                }
            }
            feats.push(mean);
            feats.push(var);
            feats.push(grad / ((s * (s - 1)) as f64));
        }
        feats
    }
}

/// Fréchet distance between two feature clouds.
pub fn frechet(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    let (mu_a, ca) = mean_cov(a);
    let (mu_b, cb) = mean_cov(b);
    frechet_from_moments(&mu_a, &ca, &mu_b, &cb)
}

/// Fréchet distance from precomputed moments.
pub fn frechet_from_moments(mu_a: &[f64], ca: &Mat, mu_b: &[f64], cb: &Mat) -> f64 {
    let mean_term: f64 = mu_a.iter().zip(mu_b).map(|(x, y)| (x - y) * (x - y)).sum();
    // tr(Ca + Cb - 2 sqrt(Ca Cb)); symmetrize the product for stability.
    let prod = ca.matmul(cb);
    let sym = {
        let t = prod.transpose();
        let mut s = prod.add(&t);
        for v in &mut s.a {
            *v *= 0.5;
        }
        s
    };
    let sqrt = sqrtm_psd(&sym);
    let d2 = mean_term + ca.trace() + cb.trace() - 2.0 * sqrt.trace();
    d2.max(0.0)
}

/// Convenience: FID-style score between two token-image sets.
pub fn fid_images(
    extractor: &FeatureExtractor,
    set_a: &[Vec<i32>],
    set_b: &[Vec<i32>],
) -> f64 {
    let fa: Vec<Vec<f64>> = set_a.iter().map(|img| extractor.features(img)).collect();
    let fb: Vec<Vec<f64>> = set_b.iter().map(|img| extractor.features(img)).collect();
    frechet(&fa, &fb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shapes;

    fn gray_extractor() -> FeatureExtractor {
        FeatureExtractor::new(shapes::GRAY_SIDE, 1, 8, 1234)
    }

    #[test]
    fn identical_sets_have_near_zero_fid() {
        let mut rng = Pcg64::new(0);
        let (imgs, _) = shapes::batch_gray(80, &mut rng);
        let d = fid_images(&gray_extractor(), &imgs, &imgs);
        assert!(d < 1e-6, "{d}");
    }

    #[test]
    fn same_distribution_fid_small_vs_noise() {
        let mut rng = Pcg64::new(1);
        let (a, _) = shapes::batch_gray(150, &mut rng);
        let (b, _) = shapes::batch_gray(150, &mut rng);
        // Uniform-noise images.
        let noise: Vec<Vec<i32>> = (0..150)
            .map(|_| (0..shapes::GRAY_SIDE * shapes::GRAY_SIDE).map(|_| rng.below(32) as i32).collect())
            .collect();
        let ex = gray_extractor();
        let d_same = fid_images(&ex, &a, &b);
        let d_noise = fid_images(&ex, &a, &noise);
        assert!(d_same < d_noise, "same-dist {d_same} should be < noise {d_noise}");
        assert!(d_noise > 5.0 * d_same.max(1e-6), "separation too weak: {d_same} vs {d_noise}");
    }

    #[test]
    fn fid_is_symmetric() {
        let mut rng = Pcg64::new(2);
        let (a, _) = shapes::batch_gray(60, &mut rng);
        let (b, _) = shapes::batch_gray(60, &mut rng);
        let ex = gray_extractor();
        let d1 = fid_images(&ex, &a, &b);
        let d2 = fid_images(&ex, &b, &a);
        assert!((d1 - d2).abs() < 1e-6);
    }

    #[test]
    fn extractor_is_deterministic() {
        let mut rng = Pcg64::new(3);
        let img = shapes::render_gray(0, shapes::GRAY_SIDE, &mut rng);
        let f1 = FeatureExtractor::new(16, 1, 8, 7).features(&img);
        let f2 = FeatureExtractor::new(16, 1, 8, 7).features(&img);
        assert_eq!(f1, f2);
        let f3 = FeatureExtractor::new(16, 1, 8, 8).features(&img);
        assert_ne!(f1, f3);
    }

    #[test]
    fn color_features_shape() {
        let mut rng = Pcg64::new(4);
        let img = shapes::render_color(2, shapes::COLOR_SIDE, &mut rng);
        let ex = FeatureExtractor::new(shapes::COLOR_SIDE, 3, 6, 11);
        let f = ex.features(&img);
        assert_eq!(f.len(), 6 * 8 + 3 * 3);
    }

    #[test]
    fn frechet_known_gaussians() {
        // Two 1-sigma clouds separated by delta in mean: d^2 ≈ |delta|^2.
        let mut rng = Pcg64::new(5);
        let a: Vec<Vec<f64>> = (0..4000).map(|_| vec![rng.normal(), rng.normal()]).collect();
        let b: Vec<Vec<f64>> =
            (0..4000).map(|_| vec![rng.normal() + 3.0, rng.normal()]).collect();
        let d = frechet(&a, &b);
        assert!((d - 9.0).abs() < 0.7, "{d}");
    }
}
