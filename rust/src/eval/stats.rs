//! Dense linear algebra for the FID metric: mean/covariance estimation,
//! symmetric eigendecomposition (cyclic Jacobi), and the matrix square
//! root needed by the Fréchet distance.

/// Column-major-free small dense symmetric matrix ops (row-major `Vec<f64>`).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Self {
        Mat { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * other.get(k, j);
                }
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        Mat { n: self.n, a: self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect() }
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.get(i, i)).sum()
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Max |a_ij - a_ji| (symmetry check).
    pub fn asymmetry(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..i {
                m = m.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        m
    }
}

/// Sample mean and covariance of rows in `data` (`[m][d]`).
pub fn mean_cov(data: &[Vec<f64>]) -> (Vec<f64>, Mat) {
    let m = data.len();
    assert!(m > 1, "need >= 2 samples");
    let d = data[0].len();
    let mut mean = vec![0.0; d];
    for row in data {
        for (mi, &x) in mean.iter_mut().zip(row.iter()) {
            *mi += x;
        }
    }
    for mi in &mut mean {
        *mi /= m as f64;
    }
    let mut cov = Mat::zeros(d);
    for row in data {
        for i in 0..d {
            let ci = row[i] - mean[i];
            for j in i..d {
                let cj = row[j] - mean[j];
                cov.a[i * d + j] += ci * cj;
            }
        }
    }
    let denom = (m - 1) as f64;
    for i in 0..d {
        for j in i..d {
            let v = cov.get(i, j) / denom;
            cov.set(i, j, v);
            cov.set(j, i, v);
        }
    }
    (mean, cov)
}

/// Symmetric eigendecomposition by cyclic Jacobi rotations.
///
/// Returns (eigenvalues, eigenvectors as rows of V st A = V^T diag(w) V).
pub fn sym_eig(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    let n = a.n;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off < 1e-20 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of m.
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vpk = v.get(p, k);
                    let vqk = v.get(q, k);
                    v.set(p, k, c * vpk - s * vqk);
                    v.set(q, k, s * vpk + c * vqk);
                }
            }
        }
    }
    let w: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    (w, v)
}

/// Symmetric positive-semidefinite square root via eigendecomposition.
pub fn sqrtm_psd(a: &Mat) -> Mat {
    let (w, v) = sym_eig(a, 50);
    let n = a.n;
    // sqrt(A) = V^T diag(sqrt(max(w,0))) V.
    let mut out = Mat::zeros(n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for (k, &wk) in w.iter().enumerate() {
                s += v.get(k, i) * wk.max(0.0).sqrt() * v.get(k, j);
            }
            out.set(i, j, s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_cov_known() {
        let data = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let (mean, cov) = mean_cov(&data);
        assert_eq!(mean, vec![3.0, 4.0]);
        assert!((cov.get(0, 0) - 4.0).abs() < 1e-12);
        assert!((cov.get(0, 1) - 4.0).abs() < 1e-12);
        assert!((cov.get(1, 1) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn eig_diagonal() {
        let mut a = Mat::zeros(3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 1.0);
        a.set(2, 2, 2.0);
        let (mut w, _) = sym_eig(&a, 30);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-9);
        assert!((w[1] - 2.0).abs() < 1e-9);
        assert!((w[2] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let mut a = Mat::zeros(2);
        a.set(0, 0, 2.0);
        a.set(0, 1, 1.0);
        a.set(1, 0, 1.0);
        a.set(1, 1, 2.0);
        let (mut w, _) = sym_eig(&a, 30);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-9, "{w:?}");
        assert!((w[1] - 3.0).abs() < 1e-9, "{w:?}");
    }

    #[test]
    fn sqrtm_squares_back() {
        // Random-ish SPD matrix: A = B B^T + I.
        let n = 5;
        let mut b = Mat::zeros(n);
        let mut seed = 1u64;
        for i in 0..n * n {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            b.a[i] = ((seed >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
        }
        let a = b.matmul(&b.transpose()).add(&Mat::eye(n));
        let s = sqrtm_psd(&a);
        let s2 = s.matmul(&s);
        for i in 0..n * n {
            assert!((s2.a[i] - a.a[i]).abs() < 1e-6, "i={i}: {} vs {}", s2.a[i], a.a[i]);
        }
        assert!(s.asymmetry() < 1e-8);
    }

    #[test]
    fn matmul_identity() {
        let i3 = Mat::eye(3);
        let mut a = Mat::zeros(3);
        for (k, v) in a.a.iter_mut().enumerate() {
            *v = k as f64;
        }
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn trace_and_transpose() {
        let mut a = Mat::zeros(2);
        a.set(0, 0, 1.0);
        a.set(0, 1, 5.0);
        a.set(1, 1, 2.0);
        assert_eq!(a.trace(), 3.0);
        assert_eq!(a.transpose().get(1, 0), 5.0);
    }
}
