//! Continuous cross-bundle batching: the step-level batch composer.
//!
//! The per-bundle REFINE path ([`crate::coordinator::scheduler`]) drives
//! each drafted chunk through its whole Euler trajectory as one engine
//! loop — simple, but under concurrent load the engine sees a sequence
//! of small batches, one bundle at a time. The composer instead treats
//! the **step** as the scheduling quantum, vLLM-style: every in-flight
//! chunk contributes its useful rows to a shared engine dispatch each
//! step, rows retire as their segment ladders complete, and freshly
//! drafted bundles join at the next step boundary instead of queueing
//! behind a whole foreign trajectory.
//!
//! ## Row bookkeeping
//!
//! Each admitted [`DraftedBundle`] breaks into per-chunk lockstep groups
//! ([`ChunkState`]): a chunk's useful rows (padding never enters the
//! composer) advance together, carrying their own schedule cursor —
//! current cascade segment, step-in-segment, absolute step offset — plus
//! their identity (job slot, chunk index). Chunks from different bundles
//! at different trajectory points coexist in one composed step.
//!
//! ## Why outputs are bitwise-identical to the per-bundle path
//!
//! Nothing in the numerical chain depends on *who else* shares a step:
//!
//! 1. the run seed is drawn exactly as the per-bundle path draws it
//!    (first `next_u64` of `Pcg64::substream(bundle_seed, chunk_index,
//!    REFINE_LANE)`);
//! 2. each composed step evaluates the chunk at its own `(t, h, warp)`
//!    from the same sliced [`Schedule`] the segment executor uses;
//! 3. every categorical draw keys on `(run_seed, absolute step,
//!    position)` via [`crate::core::prob`]'s seeded row sampler — the
//!    same substreams the engine-resident loop uses, with positions
//!    indexed within the chunk exactly as the unbatched padded batch
//!    indexes its useful prefix;
//! 4. gates are evaluated with the shared [`eval_gate`] on the same
//!    intermediate state, so composed and per-bundle cascades exit at
//!    the same stage.
//!
//! Composition therefore only changes *grouping*, never values — pinned
//! by the parity tests below and the service-level sweep
//! (`composer on/off × fleet replicas × refine workers × pipeline depth
//! × cascade modes`).
//!
//! ## Failure containment
//!
//! A composed dispatch that errors fails over: every in-flight bundle is
//! re-run from its untouched draft through the per-bundle
//! [`Scheduler::refine_bundle`] (deterministic, so a fault-free retry
//! yields the exact tokens the composed run would have produced). The
//! caller sees the same `(ctx, Result)` contract either way.

use crate::cascade::executor::eval_gate;
use crate::cascade::{Segment, StageOutcome};
use crate::coordinator::request::{CascadeInfo, GenResponse, TimingInfo};
use crate::coordinator::scheduler::{DraftedBundle, Scheduler, REFINE_LANE};
use crate::obs::SpanKind;
use crate::core::prob::sample_row_seeded;
use crate::core::rng::Pcg64;
use crate::core::schedule::Schedule;
use crate::runtime::engine::RowStep;
use anyhow::Result;
use std::time::{Duration, Instant};

/// One admitted bundle riding the composer: its context (whatever the
/// service needs to deliver the result), the untouched drafted bundle
/// (kept whole for finalization and the failure-containment re-run), and
/// the per-chunk completion slots.
struct Job<C> {
    ctx: C,
    drafted: DraftedBundle,
    /// Finished chunks, indexed by position in `drafted.chunks`.
    done: Vec<Option<DoneChunk>>,
    remaining: usize,
    /// Wall-clock of composed steps this job participated in.
    refine_time: Duration,
}

/// A chunk that finished its ladder: final tokens (useful rows only) and
/// the executed stage accounting, mirroring a `CascadeOutcome` prefix.
struct DoneChunk {
    tokens: Vec<i32>,
    stages: Vec<StageOutcome>,
    early_exit: bool,
}

/// One chunk's lockstep row group advancing through its segment ladder.
struct ChunkState {
    /// Owning job slot in `ComposedRefiner::jobs`.
    job: usize,
    /// Position in the job's `drafted.chunks` (completion slot).
    slot: usize,
    /// Useful rows (== `chunk_len`; padding never enters the composer).
    rows: usize,
    seq_len: usize,
    vocab: usize,
    domain: String,
    tag: String,
    /// The chunk's own step artifact — names the compiled family for
    /// dispatch (and fleet affinity); the engine re-pads per dispatch.
    artifact: String,
    /// `[rows * seq_len]` current token state, resampled every step.
    tokens: Vec<i32>,
    run_seed: u64,
    warp: f32,
    steps_cold: usize,
    t0: f64,
    plan: Vec<Segment>,
    seg_idx: usize,
    /// Sliced schedule of the current segment (absolute `step_offset`).
    schedule: Schedule,
    step_in_seg: usize,
    stages: Vec<StageOutcome>,
    early_exit: bool,
    retired: bool,
}

impl ChunkState {
    /// The per-row step parameters for the chunk's next step — exactly
    /// the `(t, h, warp)` the engine-resident loop would dispatch.
    fn row_step(&self) -> RowStep {
        RowStep {
            t: self.schedule.times[self.step_in_seg] as f32,
            h: self.schedule.step_size(self.step_in_seg) as f32,
            warp: self.warp,
        }
    }

    fn family(&self) -> (&str, &str, usize, usize) {
        (self.domain.as_str(), self.tag.as_str(), self.seq_len, self.vocab)
    }
}

/// The step-level batch composer: merges rows from multiple in-flight
/// [`DraftedBundle`]s (and their cascade segments) into shared engine
/// steps, retiring rows as segments complete and admitting new bundles
/// at step boundaries.
///
/// Generic over a caller context `C` (response channels, fallback plans)
/// returned verbatim with each finished bundle's result. Borrows the
/// stage thread's [`Scheduler`] so composed and per-bundle refinement
/// share one executor, controller, cascade policy, and metrics sink.
pub struct ComposedRefiner<'s, 'a, C> {
    sched: &'s Scheduler<'a>,
    /// Row cap per composed dispatch (`composer.max_rows`); 0 = no cap
    /// (the engine tiles oversized dispatches over its compiled batches).
    max_rows: usize,
    jobs: Vec<Option<Job<C>>>,
    free: Vec<usize>,
    chunks: Vec<ChunkState>,
    completed: Vec<(C, Result<Vec<GenResponse>>)>,
}

impl<'s, 'a, C> ComposedRefiner<'s, 'a, C> {
    pub fn new(sched: &'s Scheduler<'a>, max_rows: usize) -> Self {
        ComposedRefiner {
            sched,
            max_rows,
            jobs: Vec::new(),
            free: Vec::new(),
            chunks: Vec::new(),
            completed: Vec::new(),
        }
    }

    /// Whether any rows are still in flight (i.e. [`ComposedRefiner::step`]
    /// has work to do).
    pub fn has_work(&self) -> bool {
        !self.chunks.is_empty()
    }

    /// Finished bundles: `(ctx, responses)` in completion order. Errors
    /// here already survived the per-bundle fallback re-run.
    pub fn take_completed(&mut self) -> Vec<(C, Result<Vec<GenResponse>>)> {
        std::mem::take(&mut self.completed)
    }

    /// Admit a drafted bundle: its chunks join the composed step loop at
    /// the next step boundary. Admission never fails outward — a chunk
    /// that cannot be set up (shape mismatch, unschedulable segment)
    /// sends the whole bundle down the per-bundle path instead.
    pub fn admit(&mut self, ctx: C, drafted: DraftedBundle) {
        let slot = self.free.pop().unwrap_or_else(|| {
            self.jobs.push(None);
            self.jobs.len() - 1
        });
        let n_chunks = drafted.chunks.len();
        debug_assert!(n_chunks > 0, "draft_bundle never yields zero chunks");
        match self.build_chunks(slot, &drafted) {
            Ok(states) => {
                self.chunks.extend(states);
                self.jobs[slot] = Some(Job {
                    ctx,
                    drafted,
                    done: (0..n_chunks).map(|_| None).collect(),
                    remaining: n_chunks,
                    refine_time: Duration::ZERO,
                });
            }
            Err(e) => {
                crate::error!("composed admission failed ({e:#}); per-bundle fallback");
                self.free.push(slot);
                self.completed.push((ctx, self.sched.refine_bundle(drafted)));
            }
        }
    }

    /// Build the per-chunk lockstep states for a job. RNG, plan, and
    /// schedule derivation mirror `Scheduler::refine_bundle` exactly.
    fn build_chunks(&self, slot: usize, drafted: &DraftedBundle) -> Result<Vec<ChunkState>> {
        let key = &drafted.bundle.key;
        let t0 = drafted.decision.t0;
        let warp = key.warp_mode().warp_factor(t0) as f32;
        let mut states = Vec::with_capacity(drafted.chunks.len());
        for (ci, chunk) in drafted.chunks.iter().enumerate() {
            crate::sampler::dfm::check_shape(
                chunk.meta.batch,
                chunk.meta.seq_len,
                &chunk.meta.name,
                &chunk.init,
            )?;
            // The run-seed draw matches both per-bundle paths (`sample_warm`
            // and the cascade executor draw one u64 from this substream).
            let mut rng = Pcg64::substream(drafted.bundle_seed, chunk.chunk_index as u64, REFINE_LANE);
            let run_seed = rng.next_u64();
            let plan = self.sched.cascade().plan(key.steps_cold, t0, &chunk.meta.name);
            let seg = &plan[0];
            let schedule = Schedule::segment(key.steps_cold, t0, seg.t_start, seg.t_end)?;
            let mut tokens = Vec::with_capacity(chunk.chunk_len * chunk.meta.seq_len);
            for r in 0..chunk.chunk_len {
                tokens.extend_from_slice(chunk.init.row(r));
            }
            states.push(ChunkState {
                job: slot,
                slot: ci,
                rows: chunk.chunk_len,
                seq_len: chunk.meta.seq_len,
                vocab: chunk.meta.vocab,
                domain: chunk.meta.domain.clone(),
                tag: chunk.meta.tag.clone(),
                artifact: chunk.meta.name.clone(),
                tokens,
                run_seed,
                warp,
                steps_cold: key.steps_cold,
                t0,
                plan,
                seg_idx: 0,
                schedule,
                step_in_seg: 0,
                stages: Vec::new(),
                early_exit: false,
                retired: false,
            });
        }
        Ok(states)
    }

    /// Drive every in-flight chunk one Euler step through shared engine
    /// dispatches. Returns `false` when nothing was in flight.
    ///
    /// Active chunks group by compiled family `(domain, tag, seq_len,
    /// vocab)`; within a family, chunks at equal `(t, h, warp)` sort
    /// adjacent (stably, so admission order breaks ties) and merge into
    /// one forward pass via [`RowStep`] run-grouping — concurrently
    /// admitted bundles on the same schedule share compute, while
    /// heterogeneous rows still share the single engine round-trip.
    pub fn step(&mut self) -> bool {
        if self.chunks.is_empty() {
            return false;
        }
        let step_start = Instant::now();
        let active_jobs: Vec<usize> = {
            let mut v: Vec<usize> = self.chunks.iter().map(|c| c.job).collect();
            v.sort_unstable();
            v.dedup();
            v
        };

        // Plan dispatches over a family-then-parameters ordering. The
        // ordering affects only which rows share a forward pass, never
        // their values (each row's substream and step params are its own).
        let mut order: Vec<usize> = (0..self.chunks.len()).collect();
        order.sort_by(|&a, &b| {
            let (ca, cb) = (&self.chunks[a], &self.chunks[b]);
            let (ra, rb) = (ca.row_step(), cb.row_step());
            ca.family()
                .cmp(&cb.family())
                .then(ra.t.total_cmp(&rb.t))
                .then(ra.h.total_cmp(&rb.h))
                .then(ra.warp.total_cmp(&rb.warp))
        });
        let cap = if self.max_rows > 0 { self.max_rows } else { usize::MAX };
        let mut dispatches: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut cur_rows = 0usize;
        for &i in &order {
            let c = &self.chunks[i];
            let fresh = cur.is_empty()
                || self.chunks[cur[0]].family() != c.family()
                || cur_rows + c.rows > cap;
            if fresh && !cur.is_empty() {
                dispatches.push(std::mem::take(&mut cur));
                cur_rows = 0;
            }
            cur_rows += c.rows;
            cur.push(i);
        }
        if !cur.is_empty() {
            dispatches.push(cur);
        }

        // Occupancy accounting: rows advanced this composed step, and how
        // full each dispatch ran against its row budget (the configured
        // cap, else the family's largest compiled batch — >100% means one
        // dispatch tiled over multiple compiled batches).
        let total_rows: usize = self.chunks.iter().map(|c| c.rows).sum();
        self.sched.metrics.rows_per_step.record(total_rows as f64);
        let mut occ_sum = 0i64;
        for d in &dispatches {
            let c = &self.chunks[d[0]];
            let rows: usize = d.iter().map(|&i| self.chunks[i].rows).sum();
            let denom = if self.max_rows > 0 {
                self.max_rows
            } else {
                self.sched
                    .manifest
                    .step_batches(&c.domain, &c.tag)
                    .last()
                    .copied()
                    .unwrap_or(rows)
                    .max(1)
            };
            occ_sum += (100 * rows / denom) as i64;
        }
        self.sched.metrics.batch_occupancy.set(occ_sum / dispatches.len().max(1) as i64);

        for d in &dispatches {
            let (seq_len, vocab) = (self.chunks[d[0]].seq_len, self.chunks[d[0]].vocab);
            let artifact = self.chunks[d[0]].artifact.clone();
            let mut toks: Vec<i32> = Vec::new();
            let mut row_steps: Vec<RowStep> = Vec::new();
            for &i in d {
                let c = &self.chunks[i];
                toks.extend_from_slice(&c.tokens);
                row_steps.extend(std::iter::repeat(c.row_step()).take(c.rows));
            }
            let mut probs = Vec::new();
            if let Err(e) =
                self.sched.exec.step_rows_into(&artifact, &toks, seq_len, &row_steps, &mut probs)
            {
                crate::error!("composed step failed ({e:#}); per-bundle fallback");
                self.fail_over();
                return true;
            }
            // Scatter: each chunk resamples its own positions under its
            // own (run_seed, absolute step) substream — position indices
            // match the unbatched padded batch's useful prefix.
            let mut off = 0usize;
            for &i in d {
                let c = &mut self.chunks[i];
                let abs_step = (c.schedule.step_offset + c.step_in_seg) as u64;
                for p in 0..c.rows * c.seq_len {
                    let row = &probs[(off + p) * vocab..(off + p + 1) * vocab];
                    c.tokens[p] = sample_row_seeded(row, c.run_seed, abs_step, p as u64);
                }
                off += c.rows * c.seq_len;
                c.step_in_seg += 1;
            }
        }

        // Segment boundaries: close stages, fire gates, advance or retire.
        let gate_threshold = self.sched.cascade().gate_threshold();
        let mut schedule_err = None;
        for c in &mut self.chunks {
            if c.step_in_seg < c.schedule.nfe() {
                continue;
            }
            let seg = &c.plan[c.seg_idx];
            let mut stage = StageOutcome {
                t_start: seg.t_start,
                t_end: seg.t_end,
                nfe: c.schedule.nfe(),
                score: None,
                gate_eval: None,
                // Composed steps interleave many chunks in one dispatch,
                // so per-stage wall-clock is not attributable to one
                // chunk; the timing breakdown reports the per-job
                // refine_time instead and stage durations stay zero.
                elapsed: Duration::ZERO,
            };
            let is_last = c.seg_idx + 1 == c.plan.len();
            if !is_last {
                if let Some(threshold) = gate_threshold {
                    let (score, gate_elapsed) = eval_gate(&c.tokens, c.rows, c.seq_len, c.vocab);
                    stage.score = Some(score);
                    stage.gate_eval = Some(gate_elapsed);
                    if score >= threshold {
                        c.early_exit = true;
                        c.stages.push(stage);
                        c.retired = true;
                        continue;
                    }
                }
            }
            c.stages.push(stage);
            if is_last {
                c.retired = true;
                continue;
            }
            c.seg_idx += 1;
            let next = &c.plan[c.seg_idx];
            match Schedule::segment(c.steps_cold, c.t0, next.t_start, next.t_end) {
                Ok(s) => {
                    c.schedule = s;
                    c.step_in_seg = 0;
                }
                Err(e) => {
                    schedule_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = schedule_err {
            crate::error!("composed segment advance failed ({e:#}); per-bundle fallback");
            self.fail_over();
            return true;
        }

        let elapsed = step_start.elapsed();
        self.sched.metrics.obs.span(
            0,
            0, // a composed step spans many bundles; no single id applies
            SpanKind::ComposedStep,
            total_rows as u32,
            step_start,
            elapsed,
        );
        for slot in active_jobs {
            if let Some(job) = self.jobs[slot].as_mut() {
                job.refine_time += elapsed;
            }
        }

        // Retire finished chunks; finalize jobs whose last chunk landed.
        let mut finished_jobs: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.chunks.len() {
            if !self.chunks[i].retired {
                i += 1;
                continue;
            }
            let c = self.chunks.swap_remove(i);
            let job = self.jobs[c.job].as_mut().expect("retiring chunk of a live job");
            job.done[c.slot] =
                Some(DoneChunk { tokens: c.tokens, stages: c.stages, early_exit: c.early_exit });
            job.remaining -= 1;
            if job.remaining == 0 {
                finished_jobs.push(c.job);
            }
        }
        for slot in finished_jobs {
            self.finalize(slot);
        }
        true
    }

    /// Run composed steps until every in-flight bundle has finished (the
    /// serial-path driver; the pipelined service interleaves `step` with
    /// queue ingest instead).
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    /// A composed dispatch failed: discard all composed state and re-run
    /// every in-flight bundle from its untouched draft through the
    /// per-bundle path. Deterministic RNG makes the retry's outputs
    /// identical to what the composed run would have produced; per-chunk
    /// metrics were deferred to finalization, so nothing double-counts.
    fn fail_over(&mut self) {
        self.chunks.clear();
        for slot in 0..self.jobs.len() {
            if let Some(job) = self.jobs[slot].take() {
                self.free.push(slot);
                self.completed.push((job.ctx, self.sched.refine_bundle(job.drafted)));
            }
        }
    }

    /// Assemble a finished job's responses — the mirror of
    /// `Scheduler::refine_bundle`'s aggregation, scatter, and metrics
    /// (sans `padded_rows`: the composer admits useful rows only, and
    /// padding is a per-dispatch engine concern here).
    fn finalize(&mut self, slot: usize) {
        let job = self.jobs[slot].take().expect("finalizing a live job");
        self.free.push(slot);
        let Job { ctx, drafted, done, refine_time, .. } = job;
        let result = self.build_responses(drafted, done, refine_time);
        self.completed.push((ctx, result));
    }

    fn build_responses(
        &self,
        drafted: DraftedBundle,
        done: Vec<Option<DoneChunk>>,
        refine_time: Duration,
    ) -> Result<Vec<GenResponse>> {
        let m = self.sched.metrics;
        let DraftedBundle { bundle, bundle_seed, chunks, decision, draft_time, started, .. } =
            drafted;
        // The composed path appends its own ledger record (the
        // per-bundle path's record rides `refine_bundle`, which composed
        // bundles never reach except on fail-over). Replica trails stay
        // empty for the same reason as TimingInfo below.
        let mut record = m
            .obs
            .ledger
            .enabled()
            .then(|| self.sched.decision_record_base(&bundle, bundle_seed, &decision));
        let key = &bundle.key;
        let n_total = bundle.total_samples();
        let t0 = decision.t0;
        let nfe_budget = self.sched.controller().nfe_budget(key.steps_cold, key.t0());
        m.chosen_t0.record(t0);
        let cascade_off = self.sched.cascade().is_off();
        let want_timing = bundle.requests.iter().any(|r| r.timing);
        let mut seg_timing: Vec<(usize, u64)> = Vec::new();
        let mut gate_us: Vec<u64> = Vec::new();

        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(n_total);
        let mut nfe = 0usize;
        let mut cascade_info: Option<CascadeInfo> = None;
        for (chunk, dc) in chunks.iter().zip(done) {
            let dc = dc.expect("every chunk retired before finalize");
            let total: usize = dc.stages.iter().map(|s| s.nfe).sum();
            debug_assert!(total <= nfe_budget, "NFE guarantee floor violated");
            m.nfe_saved.add(nfe_budget.saturating_sub(total) as u64);
            m.denoiser_calls.add(total as u64);
            m.batches_executed.inc();
            if cascade_off {
                nfe = total; // same schedule for every chunk in the bundle
            } else {
                nfe = nfe.max(total); // chunks may gate out at different stages
                if dc.early_exit {
                    m.cascade_early_exits.inc();
                }
                for stage in &dc.stages {
                    m.cascade_stage_nfe.record(stage.nfe as f64);
                    if let Some(d) = stage.gate_eval {
                        m.gate_eval.record(d);
                        gate_us.push(d.as_micros() as u64);
                    }
                }
                let info = cascade_info.get_or_insert(CascadeInfo {
                    stages_used: 0,
                    nfe_per_stage: Vec::new(),
                    early_exit: false,
                });
                if dc.stages.len() > info.stages_used {
                    info.stages_used = dc.stages.len();
                    info.nfe_per_stage = dc.stages.iter().map(|s| s.nfe).collect();
                    seg_timing =
                        dc.stages.iter().map(|s| (s.nfe, s.elapsed.as_micros() as u64)).collect();
                    if let Some(rec) = record.as_mut() {
                        rec.gate_scores = dc.stages.iter().filter_map(|s| s.score).collect();
                    }
                }
                info.early_exit |= dc.early_exit;
                if dc.early_exit {
                    if let Some(rec) = record.as_mut() {
                        if rec.exit_score.is_none() {
                            rec.exit_score = dc.stages.last().and_then(|s| s.score);
                        }
                    }
                }
            }
            for r in 0..chunk.chunk_len {
                rows.push(dc.tokens[r * chunk.meta.seq_len..(r + 1) * chunk.meta.seq_len].to_vec());
            }
        }
        debug_assert_eq!(rows.len(), n_total);

        if cascade_off {
            seg_timing = vec![(nfe, refine_time.as_micros() as u64)];
        }
        // The per-bundle path's TimingInfo, mirrored: same NFE floor and
        // segment NFEs. Replica/reroute trails stay empty — a composed
        // step's dispatches serve many bundles at once, so a per-response
        // attribution would be fiction.
        let timing_proto = want_timing.then(|| TimingInfo {
            nfe_floor: nfe_budget,
            segments: seg_timing,
            gate_us,
            replicas: Vec::new(),
            reroutes: 0,
        });

        if let Some(rec) = record.as_mut() {
            rec.nfe = nfe;
            if let Some(info) = &cascade_info {
                rec.nfe_per_stage = info.nfe_per_stage.clone();
                rec.early_exit = info.early_exit;
            }
        }
        let total_time = started.elapsed();
        let now = Instant::now();
        let mut responses = Vec::with_capacity(bundle.requests.len());
        let mut cursor = 0;
        for (ri, req) in bundle.requests.iter().enumerate() {
            let samples = rows[cursor..cursor + req.n_samples].to_vec();
            cursor += req.n_samples;
            if let Some(rec) = record.as_mut() {
                rec.requests[ri].out_hash = crate::obs::ledger::hash_samples(&samples);
            }
            responses.push(GenResponse {
                id: req.id,
                samples,
                nfe,
                t0_used: t0,
                cascade: cascade_info.clone(),
                queue_wait: now.saturating_duration_since(req.submitted).saturating_sub(total_time),
                draft_time,
                refine_time,
                total_time,
                degraded: None,
                timing: if req.timing { timing_proto.clone() } else { None },
            });
            m.requests_completed.inc();
            m.samples.record(req.n_samples as u64);
        }
        m.batch_exec.record(total_time);
        if let Some(rec) = record {
            m.obs.ledger.append(rec);
        }
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::Cascade;
    use crate::config::CascadeConfig;
    use crate::control::Controller;
    use crate::coordinator::batcher::WorkBundle;
    use crate::coordinator::request::GenRequest;
    use crate::coordinator::testutil::{mock_manifest, request, TestExec};
    use crate::core::schedule::guaranteed_nfe;
    use crate::metrics::ServingMetrics;
    use crate::runtime::engine::{Executor, LoopReport, LoopScratch, LoopSpec};
    use crate::runtime::artifact::ArtifactMeta;
    use crate::runtime::Manifest;
    use anyhow::bail;

    fn mk_bundle(spec: &[(u64, usize)]) -> WorkBundle {
        let reqs: Vec<GenRequest> = spec
            .iter()
            .map(|&(seed, n)| {
                let mut r = request(0, n);
                r.seed = seed;
                r
            })
            .collect();
        WorkBundle::new(reqs[0].bundle_key(), reqs)
    }

    fn cascade_for(mode: &str) -> Cascade {
        // Threshold 0 makes `gated` deterministically exit after stage 1 —
        // the retirement-asymmetry case worth pinning.
        let cfg =
            CascadeConfig { mode: mode.into(), gate_threshold: 0.0, ..CascadeConfig::default() };
        Cascade::from_config(&cfg).unwrap()
    }

    /// The wire-visible part of a response (timings excluded).
    fn wire(r: &GenResponse) -> (f64, usize, Vec<Vec<i32>>, Option<CascadeInfo>, bool) {
        (r.t0_used, r.nfe, r.samples.clone(), r.cascade.clone(), r.degraded.is_some())
    }

    fn reference(mode: &str, bundles: &[Vec<(u64, usize)>]) -> Vec<Vec<(f64, usize, Vec<Vec<i32>>, Option<CascadeInfo>, bool)>> {
        let exec = TestExec::stochastic(vec![1, 4, 8], 6, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 6, 5);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::with_policies(
            &exec,
            &manifest,
            &metrics,
            99,
            Controller::static_default(),
            cascade_for(mode),
        );
        bundles
            .iter()
            .map(|b| {
                let drafted = sched.draft_bundle(mk_bundle(b)).unwrap();
                sched.refine_bundle(drafted).unwrap().iter().map(wire).collect()
            })
            .collect()
    }

    const BUNDLES: &[&[(u64, usize)]] =
        &[&[(1000, 2), (1001, 3)], &[(2000, 1)], &[(3000, 6), (3001, 1), (3002, 2)]];

    #[test]
    fn composed_output_is_bitwise_identical_to_per_bundle_refine() {
        // The tentpole parity pin, composer-core level: three bundles of
        // mixed sizes admitted together, stepped through shared composed
        // dispatches, must produce exactly the per-bundle path's wire
        // responses — per cascade mode, including the gated early exit.
        for mode in ["off", "fixed", "gated"] {
            let bundles: Vec<Vec<(u64, usize)>> =
                BUNDLES.iter().map(|b| b.to_vec()).collect();
            let want = reference(mode, &bundles);

            let exec = TestExec::stochastic(vec![1, 4, 8], 6, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4, 8], 6, 5);
            let metrics = ServingMetrics::default();
            let sched = Scheduler::with_policies(
                &exec,
                &manifest,
                &metrics,
                99,
                Controller::static_default(),
                cascade_for(mode),
            );
            let mut comp: ComposedRefiner<usize> = ComposedRefiner::new(&sched, 0);
            for (bi, b) in bundles.iter().enumerate() {
                comp.admit(bi, sched.draft_bundle(mk_bundle(b)).unwrap());
            }
            comp.run_until_idle();
            let mut got = comp.take_completed();
            assert_eq!(got.len(), bundles.len(), "{mode}: lost bundles");
            got.sort_by_key(|(bi, _)| *bi);
            for (bi, result) in got {
                let responses = result.unwrap();
                let wired: Vec<_> = responses.iter().map(wire).collect();
                assert_eq!(wired, want[bi], "{mode}: bundle {bi} diverged composed");
            }
            // Composed steps actually happened and were observed.
            assert!(metrics.rows_per_step.snapshot().count > 0);
            assert!(metrics.batch_occupancy.get() > 0);
        }
    }

    #[test]
    fn mid_flight_admission_at_step_boundaries_changes_nothing() {
        // vLLM-style continuous admission: bundle B joins after A already
        // advanced two composed steps; both still match their per-bundle
        // references bit for bit, and a row cap that splits dispatches
        // doesn't change values either.
        for max_rows in [0usize, 4] {
            let bundles: Vec<Vec<(u64, usize)>> =
                vec![BUNDLES[0].to_vec(), BUNDLES[2].to_vec()];
            let want = reference("fixed", &bundles);
            let exec = TestExec::stochastic(vec![1, 4, 8], 6, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4, 8], 6, 5);
            let metrics = ServingMetrics::default();
            let sched = Scheduler::with_policies(
                &exec,
                &manifest,
                &metrics,
                99,
                Controller::static_default(),
                cascade_for("fixed"),
            );
            let mut comp: ComposedRefiner<usize> = ComposedRefiner::new(&sched, max_rows);
            comp.admit(0, sched.draft_bundle(mk_bundle(&bundles[0])).unwrap());
            assert!(comp.step());
            assert!(comp.step());
            comp.admit(1, sched.draft_bundle(mk_bundle(&bundles[1])).unwrap());
            comp.run_until_idle();
            assert!(!comp.has_work());
            let mut got = comp.take_completed();
            got.sort_by_key(|(bi, _)| *bi);
            for (bi, result) in got {
                let wired: Vec<_> = result.unwrap().iter().map(wire).collect();
                assert_eq!(wired, want[bi], "max_rows={max_rows}: bundle {bi} diverged");
            }
        }
    }

    #[test]
    fn composed_nfe_respects_the_guarantee() {
        // The per-request guarantee with the composer engaged: summed
        // per-stage NFE never exceeds guaranteed_nfe(steps_cold, t0).
        for mode in ["off", "fixed", "gated"] {
            let exec = TestExec::stochastic(vec![1, 4, 8], 6, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4, 8], 6, 5);
            let metrics = ServingMetrics::default();
            let sched = Scheduler::with_policies(
                &exec,
                &manifest,
                &metrics,
                7,
                Controller::static_default(),
                cascade_for(mode),
            );
            let mut comp: ComposedRefiner<()> = ComposedRefiner::new(&sched, 0);
            comp.admit((), sched.draft_bundle(mk_bundle(&[(5, 4), (6, 3)])).unwrap());
            comp.run_until_idle();
            let budget = guaranteed_nfe(10, 0.5); // request(): t0=0.5, 10 steps
            for (_, result) in comp.take_completed() {
                for resp in result.unwrap() {
                    assert!(resp.nfe <= budget, "{mode}: nfe {} > budget {budget}", resp.nfe);
                    assert!(resp.nfe >= 1);
                    if let Some(info) = &resp.cascade {
                        assert_eq!(info.nfe_per_stage.iter().sum::<usize>(), resp.nfe, "{mode}");
                    }
                }
            }
        }
    }

    /// An executor whose composed (`step_rows_into`) path always fails
    /// but whose per-bundle loop works — exercises failure containment.
    struct ComposedPathDown(TestExec);

    impl Executor for ComposedPathDown {
        fn step_into(
            &self,
            artifact: &str,
            tokens: &[i32],
            t: f32,
            h: f32,
            warp: f32,
            out: &mut Vec<f32>,
        ) -> anyhow::Result<()> {
            self.0.step_into(artifact, tokens, t, h, warp, out)
        }
        fn step_rows_into(
            &self,
            _artifact: &str,
            _tokens: &[i32],
            _seq_len: usize,
            _rows: &[RowStep],
            _out: &mut Vec<f32>,
        ) -> anyhow::Result<()> {
            bail!("composed path down")
        }
        fn run_loop(
            &self,
            spec: &LoopSpec,
            tokens: &mut Vec<i32>,
            scratch: &mut LoopScratch,
        ) -> anyhow::Result<LoopReport> {
            self.0.run_loop(spec, tokens, scratch)
        }
        fn draft(&self, a: &str, n: &[f32]) -> anyhow::Result<Vec<i32>> {
            self.0.draft(a, n)
        }
        fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
            self.0.meta(artifact)
        }
    }

    fn manifest_and(mode: &str) -> (Manifest, Cascade) {
        (mock_manifest(&["cold"], &[1, 4, 8], 6, 5), cascade_for(mode))
    }

    #[test]
    fn dispatch_failure_falls_back_to_the_per_bundle_path_bitwise() {
        // A composed-step error re-runs every in-flight bundle from its
        // untouched draft: no lost bundles, and (stateless RNG) the
        // fallback outputs equal the healthy composed/per-bundle outputs.
        let bundles: Vec<Vec<(u64, usize)>> = BUNDLES.iter().map(|b| b.to_vec()).collect();
        let want = reference("fixed", &bundles);
        let exec = ComposedPathDown(TestExec::stochastic(vec![1, 4, 8], 6, 5, 2));
        let (manifest, cascade) = manifest_and("fixed");
        let metrics = ServingMetrics::default();
        let sched = Scheduler::with_policies(
            &exec,
            &manifest,
            &metrics,
            99,
            Controller::static_default(),
            cascade,
        );
        let mut comp: ComposedRefiner<usize> = ComposedRefiner::new(&sched, 0);
        for (bi, b) in bundles.iter().enumerate() {
            comp.admit(bi, sched.draft_bundle(mk_bundle(b)).unwrap());
        }
        comp.run_until_idle();
        assert!(!comp.has_work());
        let mut got = comp.take_completed();
        assert_eq!(got.len(), bundles.len(), "fallback lost bundles");
        got.sort_by_key(|(bi, _)| *bi);
        for (bi, result) in got {
            let wired: Vec<_> = result.unwrap().iter().map(wire).collect();
            assert_eq!(wired, want[bi], "fallback diverged for bundle {bi}");
        }
    }
}
