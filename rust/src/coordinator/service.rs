//! The serving loop: wires admission queue → batcher → staged
//! DRAFT→REFINE pipeline → response channels.
//!
//! ## Why a pipeline
//!
//! The paper's speed-up guarantee is per-sample NFE, but serving
//! throughput used to be bounded here: the admission thread ran
//! `Scheduler::run_bundle` inline, so while one bundle refined, new
//! requests piled up unadmitted and deadline flushes slipped — the exact
//! tail-latency failure continuous batching exists to avoid. Now the
//! **admission thread only validates, batches, and flushes**; flushed
//! bundles flow over bounded channels to a DRAFT stage
//! (`config.draft_workers` threads generating warm-start init tokens) and
//! then to a REFINE stage (`config.fleet.refine_workers` threads driving
//! the engine-resident Euler loop — sized to the executor fleet's replica
//! count, since each engine replica is one execution stream and extra
//! workers beyond that only contend). Drafting bundle N+1 overlaps
//! refining bundle N, independent bundles refine concurrently on distinct
//! fleet replicas, and deadline flushes never wait on execution.
//!
//! An [`InflightGate`] caps dispatched-but-incomplete bundles at
//! `config.pipeline_depth`, bounding memory and keeping backpressure at
//! the admission queue where it surfaces as a typed BUSY response.
//! `pipeline_depth = 1` skips the stage threads entirely and runs bundles
//! inline (the legacy serial path — same outputs, pinned by tests,
//! because all bundle RNG is stateless per
//! [`crate::coordinator::scheduler::bundle_seed`]).
//!
//! ## Graceful drain
//!
//! `shutdown()` stops admissions; the admission thread drains the queue
//! and the batcher into the pipeline, then closes the draft channel; the
//! last draft worker closes the refine channel; every refine worker
//! drains and exits. Every admitted envelope gets a response or a clean
//! error — no hung receivers (pinned by the shutdown-under-load test).
//! Stage threads poll their channels at `robustness.stage_poll_ms`, so
//! drain latency is a small multiple of that knob (pinned by the
//! shutdown-latency test).
//!
//! ## Draft-fallback degradation
//!
//! When REFINE fails — the fleet exhausted its reroutes (`FleetDown`),
//! or an execution error survived — the bundle's **already-computed
//! draft tokens** are served instead of an error: the warm-start draft
//! is a complete (if unrefined) sample by construction, which is the
//! paper's premise. Degraded responses carry `degraded: true` plus a
//! reason on the wire (absent otherwise — the legacy byte layout is
//! pinned), report `nfe: 0`, and count in `degraded_responses`. Disable
//! with `robustness.draft_fallback = false` to surface refine errors
//! verbatim. Draft-stage failures are *not* degradable (there is nothing
//! to serve yet) and stay typed errors.

use crate::cascade::Cascade;
use crate::config::{ComposerConfig, WsfmConfig};
use crate::control::Controller;
use crate::coordinator::batcher::{Batcher, FlushPolicy, WorkBundle};
use crate::coordinator::composer::ComposedRefiner;
use crate::coordinator::queue::{BoundedQueue, QueueFull};
use crate::coordinator::request::{BundleKey, GenRequest, GenResponse};
use crate::coordinator::scheduler::{DraftedBundle, Scheduler};
use crate::metrics::ServingMetrics;
use crate::obs::{EventKind, Obs, SpanKind};
use crate::runtime::engine::Executor;
use crate::runtime::Manifest;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-request response channel.
type Responder = mpsc::Sender<Result<GenResponse, String>>;

/// A submitted request waiting for its response.
struct Envelope {
    request: GenRequest,
    resp: Responder,
}

/// A flushed bundle travelling to the DRAFT stage, with the response
/// channels of its requests (same order as `bundle.requests`).
struct PipelineJob {
    bundle: WorkBundle,
    responders: Vec<Responder>,
    /// When the admission thread dispatched it (for `draft_queue_wait`).
    dispatched: Instant,
}

/// A drafted bundle travelling to the REFINE stage.
struct DraftedJob {
    drafted: DraftedBundle,
    responders: Vec<Responder>,
}

/// Counting gate bounding bundles in flight across the pipeline.
/// `acquire` blocks the admission thread when `pipeline_depth` bundles
/// are already dispatched; completion (or failure) releases a slot.
struct InflightGate {
    max: usize,
    count: Mutex<usize>,
    cv: Condvar,
}

impl InflightGate {
    fn new(max: usize) -> InflightGate {
        InflightGate { max: max.max(1), count: Mutex::new(0), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut g = self.count.lock().unwrap();
        while *g >= self.max {
            g = self.cv.wait(g).unwrap();
        }
        *g += 1;
    }

    fn release(&self) {
        let mut g = self.count.lock().unwrap();
        *g = g.saturating_sub(1);
        drop(g);
        self.cv.notify_all();
    }
}

/// Handle for submitting work; cloneable across server connections.
#[derive(Clone)]
pub struct Service {
    queue: Arc<BoundedQueue<Envelope>>,
    pub metrics: Arc<ServingMetrics>,
    next_id: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
    /// Per-busy-slot unit for the BUSY retry hint (one flush interval);
    /// [`Service::retry_after`] scales it by current occupancy.
    retry_base: Duration,
}

impl Service {
    /// Start the coordinator threads over an executor + manifest.
    pub fn start<E: Executor + 'static>(exec: E, manifest: Manifest, config: WsfmConfig) -> Service {
        let queue = Arc::new(BoundedQueue::<Envelope>::new(config.queue_capacity));
        // Observability hub ([`crate::obs`], tuned by `config.obs`):
        // bounded span/event journals shared by every stage thread
        // through the metrics handle. Strictly write-only with respect to
        // scheduling — disabling it changes no output byte.
        let obs = Arc::new(
            Obs::new(config.obs.enabled, config.obs.span_cap, config.obs.event_cap)
                .with_ledger(crate::obs::ledger::Ledger::from_config(&config.obs.ledger)),
        );
        let metrics = Arc::new(ServingMetrics::with_obs(obs));
        let running = Arc::new(AtomicBool::new(true));
        // Backpressure hint unit: roughly one flush interval, floored at
        // 1 ms; `retry_after()` scales it by live occupancy.
        let retry_base = Duration::from_micros(config.batcher.max_wait_us.max(1_000));
        let policy = FlushPolicy {
            max_batch: config.batcher.max_batch,
            max_wait: Duration::from_micros(config.batcher.max_wait_us),
        };
        let exec = Arc::new(exec);
        let manifest = Arc::new(manifest);
        let seed = config.seed;
        // One controller per stage thread: pure data, so clones decide
        // identically everywhere (the determinism contract). An invalid
        // control section falls back to the legacy static behaviour —
        // config::validate rejects it at load time; this guards callers
        // that skip validation.
        let controller = Controller::from_config(&config.control).unwrap_or_else(|e| {
            crate::error!("invalid control config ({e:#}); using static t0");
            Controller::static_default()
        });
        // Same pattern for the cascade policy: pure data, cloned per stage
        // thread; an invalid section degrades to the legacy single-segment
        // path (config::validate rejects it at load time).
        let cascade = Cascade::from_config(&config.cascade).unwrap_or_else(|e| {
            crate::error!("invalid cascade config ({e:#}); cascade off");
            Cascade::off()
        });
        // Robustness knobs threaded to every stage thread: channel poll
        // cadence (bounds drain latency) and the draft-fallback switch.
        let stage_poll = config.robustness.stage_poll();
        let draft_fallback = config.robustness.draft_fallback;
        // Step-level batch composer: when enabled, REFINE merges rows
        // from every in-flight bundle into shared engine steps
        // ([`crate::coordinator::composer`]); off = per-bundle path.
        let composer = config.composer.clone();

        if config.pipeline_depth <= 1 {
            // Serial path: the admission thread executes bundles inline —
            // split into DRAFT then REFINE so a refine failure can still
            // degrade to the drafted tokens.
            let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
            let controller = controller.clone();
            let cascade = cascade.clone();
            std::thread::Builder::new()
                .name("wsfm-coordinator".into())
                .spawn(move || {
                    let scheduler = Scheduler::with_policies(
                        &*exec, &*manifest, &*m, seed, controller, cascade,
                    );
                    admission_loop(&q, &r, policy, stage_poll, |mut bundle, envelopes| {
                        bundle.bundle_id = m.obs.next_bundle_id();
                        record_admission_spans(&m, &bundle);
                        let responders = take_responders(&bundle, envelopes);
                        record_flush_lag(&m, &bundle);
                        m.inflight_bundles.inc();
                        let key = bundle.key.clone();
                        match scheduler.draft_bundle(bundle) {
                            Ok(drafted) => {
                                let fallback =
                                    fallback_plan(&scheduler, &drafted, draft_fallback);
                                // Even serially the composer earns its
                                // keep: a bundle's chunks (and cascade
                                // segments) share engine steps.
                                let result = if composer.enabled {
                                    let mut comp =
                                        ComposedRefiner::new(&scheduler, composer.max_rows);
                                    comp.admit((), drafted);
                                    comp.run_until_idle();
                                    match comp.take_completed().pop() {
                                        Some((_, r)) => r,
                                        None => Err(anyhow::anyhow!("composer lost the bundle")),
                                    }
                                } else {
                                    scheduler.refine_bundle(drafted)
                                };
                                deliver_or_degrade(result, fallback, responders, &m, &key);
                            }
                            Err(e) => deliver(Err(e), responders, &m, &key),
                        }
                        m.inflight_bundles.dec();
                    });
                })
                .expect("spawning coordinator thread");
        } else {
            let draft_q = Arc::new(BoundedQueue::<PipelineJob>::new(config.pipeline_depth));
            let refine_q = Arc::new(BoundedQueue::<DraftedJob>::new(config.pipeline_depth));
            let gate = Arc::new(InflightGate::new(config.pipeline_depth));
            let active_drafters = Arc::new(AtomicUsize::new(config.draft_workers));

            for w in 0..config.draft_workers {
                let (exec, manifest, metrics) = (exec.clone(), manifest.clone(), metrics.clone());
                let (dq, rq, gate) = (draft_q.clone(), refine_q.clone(), gate.clone());
                let active = active_drafters.clone();
                let controller = controller.clone();
                let cascade = cascade.clone();
                std::thread::Builder::new()
                    .name(format!("wsfm-draft-{w}"))
                    .spawn(move || {
                        draft_stage(
                            &*exec, &*manifest, &metrics, seed, controller, cascade, &dq, &rq,
                            &gate, stage_poll, draft_fallback,
                        );
                        // Last drafter out closes the refine channel so
                        // the refine thread can drain and exit.
                        if active.fetch_sub(1, Ordering::SeqCst) == 1 {
                            rq.close();
                        }
                    })
                    .expect("spawning draft worker thread");
            }

            // `fleet.refine_workers` REFINE threads pull from the staged
            // channel, so independent bundles refine concurrently on
            // distinct fleet replicas (with one engine replica, extra
            // workers just queue on its stream — size to `fleet.replicas`).
            // Workers need no close duties: each drains the refine channel
            // (closed by the last draft worker) and exits.
            for w in 0..config.fleet.refine_workers {
                let (exec, manifest, metrics) = (exec.clone(), manifest.clone(), metrics.clone());
                let (rq, gate) = (refine_q.clone(), gate.clone());
                let controller = controller.clone();
                let cascade = cascade.clone();
                let composer = composer.clone();
                std::thread::Builder::new()
                    .name(format!("wsfm-refine-{w}"))
                    .spawn(move || {
                        refine_stage(
                            &*exec, &*manifest, &metrics, seed, controller, cascade, &rq, &gate,
                            stage_poll, draft_fallback, composer,
                        )
                    })
                    .expect("spawning refine worker thread");
            }

            let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
            std::thread::Builder::new()
                .name("wsfm-coordinator".into())
                .spawn(move || {
                    admission_loop(&q, &r, policy, stage_poll, |mut bundle, envelopes| {
                        bundle.bundle_id = m.obs.next_bundle_id();
                        record_admission_spans(&m, &bundle);
                        let responders = take_responders(&bundle, envelopes);
                        record_flush_lag(&m, &bundle);
                        gate.acquire();
                        m.inflight_bundles.inc();
                        let key = bundle.key.clone();
                        let job = PipelineJob { bundle, responders, dispatched: Instant::now() };
                        if let Err(job) = draft_q.push_wait(job) {
                            // Stage channel closed (cannot happen before
                            // this thread closes it, but fail cleanly).
                            deliver(
                                Err(anyhow::anyhow!("pipeline shut down")),
                                job.responders,
                                &m,
                                &key,
                            );
                            m.inflight_bundles.dec();
                            gate.release();
                        }
                    });
                    // All bundles dispatched; let the stages drain.
                    draft_q.close();
                })
                .expect("spawning coordinator thread");
        }

        Service { queue, metrics, next_id: Arc::new(AtomicU64::new(1)), running, retry_base }
    }

    /// Submit a request; returns a receiver for the response.
    ///
    /// `Err(QueueFull)` is backpressure — the caller should surface "busy"
    /// with [`Service::retry_after`] as the hint.
    pub fn submit(
        &self,
        mut request: GenRequest,
    ) -> Result<mpsc::Receiver<Result<GenResponse, String>>, QueueFull> {
        request.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        request.submitted = Instant::now();
        let (tx, rx) = mpsc::channel();
        self.queue.push(Envelope { request, resp: tx }).map_err(|_| {
            self.metrics.requests_rejected.inc();
            QueueFull
        })?;
        self.metrics.requests_admitted.inc();
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn generate(&self, request: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(request).map_err(|e| anyhow::anyhow!("{e}"))?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => anyhow::bail!("generation failed: {msg}"),
            Err(_) => anyhow::bail!("coordinator dropped the request"),
        }
    }

    /// Suggested client retry delay after a BUSY rejection, derived from
    /// the *current* occupancy rather than static config: a fully drained
    /// pipeline (the gate released a moment after the rejection) hints
    /// "retry basically now" (1 ms), while each in-flight bundle and each
    /// admission-queue backlog's worth of requests adds one flush
    /// interval. Capped so a deep backlog never tells clients to go away
    /// for seconds.
    pub fn retry_after(&self) -> Duration {
        let inflight = self.metrics.inflight_bundles.get().max(0) as u64;
        let queued = self.queue.len() as u64;
        if inflight == 0 && queued == 0 {
            return Duration::from_millis(1);
        }
        // Queue backlog counts fractionally: many queued requests fold
        // into few bundles. One slot per 8 queued requests is a coarse
        // but monotone proxy.
        let busy_slots = (inflight + queued.div_ceil(8)).clamp(1, 32);
        Duration::from_millis(1) + self.retry_base * busy_slots as u32
    }

    /// Graceful shutdown: stop accepting, drain the pipeline, stop the
    /// stage threads.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }
}

/// Pull the response channels for a flushed bundle out of the envelope
/// map (same order as `bundle.requests`).
fn take_responders(bundle: &WorkBundle, envelopes: &mut HashMap<u64, Responder>) -> Vec<Responder> {
    let responders: Vec<Responder> =
        bundle.requests.iter().filter_map(|r| envelopes.remove(&r.id)).collect();
    debug_assert_eq!(responders.len(), bundle.requests.len());
    responders
}

/// Record per-request `admit` + `batcher_wait` spans at dispatch: `admit`
/// pins the submission instant (zero duration), `batcher_wait` covers
/// submit → flush. Both are request-scoped (they carry the request id as
/// well as the freshly-minted bundle id), so `{"cmd":"trace"}` can join
/// them to the bundle-scoped draft/refine spans.
fn record_admission_spans(metrics: &ServingMetrics, bundle: &WorkBundle) {
    if !metrics.obs.enabled() {
        return;
    }
    let now = Instant::now();
    for r in &bundle.requests {
        metrics.obs.span(r.id, bundle.bundle_id, SpanKind::Admit, 0, r.submitted, Duration::ZERO);
        metrics.obs.span(
            r.id,
            bundle.bundle_id,
            SpanKind::BatcherWait,
            0,
            r.submitted,
            now.saturating_duration_since(r.submitted),
        );
    }
}

/// Record how a bundle's dispatch relates to its flush deadline. A bundle
/// can flush *before* its deadline (size-triggered); its negative lag
/// used to clamp to a garbage 0 µs sample in `flush_lag`, dragging the
/// percentiles down. Early flushes now count separately (`early_flushes`
/// + the `flush_early` headroom histogram) and `flush_lag` only ever sees
/// true ≥ 0 lags.
fn record_flush_lag(metrics: &ServingMetrics, bundle: &WorkBundle) {
    if let Some(deadline) = bundle.deadline {
        let now = Instant::now();
        if now >= deadline {
            metrics.flush_lag.record(now.saturating_duration_since(deadline));
        } else {
            metrics.early_flushes.inc();
            metrics.flush_early.record(deadline.saturating_duration_since(now));
        }
    }
}

/// Send a bundle's outcome to its requesters, recording latency metrics.
fn deliver(
    result: Result<Vec<GenResponse>>,
    responders: Vec<Responder>,
    metrics: &ServingMetrics,
    key: &BundleKey,
) {
    match result {
        Ok(responses) => {
            debug_assert_eq!(responses.len(), responders.len());
            for (resp, tx) in responses.into_iter().zip(responders) {
                metrics.queue_wait.record(resp.queue_wait);
                metrics.request_latency.record(resp.queue_wait + resp.total_time);
                let _ = tx.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            crate::error!("bundle {}/{} failed: {msg}", key.domain, key.tag);
            for tx in responders {
                let _ = tx.send(Err(msg.clone()));
            }
        }
    }
}

/// Everything needed to serve a bundle's *draft* tokens if refinement
/// fails, captured before [`Scheduler::refine_bundle`] consumes the
/// [`DraftedBundle`]: the useful (non-padding) drafted rows in FIFO
/// scatter order, plus the per-request bookkeeping the response needs.
struct FallbackPlan {
    /// Useful drafted rows across chunks, in request FIFO order.
    rows: Vec<Vec<i32>>,
    /// `(id, n_samples, submitted)` per request, same order.
    per_request: Vec<(u64, usize, Instant)>,
    t0: f64,
    draft_time: Duration,
    started: Instant,
    /// Pre-built degraded decision-ledger record (outcome fields zeroed
    /// = "billed nothing", hashes over the draft rows). Appended only if
    /// the bundle actually degrades; dropped when refinement succeeds
    /// (the refine path appends its own record).
    record: Option<crate::obs::ledger::DecisionRecord>,
}

impl FallbackPlan {
    /// Scatter the drafted rows into degraded responses (`nfe: 0`, no
    /// cascade info, `degraded: Some(reason)`).
    fn into_responses(self, reason: &str) -> Vec<GenResponse> {
        let FallbackPlan { rows, per_request, t0, draft_time, started, .. } = self;
        let total_time = started.elapsed();
        let now = Instant::now();
        let mut responses = Vec::with_capacity(per_request.len());
        let mut cursor = 0;
        for (id, n_samples, submitted) in per_request {
            let samples = rows[cursor..cursor + n_samples].to_vec();
            cursor += n_samples;
            responses.push(GenResponse {
                id,
                samples,
                nfe: 0,
                t0_used: t0,
                cascade: None,
                queue_wait: now.saturating_duration_since(submitted).saturating_sub(total_time),
                draft_time,
                refine_time: Duration::ZERO,
                total_time,
                degraded: Some(reason.to_string()),
                timing: None,
            });
        }
        responses
    }
}

/// Capture the draft-fallback for a bundle about to refine. `None` when
/// degradation is disabled (`robustness.draft_fallback = false`).
/// `sched` builds the degraded decision-ledger record (ledger-gated).
fn fallback_plan(
    sched: &Scheduler<'_>,
    drafted: &DraftedBundle,
    enabled: bool,
) -> Option<FallbackPlan> {
    if !enabled {
        return None;
    }
    let mut rows = Vec::with_capacity(drafted.bundle.total_samples());
    for chunk in &drafted.chunks {
        for r in 0..chunk.chunk_len {
            rows.push(chunk.init.row(r).to_vec());
        }
    }
    let record = sched.metrics.obs.ledger.enabled().then(|| {
        let mut rec =
            sched.decision_record_base(&drafted.bundle, drafted.bundle_seed, &drafted.decision);
        rec.degraded = true;
        let mut cursor = 0;
        for rr in rec.requests.iter_mut() {
            rr.out_hash = crate::obs::ledger::hash_samples(&rows[cursor..cursor + rr.n_samples]);
            cursor += rr.n_samples;
        }
        rec
    });
    Some(FallbackPlan {
        rows,
        per_request: drafted
            .bundle
            .requests
            .iter()
            .map(|r| (r.id, r.n_samples, r.submitted))
            .collect(),
        t0: drafted.decision.t0,
        draft_time: drafted.draft_time,
        started: drafted.started,
        record,
    })
}

/// [`deliver`], except a refine failure with a captured fallback serves
/// the drafted tokens as degraded successes instead of errors. Counts
/// completions/samples itself on the degraded path (the normal path
/// counts them inside `refine_bundle`), so the "every admitted envelope
/// is accounted for" invariant holds either way.
fn deliver_or_degrade(
    result: Result<Vec<GenResponse>>,
    fallback: Option<FallbackPlan>,
    responders: Vec<Responder>,
    metrics: &ServingMetrics,
    key: &BundleKey,
) {
    match result {
        Err(e) => {
            let Some(mut plan) = fallback else {
                deliver(Err(e), responders, metrics, key);
                return;
            };
            let reason = format!("refine failed: {e:#}");
            crate::error!(
                "bundle {}/{} degraded to draft tokens: {reason}",
                key.domain,
                key.tag
            );
            metrics.obs.event(EventKind::Degraded, None, reason.clone());
            if let Some(rec) = plan.record.take() {
                metrics.obs.ledger.append(rec);
            }
            let responses = plan.into_responses(&reason);
            debug_assert_eq!(responses.len(), responders.len());
            for (resp, tx) in responses.into_iter().zip(responders) {
                metrics.queue_wait.record(resp.queue_wait);
                metrics.request_latency.record(resp.queue_wait + resp.total_time);
                metrics.requests_completed.inc();
                metrics.samples.record(resp.samples.len() as u64);
                metrics.degraded_responses.inc();
                let _ = tx.send(Ok(resp));
            }
        }
        ok => deliver(ok, responders, metrics, key),
    }
}

/// The admission thread body: validate, batch, flush — never execute.
/// `dispatch` is the only difference between the serial path (runs the
/// bundle inline) and the pipelined path (hands it to the DRAFT stage).
fn admission_loop(
    queue: &BoundedQueue<Envelope>,
    running: &AtomicBool,
    policy: FlushPolicy,
    stage_poll: Duration,
    mut dispatch: impl FnMut(WorkBundle, &mut HashMap<u64, Responder>),
) {
    let mut batcher = Batcher::new(policy);
    // Envelopes are held out-of-band, keyed by request id, so the batcher
    // itself stays a pure GenRequest structure.
    let mut envelopes: HashMap<u64, Responder> = HashMap::new();
    loop {
        // Sleep until the next flush deadline (capped at the stage poll so
        // shutdown is always noticed within one poll interval).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(stage_poll);
        match queue.pop_timeout(timeout.min(stage_poll)) {
            Some(env) => {
                if let Err(e) = env.request.validate() {
                    let _ = env.resp.send(Err(format!("invalid request: {e:#}")));
                    continue;
                }
                envelopes.insert(env.request.id, env.resp);
                if let Some(bundle) = batcher.offer(env.request) {
                    dispatch(bundle, &mut envelopes);
                }
            }
            None => {
                if !running.load(Ordering::SeqCst) && queue.is_empty() {
                    // Drain remaining bundles, then exit.
                    for bundle in batcher.flush_all() {
                        dispatch(bundle, &mut envelopes);
                    }
                    break;
                }
            }
        }
        for bundle in batcher.due(Instant::now()) {
            dispatch(bundle, &mut envelopes);
        }
    }
}

/// DRAFT-stage worker body: pop flushed bundles, generate warm-start init
/// tokens, hand the [`DraftedBundle`] to the REFINE stage.
#[allow(clippy::too_many_arguments)]
fn draft_stage(
    exec: &dyn Executor,
    manifest: &Manifest,
    metrics: &ServingMetrics,
    seed: u64,
    controller: Controller,
    cascade: Cascade,
    draft_q: &BoundedQueue<PipelineJob>,
    refine_q: &BoundedQueue<DraftedJob>,
    gate: &InflightGate,
    stage_poll: Duration,
    draft_fallback: bool,
) {
    let scheduler = Scheduler::with_policies(exec, manifest, metrics, seed, controller, cascade);
    loop {
        match draft_q.pop_timeout(stage_poll) {
            Some(job) => {
                metrics.draft_queue_wait.record(job.dispatched.elapsed());
                let key = job.bundle.key.clone();
                match scheduler.draft_bundle(job.bundle) {
                    Ok(drafted) => {
                        let handoff = DraftedJob { drafted, responders: job.responders };
                        if let Err(handoff) = refine_q.push_wait(handoff) {
                            // The refine channel closed under us: the
                            // drafts exist, so this still degrades
                            // rather than erroring.
                            let DraftedJob { drafted, responders } = handoff;
                            let fallback = fallback_plan(&scheduler, &drafted, draft_fallback);
                            deliver_or_degrade(
                                Err(anyhow::anyhow!("refine stage shut down")),
                                fallback,
                                responders,
                                metrics,
                                &key,
                            );
                            metrics.inflight_bundles.dec();
                            gate.release();
                        }
                    }
                    Err(e) => {
                        deliver(Err(e), job.responders, metrics, &key);
                        metrics.inflight_bundles.dec();
                        gate.release();
                    }
                }
            }
            None => {
                if draft_q.is_closed() && draft_q.is_empty() {
                    break;
                }
            }
        }
    }
}

/// REFINE-stage worker body: drives the engine-facing Euler loop. The
/// service spawns `fleet.refine_workers` of these over one shared MPMC
/// refine channel; with a replicated executor fleet each concurrently
/// popped bundle lands on a distinct engine replica (least-loaded
/// routing), so refinement itself scales past one execution stream.
///
/// With `composer.enabled` the worker runs the continuous-batching loop
/// instead: every ready [`DraftedJob`] admits into a [`ComposedRefiner`]
/// at the next step boundary, in-flight bundles share composed engine
/// steps, and finished bundles deliver as they retire — same outputs
/// ([`crate::coordinator::composer`]'s bitwise contract), same
/// accounting, different grouping.
#[allow(clippy::too_many_arguments)]
fn refine_stage(
    exec: &dyn Executor,
    manifest: &Manifest,
    metrics: &ServingMetrics,
    seed: u64,
    controller: Controller,
    cascade: Cascade,
    refine_q: &BoundedQueue<DraftedJob>,
    gate: &InflightGate,
    stage_poll: Duration,
    draft_fallback: bool,
    composer: ComposerConfig,
) {
    let scheduler = Scheduler::with_policies(exec, manifest, metrics, seed, controller, cascade);
    if composer.enabled {
        composed_refine_loop(&scheduler, refine_q, gate, stage_poll, draft_fallback, &composer);
        return;
    }
    loop {
        match refine_q.pop_timeout(stage_poll) {
            Some(job) => {
                let DraftedJob { drafted, responders } = job;
                let key = drafted.bundle.key.clone();
                let fallback = fallback_plan(&scheduler, &drafted, draft_fallback);
                deliver_or_degrade(
                    scheduler.refine_bundle(drafted),
                    fallback,
                    responders,
                    metrics,
                    &key,
                );
                metrics.inflight_bundles.dec();
                gate.release();
            }
            None => {
                if refine_q.is_closed() && refine_q.is_empty() {
                    break;
                }
            }
        }
    }
}

/// What the composed REFINE loop needs to deliver a finished bundle —
/// captured at admission (the fallback borrows the pre-refine draft).
struct RefineCtx {
    key: BundleKey,
    fallback: Option<FallbackPlan>,
    responders: Vec<Responder>,
}

/// The continuous cross-bundle batching loop: interleave queue ingest
/// with composed steps. While rows are in flight, ingest is a
/// non-blocking drain (new bundles join at the next step boundary
/// without stalling the ones mid-trajectory); idle, it blocks one poll
/// like the per-bundle loop so drain latency keeps the same bound.
fn composed_refine_loop(
    scheduler: &Scheduler<'_>,
    refine_q: &BoundedQueue<DraftedJob>,
    gate: &InflightGate,
    stage_poll: Duration,
    draft_fallback: bool,
    composer: &ComposerConfig,
) {
    let mut comp: ComposedRefiner<'_, '_, RefineCtx> =
        ComposedRefiner::new(scheduler, composer.max_rows);
    loop {
        let ready =
            if comp.has_work() { refine_q.drain() } else { refine_q.pop_many(stage_poll) };
        for job in ready {
            let DraftedJob { drafted, responders } = job;
            let key = drafted.bundle.key.clone();
            let fallback = fallback_plan(scheduler, &drafted, draft_fallback);
            comp.admit(RefineCtx { key, fallback, responders }, drafted);
        }
        comp.step();
        for (ctx, result) in comp.take_completed() {
            deliver_or_degrade(result, ctx.fallback, ctx.responders, scheduler.metrics, &ctx.key);
            scheduler.metrics.inflight_bundles.dec();
            gate.release();
        }
        if !comp.has_work() && refine_q.is_closed() && refine_q.is_empty() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{mock_manifest, request, GateCtl, TestExec};

    fn test_config() -> WsfmConfig {
        let mut c = WsfmConfig::default();
        c.batcher.max_batch = 4;
        c.batcher.max_wait_us = 500;
        c
    }

    #[test]
    fn end_to_end_generate() {
        let svc = Service::start(
            TestExec::drift(vec![1, 4, 8], 3, 4, 2),
            mock_manifest(&["cold"], &[1, 4, 8], 3, 4),
            test_config(),
        );
        let resp = svc.generate(request(0, 2)).unwrap();
        assert_eq!(resp.samples.len(), 2);
        assert_eq!(resp.nfe, 5); // 10 cold steps, t0=0.5
        assert!(resp.samples.iter().all(|s| s.iter().all(|&t| t == 2)));
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let svc = Service::start(
            TestExec::drift(vec![1, 4, 8], 2, 4, 2),
            mock_manifest(&["cold"], &[1, 4, 8], 2, 4),
            test_config(),
        );
        let mut rxs = Vec::new();
        for _ in 0..10 {
            rxs.push(svc.submit(request(0, 1)).unwrap());
        }
        let mut ok = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.samples.len(), 1);
            ok += 1;
        }
        assert_eq!(ok, 10);
        assert_eq!(svc.metrics.requests_completed.get(), 10);
        assert_eq!(svc.metrics.inflight_bundles.get(), 0);
        svc.shutdown();
    }

    #[test]
    fn invalid_request_gets_error() {
        let svc = Service::start(
            TestExec::drift(vec![1], 2, 4, 2),
            mock_manifest(&["cold"], &[1], 2, 4),
            test_config(),
        );
        let mut bad = request(0, 1);
        bad.t0 = 2.0;
        let rx = svc.submit(bad).unwrap();
        let result = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(result.is_err());
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue; requests park behind an artificial high max_wait so
        // the queue fills faster than the coordinator drains at deadline.
        let mut cfg = test_config();
        cfg.queue_capacity = 2;
        cfg.batcher.max_wait_us = 200_000;
        cfg.batcher.max_batch = 1000;
        let svc = Service::start(
            TestExec::drift(vec![1, 4], 2, 4, 2),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
            cfg,
        );
        assert!(svc.retry_after() >= Duration::from_millis(1));
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..50 {
            match svc.submit(request(0, 1)) {
                Ok(rx) => rxs.push(rx),
                Err(QueueFull) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected some backpressure rejections");
        // All admitted requests must still complete.
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn unknown_tag_fails_cleanly() {
        let svc = Service::start(
            TestExec::drift(vec![1], 2, 4, 2),
            mock_manifest(&["cold"], &[1], 2, 4),
            test_config(),
        );
        let mut r = request(0, 1);
        r.tag = "ws_t999".into();
        let rx = svc.submit(r).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        svc.shutdown();
    }

    #[test]
    fn deadline_flush_proceeds_while_bundle_refines() {
        // The headline property of the pipelined coordinator: a slow
        // refine must not block admission or deadline flushes. A gated
        // executor parks the first ("slow"-tagged) bundle inside REFINE;
        // a later request must still deadline-flush and complete DRAFT
        // while the gate is held.
        let gate = Arc::new(GateCtl::default());
        let mut exec = TestExec::drift(vec![1, 4, 8], 2, 4, 1);
        exec.gate = Some(gate.clone());
        let manifest = mock_manifest(&["cold", "slow"], &[1, 4, 8], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1000; // deadline flushes only
        cfg.batcher.max_wait_us = 10_000;
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 1;
        let svc = Service::start(exec, manifest, cfg);

        let mut slow = request(0, 1);
        slow.tag = "slow".into();
        let slow_rx = svc.submit(slow).unwrap();
        let t0 = Instant::now();
        while !gate.started.load(Ordering::SeqCst) {
            assert!(t0.elapsed() < Duration::from_secs(5), "slow bundle never reached REFINE");
            std::thread::sleep(Duration::from_millis(1));
        }

        let fast_rx = svc.submit(request(0, 1)).unwrap();
        let t1 = Instant::now();
        while svc.metrics.draft_calls.get() < 2 {
            assert!(
                t1.elapsed() < Duration::from_secs(5),
                "deadline flush blocked behind the slow refine"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        // The slow bundle still occupies REFINE; nothing delivered yet,
        // and both bundles are in flight.
        assert!(slow_rx.try_recv().is_err());
        assert!(fast_rx.try_recv().is_err());
        assert!(svc.metrics.inflight_bundles.get() >= 2);
        // Both were deadline flushes; their lag was recorded.
        assert!(svc.metrics.flush_lag.snapshot().count >= 2);

        gate.release.store(true, Ordering::SeqCst);
        slow_rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        fast_rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        svc.shutdown();
    }

    fn pipeline_outputs(depth: usize, workers: usize, mode: &str) -> Vec<(f64, Vec<Vec<i32>>)> {
        pipeline_outputs_cascade(depth, workers, mode, "off")
    }

    fn pipeline_outputs_cascade(
        depth: usize,
        workers: usize,
        mode: &str,
        cascade_mode: &str,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        pipeline_outputs_composer(depth, workers, mode, cascade_mode, false)
    }

    fn pipeline_outputs_composer(
        depth: usize,
        workers: usize,
        mode: &str,
        cascade_mode: &str,
        composed: bool,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        pipeline_outputs_full(depth, workers, mode, cascade_mode, composed, true)
    }

    fn pipeline_outputs_full(
        depth: usize,
        workers: usize,
        mode: &str,
        cascade_mode: &str,
        composed: bool,
        ledger: bool,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        // seq_len 16 keeps the different-seed inequality check below safe
        // from chance collisions (the drift keeps ~40% per-token overlap).
        let exec = TestExec::stochastic(vec![1, 4, 8], 16, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = WsfmConfig::default();
        // One bundle per request: bundle composition is timing-independent,
        // so only the RNG derivation could differ across configs.
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = depth;
        cfg.draft_workers = workers;
        cfg.seed = 99;
        cfg.control.mode = mode.into();
        cfg.cascade.mode = cascade_mode.into();
        cfg.composer.enabled = composed;
        cfg.obs.ledger.enabled = ledger;
        let svc = Service::start(exec, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 1000 + i;
            rxs.push(svc.submit(r).unwrap());
        }
        let out = rxs
            .into_iter()
            .map(|rx| {
                let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
                (resp.t0_used, resp.samples)
            })
            .collect();
        svc.shutdown();
        out
    }

    #[test]
    fn outputs_bitwise_identical_across_pipeline_settings() {
        // The RNG substream contract, end to end: tokens depend only on
        // (config.seed, bundle key, request seeds) — not on pipeline
        // depth, draft-worker count, or the serial (depth=1) path.
        let reference = pipeline_outputs(1, 1, "static");
        assert_eq!(reference, pipeline_outputs(2, 1, "static"));
        assert_eq!(reference, pipeline_outputs(4, 3, "static"));
        // And the executor is genuinely stochastic: same-shape requests
        // with different seeds produce different tokens.
        assert_ne!(reference[0].1, reference[3].1);
    }

    #[test]
    fn scored_controller_outputs_bitwise_identical_across_pipeline_settings() {
        // The controller extends the contract: the chosen t0 is a pure
        // function of (bundle contents, config), so scored-mode tokens
        // AND t0 choices are identical across pipeline_depth ∈ {1, 4}
        // and draft_workers ∈ {1, 2}.
        let reference = pipeline_outputs(1, 1, "scored");
        assert_eq!(reference, pipeline_outputs(4, 1, "scored"));
        assert_eq!(reference, pipeline_outputs(4, 2, "scored"));
        // Every adaptive choice respects the configured clamp range.
        let d = WsfmConfig::default().control;
        for (t0, _) in &reference {
            assert!((d.t0_min..=d.t0_max).contains(t0), "t0_used {t0} outside clamp");
        }
    }

    /// [`pipeline_outputs`] served through a mock-replica fleet: same
    /// requests, same seed, executor pool of `replicas` identical
    /// stochastic mocks behind the least-loaded router, REFINE stage
    /// running `refine_workers` threads.
    fn fleet_outputs(replicas: usize, refine_workers: usize) -> Vec<(f64, Vec<Vec<i32>>)> {
        fleet_outputs_cascade(replicas, refine_workers, 4, "off")
    }

    fn fleet_outputs_cascade(
        replicas: usize,
        refine_workers: usize,
        depth: usize,
        cascade_mode: &str,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        fleet_outputs_composer(replicas, refine_workers, depth, cascade_mode, false)
    }

    fn fleet_outputs_composer(
        replicas: usize,
        refine_workers: usize,
        depth: usize,
        cascade_mode: &str,
        composed: bool,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        use crate::fleet::FleetHandle;
        let execs: Vec<Arc<dyn Executor>> = (0..replicas)
            .map(|_| Arc::new(TestExec::stochastic(vec![1, 4, 8], 16, 5, 2)) as Arc<dyn Executor>)
            .collect();
        let fleet = FleetHandle::from_executors(execs);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = depth;
        cfg.draft_workers = 2;
        // (The replica count lives in the pre-built FleetHandle; the
        // service only reads fleet.refine_workers.)
        cfg.fleet.refine_workers = refine_workers;
        cfg.seed = 99;
        cfg.cascade.mode = cascade_mode.into();
        cfg.composer.enabled = composed;
        let svc = Service::start(fleet, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 1000 + i;
            rxs.push(svc.submit(r).unwrap());
        }
        let out = rxs
            .into_iter()
            .map(|rx| {
                let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
                (resp.t0_used, resp.samples)
            })
            .collect();
        svc.shutdown();
        out
    }

    #[test]
    fn outputs_bitwise_identical_across_fleet_settings() {
        // The fleet extends the determinism contract one more level:
        // which replica refines a bundle, and how many REFINE workers
        // race over the staged channel, can never change its tokens.
        // Reference is the serial (depth=1), fleet-less path.
        let reference = pipeline_outputs(1, 1, "static");
        for (replicas, refine_workers) in [(1, 1), (1, 2), (2, 1), (2, 2), (4, 1), (4, 2)] {
            assert_eq!(
                reference,
                fleet_outputs(replicas, refine_workers),
                "outputs diverged at replicas={replicas} refine_workers={refine_workers}"
            );
        }
    }

    #[test]
    fn split_cascade_outputs_bitwise_identical_across_fleet_settings() {
        // Acceptance pin (a) of the cascade: a run split into ladder
        // segments (`fixed` mode, default [0.75, 0.9] ladder) reproduces
        // the unsplit run's tokens exactly — swept across fleet replicas
        // {1, 4} × refine_workers {1, 2} × pipeline depth {1, 4}, so a
        // bundle hopping between replicas mid-cascade can never change
        // its output. Reference is the serial, fleet-less, cascade-off
        // path.
        let reference = pipeline_outputs(1, 1, "static");
        assert_eq!(
            reference,
            pipeline_outputs_cascade(1, 1, "static", "fixed"),
            "split diverged on the serial fleet-less path"
        );
        for depth in [1usize, 4] {
            for (replicas, refine_workers) in [(1, 1), (1, 2), (4, 1), (4, 2)] {
                assert_eq!(
                    reference,
                    fleet_outputs_cascade(replicas, refine_workers, depth, "fixed"),
                    "split diverged at replicas={replicas} refine_workers={refine_workers} depth={depth}"
                );
            }
        }
        // And cascade off through the same sweep is the PR 4 behaviour
        // verbatim (pin (b), service level).
        assert_eq!(reference, fleet_outputs_cascade(4, 2, 4, "off"));
        // Gated outputs differ from the unsplit run when a gate passes —
        // but they are still a pure function of (seed, bundle, config):
        // identical across the serial path and a 4-replica fleet.
        let gated = pipeline_outputs_cascade(1, 1, "static", "gated");
        assert_eq!(gated, fleet_outputs_cascade(4, 2, 4, "gated"));
    }

    #[test]
    fn composed_outputs_bitwise_identical_across_settings() {
        // The tentpole acceptance pin: the step-level batch composer is a
        // pure regrouping. Reference is the serial, fleet-less, composer-
        // off, cascade-off path; composer-on must reproduce it byte for
        // byte across the serial path, the pipelined path, and a fleet
        // sweep of replicas {1, 4} × refine_workers {1, 2} × pipeline
        // depth {1, 4} — cross-bundle sharing, mid-flight admission, and
        // row retirement can never change a single token.
        let reference = pipeline_outputs(1, 1, "static");
        assert_eq!(
            reference,
            pipeline_outputs_composer(1, 1, "static", "off", true),
            "composer diverged on the serial path"
        );
        assert_eq!(
            reference,
            pipeline_outputs_composer(4, 2, "static", "off", true),
            "composer diverged on the pipelined path"
        );
        // Composer × cascade: split segments compose across bundles too.
        assert_eq!(
            reference,
            pipeline_outputs_composer(4, 2, "static", "fixed", true),
            "composer diverged with a fixed cascade ladder"
        );
        for depth in [1usize, 4] {
            for (replicas, refine_workers) in [(1, 1), (1, 2), (4, 1), (4, 2)] {
                assert_eq!(
                    reference,
                    fleet_outputs_composer(replicas, refine_workers, depth, "fixed", true),
                    "composed outputs diverged at replicas={replicas} \
                     refine_workers={refine_workers} depth={depth}"
                );
            }
        }
        // Gated cascades take data-dependent exits; composed gated output
        // equals uncomposed gated output, serial and fleet alike.
        let gated = pipeline_outputs_cascade(1, 1, "static", "gated");
        assert_eq!(gated, pipeline_outputs_composer(1, 1, "static", "gated", true));
        assert_eq!(gated, fleet_outputs_composer(4, 2, 4, "gated", true));
    }

    #[test]
    fn decision_ledger_never_perturbs_outputs() {
        // Acceptance sweep: the decision ledger is pure observation.
        // Every sweep above already runs with the ledger on (the config
        // default); here ledger-off must reproduce ledger-on byte for
        // byte across composer on/off × cascade off|fixed|gated, on both
        // the serial and the pipelined path.
        for cascade in ["off", "fixed", "gated"] {
            let with_ledger = pipeline_outputs_full(1, 1, "static", cascade, false, true);
            for composed in [false, true] {
                assert_eq!(
                    with_ledger,
                    pipeline_outputs_full(1, 1, "static", cascade, composed, false),
                    "ledger toggle perturbed serial outputs (cascade={cascade} composed={composed})"
                );
                assert_eq!(
                    with_ledger,
                    pipeline_outputs_full(4, 2, "static", cascade, composed, false),
                    "ledger toggle perturbed pipelined outputs (cascade={cascade} composed={composed})"
                );
            }
        }
    }

    #[test]
    fn composed_serving_respects_the_nfe_guarantee() {
        // The paper's per-request guarantee survives composition: every
        // response refined through shared engine steps still reports
        // nfe <= guaranteed_nfe(steps_cold, t0) — sharing a step with
        // another bundle never bills extra denoiser calls to a request.
        use crate::core::schedule::guaranteed_nfe;
        let exec = TestExec::stochastic(vec![1, 4, 8], 16, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = test_config();
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 2;
        cfg.seed = 99;
        cfg.cascade.mode = "gated".into();
        cfg.composer.enabled = true;
        let svc = Service::start(exec, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 2000 + i;
            rxs.push(svc.submit(r).unwrap());
        }
        let bound = guaranteed_nfe(10, 0.5); // request(): steps_cold 10, t0 0.5
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert!(resp.degraded.is_none());
            assert!(resp.nfe > 0 && resp.nfe <= bound, "nfe {} > bound {bound}", resp.nfe);
            if let Some(c) = &resp.cascade {
                assert_eq!(c.nfe_per_stage.iter().sum::<usize>(), resp.nfe);
            }
        }
        // The composer's step-level telemetry flowed: rows-per-step
        // samples were recorded and occupancy was published.
        assert!(svc.metrics.rows_per_step.snapshot().count > 0);
        assert!(svc.metrics.batch_occupancy.get() > 0);
        svc.shutdown();
    }

    #[test]
    fn bundles_refine_concurrently_on_distinct_replicas() {
        // The fleet's headline property: with replicas=2 and
        // refine_workers=2, two bundles occupy REFINE *simultaneously* on
        // *different* replicas. Each mock replica gets its own gate; both
        // gates held open at once is the proof.
        use crate::fleet::FleetHandle;
        let g0 = Arc::new(GateCtl::default());
        let g1 = Arc::new(GateCtl::default());
        let mut e0 = TestExec::drift(vec![1, 4, 8], 2, 4, 1);
        e0.gate = Some(g0.clone());
        let mut e1 = TestExec::drift(vec![1, 4, 8], 2, 4, 1);
        e1.gate = Some(g1.clone());
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(e0) as Arc<dyn Executor>,
            Arc::new(e1) as Arc<dyn Executor>,
        ]);
        let probe = fleet.clone();
        let manifest = mock_manifest(&["cold", "slow"], &[1, 4, 8], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1; // size-flush each request into its own bundle
        cfg.batcher.max_wait_us = 1_000;
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 1;
        cfg.fleet.refine_workers = 2;
        let svc = Service::start(fleet, manifest, cfg);

        let mk = |seed: u64| {
            let mut r = request(seed, 1);
            r.tag = "slow".into();
            r
        };
        let rx_a = svc.submit(mk(1)).unwrap();
        let rx_b = svc.submit(mk(2)).unwrap();
        let t0 = Instant::now();
        while !(g0.started.load(Ordering::SeqCst) && g1.started.load(Ordering::SeqCst)) {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "two bundles never refined concurrently on distinct replicas"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        // Right now both replicas hold one in-flight run each, and both
        // bundles are still unfinished.
        assert_eq!(probe.metrics().replica_inflight[0].get(), 1);
        assert_eq!(probe.metrics().replica_inflight[1].get(), 1);
        assert!(svc.metrics.inflight_bundles.get() >= 2);
        assert!(rx_a.try_recv().is_err());
        assert!(rx_b.try_recv().is_err());

        g0.release.store(true, Ordering::SeqCst);
        g1.release.store(true, Ordering::SeqCst);
        rx_a.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        rx_b.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(probe.metrics().fleet_reroutes.get(), 0);
        svc.shutdown();
    }

    #[test]
    fn early_size_flush_counts_separately_from_lag() {
        // Regression (ISSUE 3): a bundle that flushes *before* its
        // deadline (size-triggered) used to clamp its negative lag into a
        // garbage 0 µs flush_lag sample. A gated executor parks the
        // bundle in REFINE so the metrics can be asserted race-free.
        let gate = Arc::new(GateCtl::default());
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.gate = Some(gate.clone());
        let manifest = mock_manifest(&["slow"], &[1, 4], 2, 4);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1; // size-flush every request immediately
        cfg.batcher.max_wait_us = 10_000_000; // deadline far in the future
        cfg.pipeline_depth = 2;
        let svc = Service::start(exec, manifest, cfg);

        let mut r = request(0, 1);
        r.tag = "slow".into();
        let rx = svc.submit(r).unwrap();
        let t0 = Instant::now();
        while !gate.started.load(Ordering::SeqCst) {
            assert!(t0.elapsed() < Duration::from_secs(5), "bundle never reached REFINE");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Dispatched well before its 10 s deadline: counted as early, the
        // lag histogram stays clean.
        assert_eq!(svc.metrics.early_flushes.get(), 1);
        assert_eq!(svc.metrics.flush_lag.snapshot().count, 0);
        let early = svc.metrics.flush_early.snapshot();
        assert_eq!(early.count, 1);
        assert!(early.max > Duration::from_secs(1), "headroom ~10 s, got {:?}", early.max);

        gate.release.store(true, Ordering::SeqCst);
        rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        svc.shutdown();
    }

    #[test]
    fn retry_after_tracks_occupancy() {
        // BUSY hints derive from live occupancy, not static config: while
        // a bundle is parked in REFINE the hint scales up; once the
        // pipeline drains it drops to the 1 ms floor.
        let gate = Arc::new(GateCtl::default());
        let mut exec = TestExec::drift(vec![1, 4], 2, 4, 1);
        exec.gate = Some(gate.clone());
        let manifest = mock_manifest(&["slow"], &[1, 4], 2, 4);
        let mut cfg = test_config();
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_wait_us = 2_000;
        cfg.pipeline_depth = 2;
        let svc = Service::start(exec, manifest, cfg);
        // Nothing in flight yet: drained hint.
        assert_eq!(svc.retry_after(), Duration::from_millis(1));

        let mut r = request(0, 1);
        r.tag = "slow".into();
        let rx = svc.submit(r).unwrap();
        let t0 = Instant::now();
        while !gate.started.load(Ordering::SeqCst) {
            assert!(t0.elapsed() < Duration::from_secs(5));
            std::thread::sleep(Duration::from_millis(1));
        }
        // One bundle occupied: at least one flush interval on top of the
        // floor.
        assert!(svc.retry_after() >= Duration::from_millis(3), "{:?}", svc.retry_after());

        gate.release.store(true, Ordering::SeqCst);
        rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        // Drained again (the gauge decrements on delivery).
        let t1 = Instant::now();
        while svc.retry_after() != Duration::from_millis(1) {
            assert!(t1.elapsed() < Duration::from_secs(5), "hint never drained");
            std::thread::sleep(Duration::from_millis(1));
        }
        svc.shutdown();
    }

    #[test]
    fn refine_failure_degrades_to_draft_tokens() {
        use crate::faults::{FaultPlan, FaultyExec};
        // Every RUN_LOOP call errors, so refinement can never succeed;
        // the DRAFT stage (noise drafts, no executor involvement) does —
        // the response is the drafted tokens, marked degraded.
        let plan =
            FaultPlan { seed: 1, p_panic: 0.0, p_wedge: 0.0, p_error: 1.0, wedge: Duration::ZERO };
        let inner = Arc::new(TestExec::drift(vec![1, 4], 2, 4, 2)) as Arc<dyn Executor>;
        let svc = Service::start(
            FaultyExec::new(inner, plan),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
            test_config(),
        );
        let resp = svc.generate(request(0, 2)).unwrap();
        assert_eq!(resp.samples.len(), 2);
        assert_eq!(resp.samples[0].len(), 2, "draft rows keep the artifact seq_len");
        assert_eq!(resp.nfe, 0, "no refinement was paid for");
        let reason = resp.degraded.clone().expect("response must be marked degraded");
        assert!(reason.contains("injected fault"), "{reason}");
        assert!(resp.cascade.is_none());
        assert_eq!(svc.metrics.degraded_responses.get(), 1);
        assert_eq!(svc.metrics.requests_completed.get(), 1);
        // The degraded bundle left a ledger record billing zero NFE —
        // exactly the shape the guarantee auditor accepts.
        let records = svc.metrics.obs.ledger.snapshot();
        assert_eq!(records.len(), 1);
        assert!(records[0].degraded);
        assert_eq!(records[0].nfe, 0);
        assert_eq!(records[0].requests.len(), 1);
        assert_ne!(records[0].requests[0].out_hash, 0, "fallback drafts are still hashed");
        assert_eq!(svc.metrics.obs.ledger.violations(), 0);
        svc.shutdown();
    }

    #[test]
    fn draft_fallback_disabled_surfaces_the_refine_error() {
        use crate::faults::{FaultPlan, FaultyExec};
        let plan =
            FaultPlan { seed: 1, p_panic: 0.0, p_wedge: 0.0, p_error: 1.0, wedge: Duration::ZERO };
        let inner = Arc::new(TestExec::drift(vec![1, 4], 2, 4, 2)) as Arc<dyn Executor>;
        let mut cfg = test_config();
        cfg.robustness.draft_fallback = false;
        let svc = Service::start(
            FaultyExec::new(inner, plan),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
            cfg,
        );
        let err = svc.generate(request(0, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert_eq!(svc.metrics.degraded_responses.get(), 0);
        svc.shutdown();
    }

    /// The chaos workload of [`pipeline_outputs_cascade`] served through
    /// a resurrectable 4-replica fleet of fault-injected stochastic
    /// mocks (watchdog 2 ms, so the plan's 5 ms wedges trip the typed
    /// EngineTimeout path).
    fn chaos_run(
        plan: crate::faults::FaultPlan,
        rb: &crate::config::RobustnessConfig,
    ) -> Vec<Result<GenResponse, String>> {
        chaos_run_composer(plan, rb, false)
    }

    fn chaos_run_composer(
        plan: crate::faults::FaultPlan,
        rb: &crate::config::RobustnessConfig,
        composed: bool,
    ) -> Vec<Result<GenResponse, String>> {
        use crate::faults::FaultyExec;
        use crate::fleet::{FleetHandle, ReplicaFactory};
        let factories: Vec<ReplicaFactory> = (0..4)
            .map(|_| {
                let plan = plan.clone();
                Box::new(move || {
                    let inner = Arc::new(TestExec::stochastic(vec![1, 4, 8], 16, 5, 2))
                        as Arc<dyn Executor>;
                    let faulty = FaultyExec::new(inner, plan.clone())
                        .with_watchdog(Duration::from_millis(2));
                    Ok(Arc::new(faulty) as Arc<dyn Executor>)
                }) as ReplicaFactory
            })
            .collect();
        let fleet = FleetHandle::from_factories(factories, rb).unwrap();
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 2;
        cfg.fleet.refine_workers = 2;
        cfg.seed = 99;
        cfg.cascade.mode = "gated".into();
        cfg.robustness = rb.clone();
        cfg.composer.enabled = composed;
        let svc = Service::start(fleet, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 1000 + i;
            rxs.push(svc.submit(r).unwrap());
        }
        let out: Vec<Result<GenResponse, String>> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(10)).expect("chaos hung a response"))
            .collect();
        // The decision ledger audited every appended bundle in-line:
        // zero guarantee violations under every fault seed is the CI
        // chaos-matrix assertion (ledger on by default in this config).
        let resolved = out.iter().filter(|r| r.is_ok()).count();
        if resolved > 0 {
            assert!(svc.metrics.obs.ledger.appended() > 0, "responses without ledger records");
        }
        assert_eq!(
            svc.metrics.obs.ledger.violations(),
            0,
            "guarantee auditor flagged a violation under chaos"
        );
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.guarantee_violations, 0);
        assert_eq!(snap.ledger_records, svc.metrics.obs.ledger.appended());
        svc.shutdown();
        out
    }

    #[test]
    fn chaos_seeded_faults_never_hang_and_preserve_the_bitwise_contract() {
        use crate::config::RobustnessConfig;
        use crate::faults::FaultPlan;
        // The tentpole integration pin: deterministic chaos over the full
        // pipeline (depth 4, four fault-injected replicas, two refine
        // workers, gated cascade). Every admitted request resolves as ok,
        // degraded, or a typed error — no hangs, no lost envelopes — and
        // any response that *did* refine is bitwise-identical to the
        // fault-free run. Seeds come from WSFM_FAULT_SEED (the CI
        // chaos-smoke matrix) or a fixed default.
        let rb = RobustnessConfig {
            stage_poll_ms: 10,
            respawn_backoff_ms: 1,
            respawn_backoff_cap_ms: 5,
            max_respawns: 1000,
            ..RobustnessConfig::default()
        };
        let expected = pipeline_outputs_cascade(1, 1, "static", "gated");
        // Fault-free through the whole chaos harness (FaultyExec wrappers,
        // factory fleet, health loop armed) is the serial fleet-less gated
        // path, byte for byte — the wrappers are invisible when quiet.
        let reference = chaos_run(FaultPlan::none(0), &rb);
        assert_eq!(reference.len(), expected.len());
        for (got, want) in reference.iter().zip(&expected) {
            let resp = got.as_ref().expect("fault-free run must not error");
            assert!(resp.degraded.is_none(), "fault-free run must not degrade");
            assert_eq!((resp.t0_used, resp.samples.clone()), *want);
        }
        let seeds: Vec<u64> = match std::env::var("WSFM_FAULT_SEED") {
            Ok(s) => vec![s.trim().parse().expect("WSFM_FAULT_SEED must be a u64")],
            Err(_) => vec![7, 21],
        };
        for seed in seeds {
            let out = chaos_run(FaultPlan::chaos(seed), &rb);
            assert_eq!(out.len(), expected.len(), "lost envelopes under chaos (seed {seed})");
            let (mut ok, mut degraded, mut errors) = (0usize, 0usize, 0usize);
            for (got, want) in out.iter().zip(&expected) {
                match got {
                    Ok(resp) if resp.degraded.is_some() => {
                        degraded += 1;
                        assert_eq!(resp.nfe, 0, "degraded response claims refine NFE");
                    }
                    Ok(resp) => {
                        ok += 1;
                        assert_eq!(
                            (resp.t0_used, resp.samples.clone()),
                            *want,
                            "refined-under-chaos output diverged (seed {seed})"
                        );
                    }
                    Err(msg) => {
                        errors += 1;
                        assert!(!msg.is_empty());
                    }
                }
            }
            assert_eq!(ok + degraded + errors, expected.len());
        }
    }

    #[test]
    fn chaos_with_composer_preserves_the_bitwise_contract() {
        use crate::config::RobustnessConfig;
        use crate::faults::FaultPlan;
        // Satellite: the chaos harness re-run with the step-level batch
        // composer driving REFINE. A dispatch fault now hits a *composed*
        // step shared by several bundles — the composer fails the whole
        // cohort over to the per-bundle path, which re-runs each bundle
        // deterministically, so refined outputs stay bitwise-identical
        // and every envelope still resolves ok/degraded/error.
        let rb = RobustnessConfig {
            stage_poll_ms: 10,
            respawn_backoff_ms: 1,
            respawn_backoff_cap_ms: 5,
            max_respawns: 1000,
            ..RobustnessConfig::default()
        };
        let expected = pipeline_outputs_cascade(1, 1, "static", "gated");
        // Fault-free composed chaos is the serial uncomposed gated path,
        // byte for byte.
        let reference = chaos_run_composer(FaultPlan::none(0), &rb, true);
        assert_eq!(reference.len(), expected.len());
        for (got, want) in reference.iter().zip(&expected) {
            let resp = got.as_ref().expect("fault-free composed run must not error");
            assert!(resp.degraded.is_none(), "fault-free composed run must not degrade");
            assert_eq!((resp.t0_used, resp.samples.clone()), *want);
        }
        for seed in [7u64, 21] {
            let out = chaos_run_composer(FaultPlan::chaos(seed), &rb, true);
            assert_eq!(out.len(), expected.len(), "lost envelopes under composed chaos");
            let (mut ok, mut degraded, mut errors) = (0usize, 0usize, 0usize);
            for (got, want) in out.iter().zip(&expected) {
                match got {
                    Ok(resp) if resp.degraded.is_some() => {
                        degraded += 1;
                        assert_eq!(resp.nfe, 0, "degraded response claims refine NFE");
                    }
                    Ok(resp) => {
                        ok += 1;
                        assert_eq!(
                            (resp.t0_used, resp.samples.clone()),
                            *want,
                            "composed refined-under-chaos output diverged (seed {seed})"
                        );
                    }
                    Err(msg) => {
                        errors += 1;
                        assert!(!msg.is_empty());
                    }
                }
            }
            assert_eq!(ok + degraded + errors, expected.len());
        }
    }

    #[test]
    fn shutdown_drains_within_a_small_multiple_of_stage_poll() {
        // Satellite: the stage channel polls come from
        // robustness.stage_poll_ms. A bundle parked behind a 10 s batcher
        // deadline must still flush and complete within a small multiple
        // of the poll once shutdown lands (admission notices the close,
        // flushes, and the two stages each add at most one poll).
        let mut cfg = test_config();
        cfg.batcher.max_batch = 1000;
        cfg.batcher.max_wait_us = 10_000_000;
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 1;
        cfg.robustness.stage_poll_ms = 20;
        let svc = Service::start(
            TestExec::drift(vec![1, 4], 2, 4, 1),
            mock_manifest(&["cold"], &[1, 4], 2, 4),
            cfg,
        );
        let rx = svc.submit(request(0, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // parked in the batcher
        assert!(rx.try_recv().is_err(), "bundle flushed before its 10 s deadline");
        let t = Instant::now();
        svc.shutdown();
        rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let drained = t.elapsed();
        assert!(drained < Duration::from_millis(200), "drain took {drained:?}, want < 10 polls");
    }

    #[test]
    fn shutdown_under_load_completes_or_cleanly_rejects() {
        // Submissions racing Service::shutdown either complete or get a
        // clean error — no hung receivers, no lost envelopes.
        let mut exec = TestExec::drift(vec![1, 4, 8], 2, 4, 1);
        exec.step_sleep = Duration::from_micros(200);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 2, 4);
        let mut cfg = test_config();
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 2;
        let svc = Service::start(exec, manifest, cfg);

        let submitters: Vec<_> = (0..4)
            .map(|_| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    (0..25).map(|_| svc.submit(request(0, 1)).ok()).collect::<Vec<_>>()
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(3));
        svc.shutdown();

        let (mut completed, mut errored, mut rejected) = (0u64, 0u64, 0u64);
        for h in submitters {
            for r in h.join().unwrap() {
                match r {
                    Some(rx) => match rx.recv_timeout(Duration::from_secs(10)) {
                        Ok(Ok(_)) => completed += 1,
                        Ok(Err(_)) => errored += 1,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            panic!("envelope dropped without a response")
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => panic!("hung receiver"),
                    },
                    None => rejected += 1,
                }
            }
        }
        assert_eq!(completed + errored + rejected, 100);
        assert!(completed > 0, "some submissions must have completed");
        assert_eq!(svc.metrics.requests_completed.get(), completed);
        svc.shutdown(); // idempotent
    }

    /// [`fleet_outputs_composer`] with every request asking for the
    /// opt-in timing breakdown and the observability journals toggled —
    /// the "observation never perturbs outputs" sweep. Also asserts the
    /// breakdown's internal invariants on every response.
    fn observed_outputs(
        timing: bool,
        obs_enabled: bool,
        replicas: usize,
        refine_workers: usize,
        depth: usize,
        composed: bool,
    ) -> Vec<(f64, Vec<Vec<i32>>)> {
        use crate::fleet::FleetHandle;
        let execs: Vec<Arc<dyn Executor>> = (0..replicas)
            .map(|_| Arc::new(TestExec::stochastic(vec![1, 4, 8], 16, 5, 2)) as Arc<dyn Executor>)
            .collect();
        let fleet = FleetHandle::from_executors(execs);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = depth;
        cfg.draft_workers = 2;
        cfg.fleet.refine_workers = refine_workers;
        cfg.seed = 99;
        cfg.cascade.mode = "gated".into();
        cfg.composer.enabled = composed;
        cfg.obs.enabled = obs_enabled;
        let svc = Service::start(fleet, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 1000 + i;
            r.timing = timing;
            rxs.push(svc.submit(r).unwrap());
        }
        let out = rxs
            .into_iter()
            .map(|rx| {
                let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
                if timing {
                    let ti = resp.timing.as_ref().expect("timing requested but absent");
                    assert!(ti.nfe_floor >= resp.nfe, "NFE above the reported floor");
                    assert_eq!(
                        ti.segments.iter().map(|(n, _)| *n).sum::<usize>(),
                        resp.nfe,
                        "segment NFE must sum to the reported NFE"
                    );
                } else {
                    assert!(resp.timing.is_none(), "timing must be strictly opt-in");
                }
                (resp.t0_used, resp.samples)
            })
            .collect();
        svc.shutdown();
        out
    }

    #[test]
    fn timing_and_observability_never_perturb_outputs() {
        // Acceptance sweep: the opt-in timing breakdown and the obs
        // journals are pure observation. Reference is the serial,
        // fleet-less, untraced gated path; tracing on across fleet
        // replicas {1, 4} × refine_workers {1, 2} × pipeline depth
        // {1, 4} × composer on/off reproduces it byte for byte.
        let reference = pipeline_outputs_cascade(1, 1, "static", "gated");
        for composed in [false, true] {
            for depth in [1usize, 4] {
                for (replicas, refine_workers) in [(1, 1), (1, 2), (4, 1), (4, 2)] {
                    assert_eq!(
                        reference,
                        observed_outputs(true, true, replicas, refine_workers, depth, composed),
                        "timing=true perturbed outputs at replicas={replicas} \
                         refine_workers={refine_workers} depth={depth} composed={composed}"
                    );
                }
            }
        }
        // Journals disabled: same bytes again (and the breakdown still
        // works — it derives from the refine trail, not the journal).
        assert_eq!(reference, observed_outputs(true, false, 4, 2, 4, true));
        assert_eq!(reference, observed_outputs(false, false, 1, 1, 1, false));
    }

    #[test]
    fn span_journal_joins_a_request_to_its_bundle_spans() {
        let svc = Service::start(
            TestExec::drift(vec![1, 4, 8], 3, 4, 2),
            mock_manifest(&["cold"], &[1, 4, 8], 3, 4),
            test_config(),
        );
        let mut r = request(0, 2);
        r.timing = true;
        let resp = svc.generate(r).unwrap();
        let spans = svc.metrics.obs.spans.for_request(resp.id);
        let kind_count = |k: SpanKind| spans.iter().filter(|s| s.kind == k).count();
        assert_eq!(kind_count(SpanKind::Admit), 1);
        assert_eq!(kind_count(SpanKind::BatcherWait), 1);
        assert!(kind_count(SpanKind::Draft) >= 1, "bundle draft span must join via bundle id");
        assert!(kind_count(SpanKind::RefineSegment) >= 1);
        let ti = resp.timing.expect("timing requested");
        assert_eq!(ti.nfe_floor, 5); // guaranteed_nfe(10, 0.5)
        assert_eq!(ti.segments.iter().map(|(n, _)| *n).sum::<usize>(), resp.nfe);
        // An unknown request id joins nothing (the wire layer turns this
        // into a typed error).
        assert!(svc.metrics.obs.spans.for_request(9_999_999).is_empty());
        svc.shutdown();
    }

    /// [`chaos_run`] with tracing on and the fleet's event journal
    /// attached: returns the outcomes, the journal, and a fleet probe.
    fn chaos_run_observed(
        plan: crate::faults::FaultPlan,
        rb: &crate::config::RobustnessConfig,
    ) -> (Vec<Result<GenResponse, String>>, Arc<Obs>, crate::fleet::FleetHandle) {
        use crate::faults::FaultyExec;
        use crate::fleet::{FleetHandle, ReplicaFactory};
        let factories: Vec<ReplicaFactory> = (0..4)
            .map(|_| {
                let plan = plan.clone();
                Box::new(move || {
                    let inner = Arc::new(TestExec::stochastic(vec![1, 4, 8], 16, 5, 2))
                        as Arc<dyn Executor>;
                    let faulty = FaultyExec::new(inner, plan.clone())
                        .with_watchdog(Duration::from_millis(2));
                    Ok(Arc::new(faulty) as Arc<dyn Executor>)
                }) as ReplicaFactory
            })
            .collect();
        let fleet = FleetHandle::from_factories(factories, rb).unwrap();
        let obs = Arc::new(Obs::default());
        fleet.attach_obs(obs.clone());
        let probe = fleet.clone();
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 16, 5);
        let mut cfg = WsfmConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.pipeline_depth = 4;
        cfg.draft_workers = 2;
        cfg.fleet.refine_workers = 2;
        cfg.seed = 99;
        cfg.cascade.mode = "gated".into();
        cfg.robustness = rb.clone();
        let svc = Service::start(fleet, manifest, cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut r = request(0, (i as usize % 3) + 1);
            r.seed = 1000 + i;
            r.timing = true;
            rxs.push(svc.submit(r).unwrap());
        }
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(10)).expect("chaos hung a response"))
            .collect();
        svc.shutdown();
        (out, obs, probe)
    }

    #[test]
    fn chaos_event_journal_mirrors_fleet_counters() {
        use crate::config::RobustnessConfig;
        use crate::faults::FaultPlan;
        // Satellite: the chaos run re-run with tracing on. Every fleet
        // fault-handling counter increment leaves a matching typed event
        // in the journal, and anything that refined is still
        // bitwise-identical to the fault-free reference — tracing a
        // failing fleet perturbs nothing.
        let rb = RobustnessConfig {
            stage_poll_ms: 10,
            respawn_backoff_ms: 1,
            respawn_backoff_cap_ms: 5,
            max_respawns: 1000,
            ..RobustnessConfig::default()
        };
        let expected = pipeline_outputs_cascade(1, 1, "static", "gated");
        for seed in [7u64, 21] {
            let (out, obs, probe) = chaos_run_observed(FaultPlan::chaos(seed), &rb);
            assert_eq!(out.len(), expected.len(), "lost envelopes (seed {seed})");
            for (got, want) in out.iter().zip(&expected) {
                if let Ok(resp) = got {
                    if resp.degraded.is_none() {
                        assert_eq!(
                            (resp.t0_used, resp.samples.clone()),
                            *want,
                            "traced chaos output diverged (seed {seed})"
                        );
                    }
                }
            }
            // Counter/journal agreement, allowing the async health loop a
            // moment to finish whichever transition it was mid-way
            // through when the last response landed.
            let count = |k: EventKind| obs.events.of_kind(k).len() as u64;
            let settled = Instant::now() + Duration::from_secs(2);
            loop {
                let fm = probe.metrics();
                let ok = count(EventKind::Quarantine) == fm.replica_unhealthy.get()
                    && count(EventKind::Reroute) == fm.fleet_reroutes.get()
                    && count(EventKind::Respawn) == fm.replica_respawns.get()
                    && count(EventKind::RespawnFailed) == fm.respawn_failures.get()
                    && count(EventKind::EngineTimeout) == fm.engine_timeouts.get();
                if ok {
                    break;
                }
                if Instant::now() > settled {
                    assert_eq!(count(EventKind::Quarantine), fm.replica_unhealthy.get());
                    assert_eq!(count(EventKind::Reroute), fm.fleet_reroutes.get());
                    assert_eq!(count(EventKind::Respawn), fm.replica_respawns.get());
                    assert_eq!(count(EventKind::RespawnFailed), fm.respawn_failures.get());
                    assert_eq!(count(EventKind::EngineTimeout), fm.engine_timeouts.get());
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            let fm = probe.metrics();
            assert!(
                fm.replica_unhealthy.get() > 0 || fm.engine_timeouts.get() > 0,
                "chaos seed {seed} exercised no fault path"
            );
        }
    }
}
