//! The serving loop: wires admission queue → batcher → scheduler → response
//! channels, on a dedicated coordinator thread.
//!
//! One coordinator thread is the right shape here: the engine serializes on
//! the single CPU PJRT stream, so extra schedulers would only contend. The
//! thread blocks on the queue with a deadline derived from the batcher's
//! earliest pending flush, so idle service costs no CPU.

use crate::config::WsfmConfig;
use crate::coordinator::batcher::{Batcher, FlushPolicy};
use crate::coordinator::queue::{BoundedQueue, QueueFull};
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::coordinator::scheduler::Scheduler;
use crate::core::rng::Pcg64;
use crate::metrics::ServingMetrics;
use crate::runtime::engine::Executor;
use crate::runtime::Manifest;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// A submitted request waiting for its response.
struct Envelope {
    request: GenRequest,
    resp: mpsc::Sender<Result<GenResponse, String>>,
}

/// Handle for submitting work; cloneable across server connections.
#[derive(Clone)]
pub struct Service {
    queue: Arc<BoundedQueue<Envelope>>,
    pub metrics: Arc<ServingMetrics>,
    next_id: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl Service {
    /// Start the coordinator thread over an executor + manifest.
    pub fn start<E: Executor + 'static>(exec: E, manifest: Manifest, config: WsfmConfig) -> Service {
        let queue = Arc::new(BoundedQueue::<Envelope>::new(config.queue_capacity));
        let metrics = Arc::new(ServingMetrics::default());
        let running = Arc::new(AtomicBool::new(true));

        let q = queue.clone();
        let m = metrics.clone();
        let r = running.clone();
        std::thread::Builder::new()
            .name("wsfm-coordinator".into())
            .spawn(move || {
                coordinator_loop(exec, manifest, config, q, m, r);
            })
            .expect("spawning coordinator thread");

        Service { queue, metrics, next_id: Arc::new(AtomicU64::new(1)), running }
    }

    /// Submit a request; returns a receiver for the response.
    ///
    /// `Err(QueueFull)` is backpressure — the caller should surface "busy".
    pub fn submit(
        &self,
        mut request: GenRequest,
    ) -> Result<mpsc::Receiver<Result<GenResponse, String>>, QueueFull> {
        request.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        request.submitted = Instant::now();
        let (tx, rx) = mpsc::channel();
        self.queue.push(Envelope { request, resp: tx }).map_err(|_| {
            self.metrics.requests_rejected.inc();
            QueueFull
        })?;
        self.metrics.requests_admitted.inc();
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn generate(&self, request: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(request).map_err(|e| anyhow::anyhow!("{e}"))?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => anyhow::bail!("generation failed: {msg}"),
            Err(_) => anyhow::bail!("coordinator dropped the request"),
        }
    }

    /// Graceful shutdown: stop accepting, drain, stop the thread.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }
}

fn coordinator_loop<E: Executor>(
    exec: E,
    manifest: Manifest,
    config: WsfmConfig,
    queue: Arc<BoundedQueue<Envelope>>,
    metrics: Arc<ServingMetrics>,
    running: Arc<AtomicBool>,
) {
    let policy = FlushPolicy {
        max_batch: config.batcher.max_batch,
        max_wait: Duration::from_micros(config.batcher.max_wait_us),
    };
    let mut batcher = Batcher::new(policy);
    // Envelopes are held out-of-band, keyed by request id, so the batcher
    // itself stays a pure GenRequest structure.
    let mut envelopes: std::collections::HashMap<u64, mpsc::Sender<Result<GenResponse, String>>> =
        std::collections::HashMap::new();
    let mut rng = Pcg64::new(config.seed);
    let scheduler = Scheduler::new(&exec, &manifest, &metrics);

    let run_bundles = |bundles: Vec<crate::coordinator::batcher::WorkBundle>,
                           envelopes: &mut std::collections::HashMap<u64, mpsc::Sender<Result<GenResponse, String>>>,
                           rng: &mut Pcg64| {
        for bundle in bundles {
            match scheduler.run_bundle(&bundle, rng) {
                Ok(responses) => {
                    for resp in responses {
                        metrics.queue_wait.record(resp.queue_wait);
                        metrics.request_latency.record(resp.queue_wait + resp.total_time);
                        if let Some(tx) = envelopes.remove(&resp.id) {
                            let _ = tx.send(Ok(resp));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    crate::error!("bundle {}/{} failed: {msg}", bundle.key.domain, bundle.key.tag);
                    for req in &bundle.requests {
                        if let Some(tx) = envelopes.remove(&req.id) {
                            let _ = tx.send(Err(msg.clone()));
                        }
                    }
                }
            }
        }
    };

    loop {
        // Sleep until the next flush deadline (or a short max when idle).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match queue.pop_timeout(timeout.min(Duration::from_millis(50))) {
            Some(env) => {
                if let Err(e) = env.request.validate() {
                    let _ = env.resp.send(Err(format!("invalid request: {e:#}")));
                    continue;
                }
                envelopes.insert(env.request.id, env.resp);
                if let Some(bundle) = batcher.offer(env.request) {
                    run_bundles(vec![bundle], &mut envelopes, &mut rng);
                }
            }
            None => {
                if !running.load(Ordering::SeqCst) && queue.is_empty() {
                    // Drain remaining bundles, then exit.
                    let rest = batcher.flush_all();
                    run_bundles(rest, &mut envelopes, &mut rng);
                    break;
                }
            }
        }
        let due = batcher.due(Instant::now());
        if !due.is_empty() {
            run_bundles(due, &mut envelopes, &mut rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::DraftSpec;
    use crate::core::schedule::WarpMode;
    use crate::runtime::artifact::{ArtifactMeta, TensorSpec};
    use crate::util::json::Json;
    use anyhow::Context;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    struct DriftExec {
        batches: Vec<usize>,
        seq_len: usize,
        vocab: usize,
    }

    impl Executor for DriftExec {
        fn step(&self, _a: &str, tokens: &[i32], _t: f32, _h: f32, _w: f32) -> Result<Vec<f32>> {
            let mut out = vec![0.0f32; tokens.len() * self.vocab];
            for i in 0..tokens.len() {
                out[i * self.vocab + 2] = 1.0;
            }
            Ok(out)
        }
        fn draft(&self, _a: &str, _n: &[f32]) -> Result<Vec<i32>> {
            anyhow::bail!("no drafts")
        }
        fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
            let b: usize = artifact.rsplit('b').next().context("bad")?.parse()?;
            if !self.batches.contains(&b) {
                anyhow::bail!("unknown batch");
            }
            Ok(ArtifactMeta {
                name: artifact.to_string(),
                hlo_file: String::new(),
                domain: "mock".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: b,
                seq_len: self.seq_len,
                vocab: self.vocab,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![],
                outputs: vec![TensorSpec {
                    name: "probs".into(),
                    shape: vec![b, self.seq_len, self.vocab],
                    dtype: "f32".into(),
                }],
            })
        }
    }

    fn manifest(batches: &[usize], seq_len: usize, vocab: usize) -> Manifest {
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: batches
                .iter()
                .map(|&b| ArtifactMeta {
                    name: format!("mock_cold_step_b{b}"),
                    hlo_file: String::new(),
                    domain: "mock".into(),
                    kind: "step".into(),
                    tag: "cold".into(),
                    draft: None,
                    batch: b,
                    seq_len,
                    vocab,
                    t0: Some(0.0),
                    latent_dim: None,
                    inputs: vec![],
                    outputs: vec![],
                })
                .collect(),
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
        }
    }

    fn test_config() -> WsfmConfig {
        let mut c = WsfmConfig::default();
        c.batcher.max_batch = 4;
        c.batcher.max_wait_us = 500;
        c
    }

    fn request(n: usize) -> GenRequest {
        GenRequest {
            id: 0,
            domain: "mock".into(),
            tag: "cold".into(),
            draft: DraftSpec::Noise,
            n_samples: n,
            t0: 0.5,
            steps_cold: 8,
            warp_mode: WarpMode::Exact,
            seed: 1,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn end_to_end_generate() {
        let svc = Service::start(
            DriftExec { batches: vec![1, 4, 8], seq_len: 3, vocab: 4 },
            manifest(&[1, 4, 8], 3, 4),
            test_config(),
        );
        let resp = svc.generate(request(2)).unwrap();
        assert_eq!(resp.samples.len(), 2);
        assert_eq!(resp.nfe, 4); // 8 cold steps, t0=0.5
        assert!(resp.samples.iter().all(|s| s.iter().all(|&t| t == 2)));
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let svc = Service::start(
            DriftExec { batches: vec![1, 4, 8], seq_len: 2, vocab: 4 },
            manifest(&[1, 4, 8], 2, 4),
            test_config(),
        );
        let mut rxs = Vec::new();
        for _ in 0..10 {
            rxs.push(svc.submit(request(1)).unwrap());
        }
        let mut ok = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.samples.len(), 1);
            ok += 1;
        }
        assert_eq!(ok, 10);
        assert_eq!(svc.metrics.requests_completed.get(), 10);
        svc.shutdown();
    }

    #[test]
    fn invalid_request_gets_error() {
        let svc = Service::start(
            DriftExec { batches: vec![1], seq_len: 2, vocab: 4 },
            manifest(&[1], 2, 4),
            test_config(),
        );
        let mut bad = request(1);
        bad.t0 = 2.0;
        let rx = svc.submit(bad).unwrap();
        let result = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(result.is_err());
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue; requests park behind an artificial high max_wait so
        // the queue fills faster than the coordinator drains at deadline.
        let mut cfg = test_config();
        cfg.queue_capacity = 2;
        cfg.batcher.max_wait_us = 200_000;
        cfg.batcher.max_batch = 1000;
        let svc = Service::start(
            DriftExec { batches: vec![1, 4], seq_len: 2, vocab: 4 },
            manifest(&[1, 4], 2, 4),
            cfg,
        );
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..50 {
            match svc.submit(request(1)) {
                Ok(rx) => rxs.push(rx),
                Err(QueueFull) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected some backpressure rejections");
        // All admitted requests must still complete.
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn unknown_tag_fails_cleanly() {
        let svc = Service::start(
            DriftExec { batches: vec![1], seq_len: 2, vocab: 4 },
            manifest(&[1], 2, 4),
            test_config(),
        );
        let mut r = request(1);
        r.tag = "ws_t999".into();
        let rx = svc.submit(r).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_err());
        svc.shutdown();
    }
}
