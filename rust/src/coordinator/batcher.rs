//! Dynamic batcher: groups compatible requests (same [`BundleKey`]) and
//! flushes a bundle when it has enough samples or its oldest request has
//! waited past the deadline — the standard continuous-batching trade
//! between throughput (bigger batches) and tail latency (deadlines).
//!
//! Pure data structure (no threads): the service loop feeds `offer()` and
//! polls `due()`. Property tests pin conservation (no request lost or
//! duplicated) and FIFO within a bundle.

use crate::coordinator::request::{BundleKey, GenRequest};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Flush tuning (from [`crate::config::BatcherConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct FlushPolicy {
    /// Flush when a bundle has at least this many samples pending.
    pub max_batch: usize,
    /// Flush when the oldest request in a bundle has waited this long.
    pub max_wait: Duration,
}

/// A flushed group ready for the scheduler.
#[derive(Debug)]
pub struct WorkBundle {
    pub key: BundleKey,
    pub requests: Vec<GenRequest>,
    /// The flush deadline of the bundle's oldest request
    /// (`oldest + max_wait`); `None` only for shutdown (`flush_all`)
    /// flushes, which have no deadline semantics. Deadline-driven flushes
    /// (`due()`) dispatch at or after it and the service records the slip
    /// as `flush_lag`; size-triggered flushes dispatch *before* it and
    /// count as `early_flushes` instead — a negative lag must never be
    /// clamped into the lag histogram.
    pub deadline: Option<Instant>,
    /// Observability identity ([`crate::obs`]): minted by the service at
    /// dispatch (`Obs::next_bundle_id`), 0 when untraced. Joins a
    /// request's spans to its bundle's spans in `{"cmd":"trace"}`
    /// replies. Never feeds RNG, batching, or scheduling — ids must not
    /// perturb outputs.
    pub bundle_id: u64,
}

impl WorkBundle {
    pub fn new(key: BundleKey, requests: Vec<GenRequest>) -> WorkBundle {
        WorkBundle { key, requests, deadline: None, bundle_id: 0 }
    }

    pub fn total_samples(&self) -> usize {
        self.requests.iter().map(|r| r.n_samples).sum()
    }
}

#[derive(Debug)]
struct PendingBundle {
    requests: Vec<GenRequest>,
    samples: usize,
    oldest: Instant,
}

/// The batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: FlushPolicy,
    pending: HashMap<BundleKey, PendingBundle>,
}

impl Batcher {
    pub fn new(policy: FlushPolicy) -> Self {
        Batcher { policy, pending: HashMap::new() }
    }

    /// Add a request. Returns a bundle if the addition makes one flushable
    /// by size; such bundles carry the would-be deadline they beat, so
    /// the service can tell an early (size-triggered) dispatch from a
    /// late (deadline-slipped) one.
    pub fn offer(&mut self, req: GenRequest) -> Option<WorkBundle> {
        let key = req.bundle_key();
        let entry = self.pending.entry(key.clone()).or_insert_with(|| PendingBundle {
            requests: Vec::new(),
            samples: 0,
            oldest: req.submitted,
        });
        if entry.requests.is_empty() {
            entry.oldest = req.submitted;
        }
        entry.samples += req.n_samples;
        entry.requests.push(req);
        if entry.samples >= self.policy.max_batch {
            let deadline = entry.oldest + self.policy.max_wait;
            return self.take(&key).map(|mut bundle| {
                bundle.deadline = Some(deadline);
                bundle
            });
        }
        None
    }

    /// Bundles whose deadline has passed (call periodically). Each bundle
    /// carries the deadline that fired so callers can measure flush lag.
    pub fn due(&mut self, now: Instant) -> Vec<WorkBundle> {
        let keys: Vec<(BundleKey, Instant)> = self
            .pending
            .iter()
            .filter(|(_, b)| {
                !b.requests.is_empty() && now.duration_since(b.oldest) >= self.policy.max_wait
            })
            .map(|(k, b)| (k.clone(), b.oldest + self.policy.max_wait))
            .collect();
        keys.into_iter()
            .filter_map(|(k, deadline)| {
                self.take(&k).map(|mut bundle| {
                    bundle.deadline = Some(deadline);
                    bundle
                })
            })
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<WorkBundle> {
        let keys: Vec<BundleKey> = self.pending.keys().cloned().collect();
        keys.iter().filter_map(|k| self.take(k)).collect()
    }

    /// Earliest deadline among pending bundles (service sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter(|b| !b.requests.is_empty())
            .map(|b| b.oldest + self.policy.max_wait)
            .min()
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|b| b.requests.len()).sum()
    }

    pub fn pending_samples(&self) -> usize {
        self.pending.values().map(|b| b.samples).sum()
    }

    fn take(&mut self, key: &BundleKey) -> Option<WorkBundle> {
        let bundle = self.pending.remove(key)?;
        if bundle.requests.is_empty() {
            return None;
        }
        Some(WorkBundle::new(key.clone(), bundle.requests))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::DraftSpec;
    use crate::core::schedule::WarpMode;

    fn req(id: u64, tag: &str, n: usize) -> GenRequest {
        GenRequest {
            id,
            domain: "text8".into(),
            tag: tag.into(),
            draft: DraftSpec::Lstm,
            n_samples: n,
            t0: 0.8,
            steps_cold: 64,
            warp_mode: WarpMode::Literal,
            seed: id,
            timing: false,
            submitted: Instant::now(),
        }
    }

    fn policy(max_batch: usize, wait_ms: u64) -> FlushPolicy {
        FlushPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn size_triggered_flush() {
        let mut b = Batcher::new(policy(8, 1000));
        assert!(b.offer(req(1, "cold", 3)).is_none());
        assert!(b.offer(req(2, "cold", 3)).is_none());
        let bundle = b.offer(req(3, "cold", 3)).expect("should flush at 9 >= 8");
        assert_eq!(bundle.requests.len(), 3);
        assert_eq!(bundle.total_samples(), 9);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn different_keys_do_not_mix() {
        let mut b = Batcher::new(policy(4, 1000));
        assert!(b.offer(req(1, "cold", 3)).is_none());
        // Different tag -> different bundle; neither flushes.
        assert!(b.offer(req(2, "ws_t080", 3)).is_none());
        assert_eq!(b.pending_requests(), 2);
        let flushed = b.flush_all();
        assert_eq!(flushed.len(), 2);
        for bundle in &flushed {
            assert_eq!(bundle.requests.len(), 1);
            assert!(bundle.requests.iter().all(|r| r.bundle_key() == bundle.key));
        }
    }

    #[test]
    fn deadline_triggered_flush() {
        let mut b = Batcher::new(policy(100, 0)); // immediate deadline
        b.offer(req(1, "cold", 2));
        std::thread::sleep(Duration::from_millis(1));
        let due = b.due(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].total_samples(), 2);
        // Deadline flushes carry the deadline that fired (for flush_lag).
        assert!(due[0].deadline.is_some());
        assert!(due[0].deadline.unwrap() <= Instant::now());
        assert!(b.due(Instant::now()).is_empty());
    }

    #[test]
    fn size_flush_carries_future_deadline_but_shutdown_has_none() {
        let mut b = Batcher::new(policy(2, 1000));
        let bundle = b.offer(req(1, "cold", 2)).expect("size flush");
        // Size flush beats its deadline: the would-be deadline rides along
        // (still in the future) so the service can count it as early.
        let deadline = bundle.deadline.expect("size flush carries its deadline");
        assert!(deadline > Instant::now());
        b.offer(req(2, "cold", 1));
        for bundle in b.flush_all() {
            assert!(bundle.deadline.is_none(), "shutdown flushes have no deadline semantics");
        }
    }

    #[test]
    fn deadline_not_early() {
        let mut b = Batcher::new(policy(100, 10_000));
        b.offer(req(1, "cold", 2));
        assert!(b.due(Instant::now()).is_empty());
        assert!(b.next_deadline().is_some());
    }

    #[test]
    fn fifo_within_bundle() {
        let mut b = Batcher::new(policy(100, 1000));
        for i in 0..10 {
            b.offer(req(i, "cold", 1));
        }
        let all = b.flush_all();
        assert_eq!(all.len(), 1);
        let ids: Vec<u64> = all[0].requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn conservation_property() {
        // Random offers across keys: every request comes out exactly once.
        use crate::util::prop::{check, Pair, UsizeRange, VecOf};
        check(
            "batcher conserves requests",
            VecOf(Pair(UsizeRange(0, 3), UsizeRange(1, 9)), 40),
            |ops| {
                let tags = ["cold", "ws_t050", "ws_t080", "x"];
                let mut b = Batcher::new(policy(8, 1000));
                let mut submitted = Vec::new();
                let mut emitted = Vec::new();
                for (i, &(tag_i, n)) in ops.iter().enumerate() {
                    let r = req(i as u64, tags[tag_i], n);
                    submitted.push(r.id);
                    if let Some(bundle) = b.offer(r) {
                        emitted.extend(bundle.requests.iter().map(|r| r.id));
                    }
                }
                for bundle in b.flush_all() {
                    emitted.extend(bundle.requests.iter().map(|r| r.id));
                }
                let mut e = emitted.clone();
                e.sort_unstable();
                let mut s = submitted.clone();
                s.sort_unstable();
                if e != s {
                    return Err(format!("lost/duplicated: in={s:?} out={e:?}"));
                }
                Ok(())
            },
        );
    }
}
