//! Request/response types and the batching bundle key.

use crate::core::schedule::WarpMode;
use crate::data::two_moons::DraftKind;
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// Which draft model supplies the warm-start initial samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DraftSpec {
    /// Uniform noise (cold DFM's implicit draft).
    Noise,
    /// LSTM HLO artifact (text domains).
    Lstm,
    /// PCA-Gaussian HLO artifact (image domains).
    Pca,
    /// Two-moons contrived mixtures.
    Mixture(DraftKind),
}

impl DraftSpec {
    pub fn parse(s: &str) -> Result<DraftSpec> {
        Ok(match s {
            "noise" => DraftSpec::Noise,
            "lstm" => DraftSpec::Lstm,
            "pca" => DraftSpec::Pca,
            other => match DraftKind::parse(other) {
                Some(k) => DraftSpec::Mixture(k),
                None => bail!("unknown draft {other:?} (noise|lstm|pca|good|fair|poor)"),
            },
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DraftSpec::Noise => "noise",
            DraftSpec::Lstm => "lstm",
            DraftSpec::Pca => "pca",
            DraftSpec::Mixture(k) => k.name(),
        }
    }
}

/// One generation request (post-routing, pre-batching).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// Domain ("two_moons", "text8", "wiki", "img_gray", "img_color").
    pub domain: String,
    /// Step-artifact tag ("cold", "ws_t080", "ws_good_t095", ...).
    pub tag: String,
    pub draft: DraftSpec,
    /// Number of samples this request wants.
    pub n_samples: usize,
    /// Warm-start time (0 = cold).
    pub t0: f64,
    /// Cold-run step count (grid resolution).
    pub steps_cold: usize,
    pub warp_mode: WarpMode,
    /// Request RNG seed (reproducibility).
    pub seed: u64,
    /// Opt-in per-response timing/NFE breakdown (`"timing": true` on the
    /// wire → [`GenResponse::timing`] populated). Off by default so the
    /// legacy wire layout is untouched. Never part of the bundle key or
    /// any RNG derivation — observation must not perturb outputs.
    pub timing: bool,
    pub submitted: Instant,
}

/// Wire equality: everything except `submitted` (a local timestamp that
/// never travels over the wire and differs on every parse). Lets codec
/// round-trip property tests compare parsed requests directly.
impl PartialEq for GenRequest {
    fn eq(&self, other: &GenRequest) -> bool {
        self.id == other.id
            && self.domain == other.domain
            && self.tag == other.tag
            && self.draft == other.draft
            && self.n_samples == other.n_samples
            && self.t0 == other.t0
            && self.steps_cold == other.steps_cold
            && self.warp_mode == other.warp_mode
            && self.seed == other.seed
            && self.timing == other.timing
    }
}

impl GenRequest {
    /// Construct a validated request from decoded wire fields (shared by
    /// the JSON and binary codecs, so validation cannot diverge between
    /// them). `id` is assigned later at admission; `submitted` is now.
    #[allow(clippy::too_many_arguments)]
    pub fn from_wire(
        domain: String,
        tag: String,
        draft: DraftSpec,
        n_samples: usize,
        t0: f64,
        steps_cold: usize,
        warp_mode: WarpMode,
        seed: u64,
    ) -> Result<GenRequest> {
        let request = GenRequest {
            id: 0,
            domain,
            tag,
            draft,
            n_samples,
            t0,
            steps_cold,
            warp_mode,
            seed,
            timing: false,
            submitted: Instant::now(),
        };
        request.validate()?;
        Ok(request)
    }

    /// The batching key: requests sharing it can ride the same executor
    /// batch (same artifact and identical sampler schedule).
    pub fn bundle_key(&self) -> BundleKey {
        BundleKey {
            domain: self.domain.clone(),
            tag: self.tag.clone(),
            draft: self.draft,
            t0_milli: (self.t0 * 1000.0).round() as u32,
            steps_cold: self.steps_cold,
            warp_literal: self.warp_mode == WarpMode::Literal,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_samples == 0 {
            bail!("n_samples must be positive");
        }
        if self.n_samples > 1 << 16 {
            bail!("n_samples too large ({})", self.n_samples);
        }
        if !(0.0..1.0).contains(&self.t0) {
            bail!("t0 must be in [0, 1), got {}", self.t0);
        }
        if self.steps_cold == 0 || self.steps_cold > 1 << 16 {
            bail!("steps_cold out of range: {}", self.steps_cold);
        }
        if self.domain.is_empty() || self.tag.is_empty() {
            bail!("domain and tag must be set");
        }
        Ok(())
    }
}

/// Batching compatibility key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BundleKey {
    pub domain: String,
    pub tag: String,
    pub draft: DraftSpec,
    pub t0_milli: u32,
    pub steps_cold: usize,
    pub warp_literal: bool,
}

impl BundleKey {
    pub fn t0(&self) -> f64 {
        self.t0_milli as f64 / 1000.0
    }

    pub fn warp_mode(&self) -> WarpMode {
        if self.warp_literal {
            WarpMode::Literal
        } else {
            WarpMode::Exact
        }
    }

    /// Process-stable hash of the key (FNV-1a over all fields).
    ///
    /// This feeds the per-bundle RNG substream derivation
    /// (`Scheduler::bundle_seed`), so it must be identical across runs,
    /// threads, and pipeline interleavings — `std::hash` makes no such
    /// promise. Strings are NUL-terminated so field boundaries can't
    /// alias ("ab"+"c" vs "a"+"bc").
    pub fn stable_hash(&self) -> u64 {
        use crate::core::rng::{fnv1a64, FNV_OFFSET};
        let mut h = fnv1a64(FNV_OFFSET, self.domain.as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, self.tag.as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, self.draft.name().as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, &self.t0_milli.to_le_bytes());
        h = fnv1a64(h, &(self.steps_cold as u64).to_le_bytes());
        fnv1a64(h, &[self.warp_literal as u8])
    }
}

/// Per-response cascade accounting ([`crate::cascade`]): present exactly
/// when the bundle ran under a cascade mode (`fixed`/`gated`); `None`
/// under `cascade.mode = off` keeps the wire byte-for-byte the
/// pre-cascade format.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CascadeInfo {
    /// Ladder stages actually executed (max over the bundle's chunks).
    pub stages_used: usize,
    /// Denoiser evaluations per executed stage; sums to the response's
    /// worst-chunk total NFE.
    pub nfe_per_stage: Vec<usize>,
    /// Whether any chunk's quality gate passed before the final stage.
    pub early_exit: bool,
}

/// Opt-in per-response timing/NFE breakdown (requested with
/// `"timing": true` on the wire). The per-sample evidence for the paper's
/// guaranteed-NFE claim: where the wall-clock went (per refine segment,
/// per gate eval — queue/draft/total already ride the response), how the
/// executed NFE compares to the `guaranteed_nfe(steps_cold, t0_min)`
/// floor, and which fleet replicas did the work. Absent from the wire
/// when not requested, so the legacy byte layout is untouched.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TimingInfo {
    /// The guarantee-floor NFE budget this bundle was admitted under;
    /// `GenResponse::nfe` ≤ this is the invariant on the normal path.
    pub nfe_floor: usize,
    /// Per executed refine segment: (NFE, wall-clock µs). One entry on
    /// the single-segment path; one per executed ladder stage under a
    /// cascade. Composed-path durations are 0 (shared-cohort wall-clock
    /// is not attributable to one bundle) while NFE stays exact.
    pub segments: Vec<(usize, u64)>,
    /// Wall-clock µs of each mid-cascade quality-gate evaluation.
    pub gate_us: Vec<u64>,
    /// Fleet replica indices that served this bundle's engine calls, in
    /// first-dispatch order (empty on a fleet-less executor).
    pub replicas: Vec<u32>,
    /// Fleet reroutes absorbed while refining this bundle.
    pub reroutes: u32,
}

/// Completed generation.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResponse {
    pub id: u64,
    /// `n_samples` rows of `seq_len` tokens.
    pub samples: Vec<Vec<i32>>,
    /// Denoiser evaluations performed for the batch this request rode
    /// (under a gated cascade: the worst chunk's executed total).
    pub nfe: usize,
    /// The warm-start time the refinement actually ran with — equals the
    /// requested t0 under the `static` controller, the controller's
    /// per-bundle choice under `prior`/`scored` ([`crate::control`]).
    pub t0_used: f64,
    /// Cascade stage accounting (`None` when `cascade.mode = off`).
    pub cascade: Option<CascadeInfo>,
    pub queue_wait: Duration,
    pub draft_time: Duration,
    pub refine_time: Duration,
    pub total_time: Duration,
    /// `Some(reason)` when refinement failed and the coordinator served
    /// the already-computed draft tokens instead (graceful degradation:
    /// `samples` are the warm-start *drafts*, `nfe` is 0). `None` on the
    /// normal path — the wire format then carries no degraded fields at
    /// all, keeping the legacy byte layout.
    pub degraded: Option<String>,
    /// Per-response breakdown, present only when the request set
    /// `timing: true` (absent on degraded responses: the refine trail
    /// that would populate it is the thing that failed).
    pub timing: Option<TimingInfo>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> GenRequest {
        GenRequest {
            id: 1,
            domain: "text8".into(),
            tag: "ws_t080".into(),
            draft: DraftSpec::Lstm,
            n_samples: 4,
            t0: 0.8,
            steps_cold: 1024,
            warp_mode: WarpMode::Literal,
            seed: 0,
            timing: false,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn bundle_key_groups_compatible() {
        // The timing flag is pure observability: it must never split a
        // batch (not part of the bundle key).
        let a = req();
        let mut t = req();
        t.timing = true;
        assert_eq!(a.bundle_key(), t.bundle_key());
    }

    #[test]
    fn bundle_key_groups_compatible_fields() {
        let a = req();
        let mut b = req();
        b.id = 2;
        b.seed = 99;
        b.n_samples = 7;
        assert_eq!(a.bundle_key(), b.bundle_key()); // seed/id/count don't split batches

        let mut c = req();
        c.t0 = 0.5;
        assert_ne!(a.bundle_key(), c.bundle_key());
        let mut d = req();
        d.warp_mode = WarpMode::Exact;
        assert_ne!(a.bundle_key(), d.bundle_key());
        let mut e = req();
        e.tag = "cold".into();
        assert_ne!(a.bundle_key(), e.bundle_key());
    }

    #[test]
    fn validation() {
        assert!(req().validate().is_ok());
        let mut r = req();
        r.n_samples = 0;
        assert!(r.validate().is_err());
        let mut r = req();
        r.t0 = 1.0;
        assert!(r.validate().is_err());
        let mut r = req();
        r.steps_cold = 0;
        assert!(r.validate().is_err());
        let mut r = req();
        r.domain = String::new();
        assert!(r.validate().is_err());
    }

    #[test]
    fn draft_spec_parse() {
        assert_eq!(DraftSpec::parse("noise").unwrap(), DraftSpec::Noise);
        assert_eq!(DraftSpec::parse("lstm").unwrap(), DraftSpec::Lstm);
        assert_eq!(DraftSpec::parse("good").unwrap(), DraftSpec::Mixture(DraftKind::Good));
        assert!(DraftSpec::parse("bogus").is_err());
        assert_eq!(DraftSpec::parse("pca").unwrap().name(), "pca");
    }

    #[test]
    fn stable_hash_distinguishes_fields() {
        let base = req().bundle_key();
        assert_eq!(base.stable_hash(), req().bundle_key().stable_hash());
        let mut t = req();
        t.tag = "cold".into();
        assert_ne!(base.stable_hash(), t.bundle_key().stable_hash());
        let mut w = req();
        w.warp_mode = WarpMode::Exact;
        assert_ne!(base.stable_hash(), w.bundle_key().stable_hash());
        let mut d = req();
        d.draft = DraftSpec::Noise;
        assert_ne!(base.stable_hash(), d.bundle_key().stable_hash());
        // Domain/tag boundary aliasing is prevented by the separators.
        let mut a = req();
        a.domain = "text".into();
        a.tag = "8ws".into();
        let mut b = req();
        b.domain = "text8".into();
        b.tag = "ws".into();
        assert_ne!(a.bundle_key().stable_hash(), b.bundle_key().stable_hash());
    }

    #[test]
    fn bundle_key_t0_roundtrip() {
        let k = req().bundle_key();
        assert!((k.t0() - 0.8).abs() < 1e-9);
        assert_eq!(k.warp_mode(), WarpMode::Literal);
    }
}
