//! Bounded admission queue with backpressure.
//!
//! `push` fails fast with [`QueueFull`] when capacity is reached — the
//! server surfaces that as a `busy` response instead of buffering without
//! bound (DESIGN.md §5). Pop supports timeouts so the batcher can enforce
//! flush deadlines, and `close()` drains cleanly at shutdown.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Error returned when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull;

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "admission queue full")
    }
}
impl std::error::Error for QueueFull {}

#[derive(Debug)]
struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// MPMC bounded FIFO queue.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    capacity: usize,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            capacity,
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Non-blocking push; `Err(QueueFull)` applies backpressure.
    pub fn push(&self, item: T) -> Result<(), QueueFull> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(QueueFull);
        }
        g.items.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking push: waits for space instead of failing fast. Used for
    /// the inter-stage pipeline channels, where the producer should stall
    /// (bounding work in flight) rather than drop a flushed bundle. Fails
    /// only when the queue is closed, returning the item so the caller
    /// can fail it cleanly instead of silently dropping it.
    pub fn push_wait(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.cv.notify_all();
                return Ok(());
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Blocking pop with timeout; `None` on timeout or when closed+empty.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.cv.notify_all(); // wake a push_wait-er: space freed
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let (g2, res) = self.cv.wait_timeout(g, timeout).unwrap();
            g = g2;
            if res.timed_out() {
                let item = g.items.pop_front();
                if item.is_some() {
                    self.cv.notify_all();
                }
                return item;
            }
        }
    }

    /// Blocking pop of **everything ready** in one wakeup: waits like
    /// [`BoundedQueue::pop_timeout`] for the first item, then drains the
    /// rest of the backlog under the same lock. One notify wakes a
    /// consumer once, not once per item — the ingest primitive for the
    /// step-level batch composer, which wants every ready bundle admitted
    /// at the same step boundary. Returns an empty vec on timeout or when
    /// closed+empty.
    pub fn pop_many(&self, timeout: Duration) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let out: Vec<T> = g.items.drain(..).collect();
                self.cv.notify_all(); // wake push_wait-ers: space freed
                return out;
            }
            if g.closed {
                return Vec::new();
            }
            let (g2, res) = self.cv.wait_timeout(g, timeout).unwrap();
            g = g2;
            if res.timed_out() {
                let out: Vec<T> = g.items.drain(..).collect();
                if !out.is_empty() {
                    self.cv.notify_all();
                }
                return out;
            }
        }
    }

    /// Drain everything currently queued (non-blocking).
    pub fn drain(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let out: Vec<T> = g.items.drain(..).collect();
        if !out.is_empty() {
            self.cv.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: further pushes fail; pops drain whatever remains then None.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(i));
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(QueueFull));
        assert_eq!(q.len(), 2);
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        q.push(3).unwrap();
    }

    #[test]
    fn close_rejects_push_but_drains() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.push(8), Err(QueueFull));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(7));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
        assert!(q.is_closed());
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(64));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                while q2.push(i).is_err() {
                    std::thread::yield_now();
                }
            }
            q2.close();
        });
        let mut got = Vec::new();
        while let Some(v) = q.pop_timeout(Duration::from_millis(200)) {
            got.push(v);
            if got.len() == 100 {
                break;
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn push_wait_blocks_until_space_then_succeeds() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push_wait(2));
        // The pusher is blocked on a full queue; free a slot.
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(1));
        pusher.join().unwrap().unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(2));
    }

    #[test]
    fn push_wait_unblocks_on_close() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push_wait(2));
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        // The rejected item comes back to the caller.
        assert_eq!(pusher.join().unwrap(), Err(2));
        // The original item still drains.
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(1));
    }

    #[test]
    fn pop_many_takes_whole_backlog_in_one_wakeup() {
        let q = Arc::new(BoundedQueue::new(8));
        for i in 0..5 {
            q.push(i).unwrap();
        }
        // A ready backlog comes out whole, FIFO, in one call.
        assert_eq!(q.pop_many(Duration::from_millis(1)), vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        // Empty + timeout -> empty vec, bounded wait.
        assert_eq!(q.pop_many(Duration::from_millis(1)), Vec::<i32>::new());
        // A blocked pop_many wakes for the first push and drains whatever
        // arrived by the time it gets the lock.
        let q2 = q.clone();
        let popper = std::thread::spawn(move || q2.pop_many(Duration::from_millis(500)));
        std::thread::sleep(Duration::from_millis(10));
        q.push(41).unwrap();
        q.push(42).unwrap();
        let got = popper.join().unwrap();
        assert!(!got.is_empty() && got[0] == 41, "{got:?}");
        // Closed + empty -> empty vec immediately; closed + backlog drains.
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop_many(Duration::from_millis(1)), vec![7]);
        assert_eq!(q.pop_many(Duration::from_millis(1)), Vec::<i32>::new());
        // pop_many frees space for a blocked push_wait-er.
        let q3 = Arc::new(BoundedQueue::new(1));
        q3.push(1).unwrap();
        let q4 = q3.clone();
        let pusher = std::thread::spawn(move || q4.push_wait(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q3.pop_many(Duration::from_millis(100)), vec![1]);
        pusher.join().unwrap().unwrap();
        assert_eq!(q3.pop_many(Duration::from_millis(100)), vec![2]);
    }

    #[test]
    fn drain_takes_all() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.drain(), vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }
}
