//! Deterministic replay of decision-ledger records (`wsfm replay`).
//!
//! A [`crate::obs::ledger::DecisionRecord`] carries everything the
//! scheduler needs to re-execute its bundle: the bundle key fields, the
//! controller and cascade policy that were in force, the stateless seeds,
//! and the per-request output hashes. Replay rebuilds the requests and
//! policies from the record alone, re-runs DRAFT → REFINE against a live
//! manifest, and asserts the outputs are **bitwise identical** to what
//! was served — `hash_samples` over every response's rows, plus the
//! realized NFE and chosen t0.
//!
//! The one decision replay does *not* re-derive is the controller's t0
//! choice: the recorded [`crate::control::ControlDecision`] is injected
//! after the DRAFT phase, exactly where the live path computed it. This
//! makes replay robust to calibration-table drift (the table is not part
//! of the record) while still exercising the full RNG substream
//! derivation, chunk planning, drafting, and refinement — if any of
//! those changed since the record was written, the hashes diverge and
//! the mismatch names the bundle.
//!
//! Degraded records are skipped (their outputs are draft tokens from a
//! failed refine — there is nothing deterministic to reproduce), as are
//! records whose artifacts are absent from the manifest at hand
//! (reported separately so CI can stay strict while ad-hoc runs stay
//! usable).

use crate::cascade::Cascade;
use crate::config::{CascadeConfig, ControlConfig};
use crate::control::{ControlDecision, Controller};
use crate::coordinator::batcher::WorkBundle;
use crate::coordinator::request::{DraftSpec, GenRequest};
use crate::coordinator::scheduler::Scheduler;
use crate::core::schedule::WarpMode;
use crate::metrics::ServingMetrics;
use crate::obs::ledger::{hash_samples, DecisionRecord};
use crate::runtime::engine::Executor;
use crate::runtime::Manifest;
use anyhow::{Context, Result};
use std::time::Instant;

/// Outcome of replaying one ledger file's records.
#[derive(Debug, Default)]
pub struct ReplayReport {
    /// Records re-executed with bitwise-identical outputs.
    pub matched: usize,
    /// `(bundle_id, reason)` for records that re-executed but diverged,
    /// or whose recorded policies no longer parse.
    pub mismatched: Vec<(u64, String)>,
    /// Degraded records carry no refined output to reproduce.
    pub skipped_degraded: usize,
    /// `(bundle_id, reason)` for records whose artifacts the manifest
    /// at hand cannot serve (e.g. replaying a production ledger against
    /// a smoke-test artifact set).
    pub skipped_unavailable: Vec<(u64, String)>,
}

impl ReplayReport {
    /// No divergence among the records that could be re-executed.
    pub fn is_clean(&self) -> bool {
        self.mismatched.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "replayed {} record(s): {} matched, {} mismatched, {} degraded skipped, {} unavailable\n",
            self.matched + self.mismatched.len(),
            self.matched,
            self.mismatched.len(),
            self.skipped_degraded,
            self.skipped_unavailable.len(),
        );
        for (id, reason) in &self.mismatched {
            out.push_str(&format!("  MISMATCH bundle {id}: {reason}\n"));
        }
        for (id, reason) in &self.skipped_unavailable {
            out.push_str(&format!("  skipped bundle {id}: {reason}\n"));
        }
        out
    }
}

/// Rebuild the requests a record was served for. Ids, seeds, and sample
/// counts come straight from the record; `submitted` is now (it never
/// participates in RNG or batching).
fn rebuild_requests(rec: &DecisionRecord) -> Result<Vec<GenRequest>> {
    let draft = DraftSpec::parse(&rec.draft)
        .with_context(|| format!("bundle {}: recorded draft kind", rec.bundle_id))?;
    let warp_mode = if rec.warp_literal { WarpMode::Literal } else { WarpMode::Exact };
    rec.requests
        .iter()
        .map(|r| {
            let req = GenRequest {
                id: r.id,
                domain: rec.domain.clone(),
                tag: rec.tag.clone(),
                draft,
                n_samples: r.n_samples,
                t0: rec.requested_t0,
                steps_cold: rec.steps_cold,
                warp_mode,
                seed: r.seed,
                timing: false,
                submitted: Instant::now(),
            };
            req.validate().with_context(|| format!("bundle {}: recorded request", rec.bundle_id))?;
            Ok(req)
        })
        .collect()
}

/// Rebuild the warm-start controller a record ran under. The calibration
/// table is deliberately empty: the recorded decision is injected
/// verbatim, so only the mode/bounds matter — and they must match so the
/// NFE budget (hence the `debug_assert` guarantee check) is computed the
/// way the live path computed it.
fn rebuild_controller(rec: &DecisionRecord) -> Result<Controller> {
    Controller::from_config(&ControlConfig {
        mode: rec.control_mode.clone(),
        t0_min: rec.t0_min,
        t0_max: rec.t0_max,
        grid: rec.grid.clone(),
        calibration: Vec::new(),
    })
    .with_context(|| format!("bundle {}: recorded controller", rec.bundle_id))
}

fn rebuild_cascade(rec: &DecisionRecord) -> Result<Cascade> {
    Cascade::from_config(&CascadeConfig {
        mode: rec.cascade_mode.clone(),
        ladder: rec.ladder.clone(),
        gate_threshold: rec.gate_threshold.unwrap_or(CascadeConfig::default().gate_threshold),
    })
    .with_context(|| format!("bundle {}: recorded cascade", rec.bundle_id))
}

/// Re-execute one record and return `Err(reason)` on any divergence.
/// `Ok(())` means every response hash, the realized NFE, and the chosen
/// t0 came out bitwise/exactly equal to the record.
fn replay_one(
    exec: &dyn Executor,
    manifest: &Manifest,
    metrics: &ServingMetrics,
    rec: &DecisionRecord,
) -> Result<()> {
    let requests = rebuild_requests(rec)?;
    let controller = rebuild_controller(rec)?;
    let cascade = rebuild_cascade(rec)?;
    let sched =
        Scheduler::with_policies(exec, manifest, metrics, rec.config_seed, controller, cascade);

    let key = requests[0].bundle_key();
    let mut bundle = WorkBundle::new(key, requests);
    bundle.bundle_id = rec.bundle_id;
    let derived = sched.bundle_seed(&bundle);
    if derived != rec.bundle_seed {
        anyhow::bail!(
            "bundle seed derivation diverged: derived {derived:#x}, recorded {:#x}",
            rec.bundle_seed
        );
    }

    let mut drafted = sched.draft_bundle(bundle)?;
    // Inject the recorded decision at the DRAFT→REFINE hand-off — the
    // exact point the live path set it.
    drafted.decision = ControlDecision { t0: rec.chosen_t0, score: rec.score };
    let responses = sched.refine_bundle(drafted)?;

    if responses.len() != rec.requests.len() {
        anyhow::bail!("{} responses for {} recorded requests", responses.len(), rec.requests.len());
    }
    for (resp, rr) in responses.iter().zip(&rec.requests) {
        if resp.id != rr.id {
            anyhow::bail!("response order diverged: got id {}, recorded {}", resp.id, rr.id);
        }
        let h = hash_samples(&resp.samples);
        if h != rr.out_hash {
            anyhow::bail!(
                "request {}: output hash {h:#x} != recorded {:#x} (tokens diverged)",
                rr.id,
                rr.out_hash
            );
        }
        if resp.nfe != rec.nfe {
            anyhow::bail!("request {}: nfe {} != recorded {}", rr.id, resp.nfe, rec.nfe);
        }
        if resp.t0_used != rec.chosen_t0 {
            anyhow::bail!("request {}: t0 {} != recorded {}", rr.id, resp.t0_used, rec.chosen_t0);
        }
    }
    Ok(())
}

/// Replay every record against `exec`/`manifest`, sorting each into
/// matched / mismatched / skipped. Never fails as a whole: a corrupt or
/// un-servable record is that record's problem, reported in the result.
pub fn replay_records(
    exec: &dyn Executor,
    manifest: &Manifest,
    records: &[DecisionRecord],
) -> ReplayReport {
    let metrics = ServingMetrics::default();
    let mut report = ReplayReport::default();
    for rec in records {
        if rec.degraded {
            report.skipped_degraded += 1;
            continue;
        }
        if manifest.step_batches(&rec.domain, &rec.tag).is_empty() {
            report
                .skipped_unavailable
                .push((rec.bundle_id, format!("no step artifacts for {}/{}", rec.domain, rec.tag)));
            continue;
        }
        match replay_one(exec, manifest, &metrics, rec) {
            Ok(()) => report.matched += 1,
            Err(e) => report.mismatched.push((rec.bundle_id, format!("{e:#}"))),
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{mock_manifest, request, TestExec};

    /// Run a bundle live with the default in-memory ledger, then replay
    /// what the ledger captured.
    fn serve_and_capture(
        cascade_mode: &str,
        control_mode: &str,
        config_seed: u64,
    ) -> (Vec<DecisionRecord>, Vec<Vec<Vec<i32>>>) {
        let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
        let metrics = ServingMetrics::default();
        let controller = Controller::from_config(&ControlConfig {
            mode: control_mode.into(),
            ..ControlConfig::default()
        })
        .unwrap();
        let cascade = Cascade::from_config(&CascadeConfig {
            mode: cascade_mode.into(),
            ..CascadeConfig::default()
        })
        .unwrap();
        let sched =
            Scheduler::with_policies(&exec, &manifest, &metrics, config_seed, controller, cascade);
        let reqs = vec![request(1, 3), request(2, 2)];
        let bundle = WorkBundle::new(reqs[0].bundle_key(), reqs);
        let responses = sched.run_bundle(bundle).unwrap();
        let samples = responses.iter().map(|r| r.samples.clone()).collect();
        (metrics.obs.ledger.snapshot(), samples)
    }

    #[test]
    fn replay_reproduces_served_outputs_bitwise() {
        for (cascade_mode, control_mode) in
            [("off", "static"), ("fixed", "static"), ("gated", "scored"), ("off", "prior")]
        {
            let (records, _) = serve_and_capture(cascade_mode, control_mode, 77);
            assert_eq!(records.len(), 1, "{cascade_mode}/{control_mode}");
            // A fresh executor + manifest (fresh caches, fresh scratch):
            // replay must still land on the identical hashes.
            let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
            let report = replay_records(&exec, &manifest, &records);
            assert!(
                report.is_clean(),
                "{cascade_mode}/{control_mode}: {}",
                report.render()
            );
            assert_eq!(report.matched, 1);
            assert_eq!(report.skipped_degraded, 0);
            assert!(report.skipped_unavailable.is_empty());
        }
    }

    #[test]
    fn replay_detects_tampered_outputs_and_seeds() {
        let (records, _) = serve_and_capture("off", "static", 5);
        // Tampered output hash: the replayed tokens no longer match.
        let mut tampered = records.clone();
        tampered[0].requests[0].out_hash ^= 1;
        let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
        let report = replay_records(&exec, &manifest, &tampered);
        assert_eq!(report.mismatched.len(), 1);
        assert!(report.mismatched[0].1.contains("output hash"), "{}", report.mismatched[0].1);
        assert!(report.render().contains("MISMATCH"));
        // Tampered bundle seed: caught before any engine work runs.
        let mut reseeded = records.clone();
        reseeded[0].bundle_seed ^= 1;
        let report = replay_records(&exec, &manifest, &reseeded);
        assert_eq!(report.mismatched.len(), 1);
        assert!(report.mismatched[0].1.contains("seed derivation"), "{}", report.mismatched[0].1);
    }

    #[test]
    fn replay_skips_degraded_and_unavailable_records() {
        let (mut records, _) = serve_and_capture("off", "static", 5);
        let mut degraded = records[0].clone();
        degraded.bundle_id += 1;
        degraded.degraded = true;
        degraded.nfe = 0;
        let mut foreign = records[0].clone();
        foreign.bundle_id += 2;
        foreign.domain = "text8".into();
        records.extend([degraded, foreign]);
        let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
        let report = replay_records(&exec, &manifest, &records);
        assert_eq!(report.matched, 1);
        assert_eq!(report.skipped_degraded, 1);
        assert_eq!(report.skipped_unavailable.len(), 1);
        assert!(report.is_clean(), "skips are not mismatches");
    }
}
