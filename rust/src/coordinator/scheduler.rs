//! The staged scheduler: PLAN → DRAFT → REFINE over one flushed bundle.
//!
//! For a bundle of `n` total samples it plans executor chunks over the
//! compiled batch shapes ([`crate::runtime::pool`]), generates draft
//! samples for each chunk (LSTM/PCA artifact, two-moons mixture, or
//! uniform noise), runs the warm-start Euler loop, strips batch padding,
//! and scatters rows back to the originating requests in FIFO order.
//!
//! The phases are **separable**: [`Scheduler::draft_bundle`] produces an
//! explicit [`DraftedBundle`] that [`Scheduler::refine_bundle`] consumes,
//! so the pipelined service ([`crate::coordinator::service`]) can run the
//! cheap DRAFT phase for bundle N+1 on a worker thread while the REFINE
//! phase of bundle N occupies the engine — the serving-side dual of
//! warm-start flow matching itself (draft cost ≪ refine cost, paper §3).
//! [`Scheduler::run_bundle`] composes both for the serial path.
//!
//! ## RNG substream contract (bundle level)
//!
//! All bundle randomness derives statelessly from
//! `(config.seed, bundle key, request seeds)` via [`Scheduler::bundle_seed`]:
//! chunk `c` drafts from `Pcg64::substream(bundle_seed, c, DRAFT_LANE)` and
//! refines with a run seed drawn from
//! `Pcg64::substream(bundle_seed, c, REFINE_LANE)`. No RNG state threads
//! across bundles, so output tokens are bitwise-identical regardless of
//! pipeline depth, draft-worker count, or bundle completion order — the
//! same contract the row-parallel sampler established per `(step, row)`
//! (EXPERIMENTS.md §Perf), lifted one level up.

use crate::cascade::{self, Cascade};
use crate::control::{ControlDecision, Controller, ControllerMode};
use crate::coordinator::batcher::WorkBundle;
use crate::coordinator::request::{CascadeInfo, DraftSpec, GenRequest, GenResponse, TimingInfo};
use crate::core::rng::{splitmix64, Pcg64};
use crate::obs::{scope, SpanKind};
use crate::core::tensor::TokenBatch;
use crate::draft::{Draft, DraftNoise, HloDraft, MixtureDraft, NoiseDraft};
use crate::metrics::ServingMetrics;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::engine::{Executor, LoopScratch};
use crate::runtime::{plan_chunks, Manifest};
use crate::sampler::dfm::{sample_warm_with_scratch, SamplerParams};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Substream lane for draft-phase RNG draws.
const DRAFT_LANE: u64 = 0;
/// Substream lane for refine-phase run seeds. `pub(crate)` so the batch
/// composer derives exactly the run seeds the per-bundle path would.
pub(crate) const REFINE_LANE: u64 = 1;

/// Derive the stateless per-bundle seed from the config seed, the bundle
/// key, and the request seeds (in FIFO order). Request ids and timestamps
/// deliberately do not participate: the same logical work always samples
/// the same tokens.
pub fn bundle_seed(config_seed: u64, bundle: &WorkBundle) -> u64 {
    let mut h = splitmix64(config_seed ^ bundle.key.stable_hash());
    for req in &bundle.requests {
        h = splitmix64(h ^ splitmix64(req.seed));
    }
    h
}

/// One executor chunk with its warm-start init tokens already drafted.
#[derive(Debug)]
pub struct DraftedChunk {
    /// Useful rows in this chunk (the rest is batch padding).
    pub chunk_len: usize,
    /// Step artifact this chunk refines on (owns the compiled shape).
    pub meta: ArtifactMeta,
    /// `[exec_batch, seq_len]` draft samples (padding rows included).
    pub init: TokenBatch,
    /// Position in the bundle's chunk plan — the substream coordinate.
    pub chunk_index: usize,
}

/// The explicit DRAFT→REFINE hand-off: a bundle whose warm-start init
/// tokens exist but whose Euler refinement has not run yet. `Send`, so it
/// can cross the pipeline channel between stage threads.
#[derive(Debug)]
pub struct DraftedBundle {
    pub bundle: WorkBundle,
    /// Stateless seed every chunk substream derives from.
    pub bundle_seed: u64,
    pub chunks: Vec<DraftedChunk>,
    /// The warm-start controller's per-bundle t0 choice, made at the end
    /// of the DRAFT phase (scored modes need the drafted tokens). A pure
    /// function of (bundle contents, config), so it crosses the pipeline
    /// hand-off without breaking the determinism contract.
    pub decision: ControlDecision,
    /// Wall-clock of the DRAFT phase.
    pub draft_time: Duration,
    /// When the DRAFT phase started — total_time in responses is measured
    /// from here, so it covers draft + inter-stage wait + refine.
    pub started: Instant,
}

/// Executes bundles against an [`Executor`].
///
/// The refinement loop runs engine-resident (`Executor::run_loop`): one
/// engine round-trip per executor chunk, not per Euler step. `scratch` is
/// the loop staging buffer reused across bundles for in-process executors
/// (the production [`crate::runtime::EngineHandle`] keeps its own per
/// artifact on the engine thread). `drafts` caches resolved draft models
/// keyed by `(domain, spec, batch, vocab)` so repeated chunks stop re-resolving
/// manifest metadata and re-boxing a fresh [`Draft`]. Both are `RefCell`s
/// because each scheduler instance is owned by a single stage thread.
pub struct Scheduler<'a> {
    pub exec: &'a dyn Executor,
    pub manifest: &'a Manifest,
    pub metrics: &'a ServingMetrics,
    /// Root seed (config.seed) for per-bundle substream derivation.
    seed: u64,
    /// Per-bundle t0 controller ([`crate::control`]); the default
    /// [`Scheduler::new`] uses the static pass-through controller.
    controller: Controller,
    /// Cascade-refinement policy ([`crate::cascade`]); the default is
    /// [`Cascade::off`] — one uninterrupted segment, the legacy path.
    cascade: Cascade,
    scratch: RefCell<LoopScratch>,
    drafts: RefCell<HashMap<DraftCacheKey, Box<dyn Draft + 'a>>>,
}

/// Draft-model cache key: `(domain, spec, batch, vocab)`. Vocab rides
/// along because `NoiseDraft` bakes it in at resolution time, and two
/// tags of one domain could in principle compile different vocab sizes
/// at the same batch.
type DraftCacheKey = (String, DraftSpec, usize, usize);

impl<'a> Scheduler<'a> {
    pub fn new(
        exec: &'a dyn Executor,
        manifest: &'a Manifest,
        metrics: &'a ServingMetrics,
        seed: u64,
    ) -> Self {
        Self::with_controller(exec, manifest, metrics, seed, Controller::static_default())
    }

    /// [`Scheduler::new`] with an explicit warm-start controller (the
    /// pipelined service builds one per stage thread from
    /// `config.control`; they are pure data, so sharing a config yields
    /// identical decisions on every thread).
    pub fn with_controller(
        exec: &'a dyn Executor,
        manifest: &'a Manifest,
        metrics: &'a ServingMetrics,
        seed: u64,
        controller: Controller,
    ) -> Self {
        Self::with_policies(exec, manifest, metrics, seed, controller, Cascade::off())
    }

    /// [`Scheduler::with_controller`] plus an explicit cascade policy
    /// ([`crate::cascade`]). Both policies are pure data; stage threads
    /// holding clones of the same config decide identically.
    pub fn with_policies(
        exec: &'a dyn Executor,
        manifest: &'a Manifest,
        metrics: &'a ServingMetrics,
        seed: u64,
        controller: Controller,
        cascade: Cascade,
    ) -> Self {
        Scheduler {
            exec,
            manifest,
            metrics,
            seed,
            controller,
            cascade,
            scratch: RefCell::new(LoopScratch::default()),
            drafts: RefCell::new(HashMap::new()),
        }
    }

    /// The stateless seed this scheduler derives for a bundle.
    pub fn bundle_seed(&self, bundle: &WorkBundle) -> u64 {
        bundle_seed(self.seed, bundle)
    }

    /// The warm-start controller — shared with the step-level batch
    /// composer ([`crate::coordinator::composer`]) so composed and
    /// per-bundle refinement compute identical NFE budgets.
    pub(crate) fn controller(&self) -> &Controller {
        &self.controller
    }

    /// The cascade policy — shared with the batch composer so both
    /// refine paths plan identical segment ladders and gates.
    pub(crate) fn cascade(&self) -> &Cascade {
        &self.cascade
    }

    /// Start a [`crate::obs::ledger::DecisionRecord`] for one bundle
    /// with everything known on the decision side (key, controller and
    /// cascade policy, seeds). Outcome fields (NFE, gates, replicas,
    /// per-request hashes) start zeroed for the refine path to fill;
    /// the degraded-fallback path keeps them zeroed, which is exactly
    /// the "billed nothing" shape the auditor demands.
    pub(crate) fn decision_record_base(
        &self,
        bundle: &WorkBundle,
        bundle_seed: u64,
        decision: &ControlDecision,
    ) -> crate::obs::ledger::DecisionRecord {
        let key = &bundle.key;
        crate::obs::ledger::DecisionRecord {
            bundle_id: bundle.bundle_id,
            domain: key.domain.clone(),
            tag: key.tag.clone(),
            draft: key.draft.name().to_string(),
            steps_cold: key.steps_cold,
            requested_t0: key.t0(),
            warp_literal: key.warp_literal,
            control_mode: self.controller.mode().name().to_string(),
            t0_min: self.controller.t0_min(),
            t0_max: self.controller.t0_max(),
            grid: self.controller.grid().to_vec(),
            score: decision.score,
            chosen_t0: decision.t0,
            cascade_mode: self.cascade.mode().name().to_string(),
            ladder: self.cascade.ladder().to_vec(),
            gate_threshold: self.cascade.gate_threshold(),
            gate_scores: Vec::new(),
            exit_score: None,
            nfe_per_stage: Vec::new(),
            early_exit: false,
            nfe: 0,
            nfe_floor: self.controller.nfe_budget(key.steps_cold, key.t0()),
            degraded: false,
            replicas: Vec::new(),
            reroutes: 0,
            config_seed: self.seed,
            bundle_seed,
            requests: bundle
                .requests
                .iter()
                .map(|r| crate::obs::ledger::RequestRecord {
                    id: r.id,
                    n_samples: r.n_samples,
                    seed: r.seed,
                    out_hash: 0,
                })
                .collect(),
        }
    }

    /// Resolve the draft model for a bundle at a given compiled batch size
    /// (cache-miss path; counted in `draft_models_resolved`).
    fn resolve_draft(
        &self,
        key_domain: &str,
        spec: DraftSpec,
        batch: usize,
        vocab: usize,
    ) -> Result<Box<dyn Draft + 'a>> {
        self.metrics.draft_models_resolved.inc();
        Ok(match spec {
            DraftSpec::Noise => Box::new(NoiseDraft { vocab }),
            DraftSpec::Mixture(kind) => Box::new(MixtureDraft { draft_kind: kind }),
            DraftSpec::Lstm => {
                let meta = self.manifest.find_draft(key_domain, "lstm", batch)?;
                Box::new(HloDraft::new(self.exec, meta.name.clone(), DraftNoise::Gumbel))
            }
            DraftSpec::Pca => {
                let meta = self.manifest.find_draft(key_domain, "pca", batch)?;
                Box::new(HloDraft::new(self.exec, meta.name.clone(), DraftNoise::Gaussian))
            }
        })
    }

    /// Generate draft samples through the [`DraftCacheKey`] cache.
    fn draft_generate(
        &self,
        key_domain: &str,
        spec: DraftSpec,
        batch: usize,
        seq_len: usize,
        vocab: usize,
        rng: &mut Pcg64,
    ) -> Result<TokenBatch> {
        let cache_key = (key_domain.to_string(), spec, batch, vocab);
        let mut cache = self.drafts.borrow_mut();
        if !cache.contains_key(&cache_key) {
            let draft = self.resolve_draft(key_domain, spec, batch, vocab)?;
            cache.insert(cache_key.clone(), draft);
        }
        let draft = cache.get(&cache_key).expect("just inserted");
        let init = draft
            .generate(batch, seq_len, rng)
            .with_context(|| format!("draft {} for {key_domain}/b{batch}", draft.kind()))?;
        self.metrics.draft_calls.inc();
        Ok(init)
    }

    /// PLAN phase: map the bundle's total samples onto compiled chunks.
    fn plan_bundle(&self, bundle: &WorkBundle) -> Result<Vec<(usize, usize)>> {
        let key = &bundle.key;
        let n_total = bundle.total_samples();
        if n_total == 0 {
            bail!("empty bundle");
        }
        let compiled = self.manifest.step_batches(&key.domain, &key.tag);
        if compiled.is_empty() {
            bail!("no step artifacts for {}/{}", key.domain, key.tag);
        }
        plan_chunks(n_total, &compiled)
    }

    /// DRAFT phase: plan chunks and generate warm-start init tokens for
    /// each (padding rows get real draft samples too — simplest
    /// shape-correct choice; they are stripped in REFINE and never leave
    /// the scheduler).
    pub fn draft_bundle(&self, bundle: WorkBundle) -> Result<DraftedBundle> {
        let started = Instant::now();
        let plan = self.plan_bundle(&bundle)?;
        let seed = self.bundle_seed(&bundle);
        let key = &bundle.key;

        let mut chunks = Vec::with_capacity(plan.len());
        for (chunk_index, &(chunk_len, exec_batch)) in plan.iter().enumerate() {
            let meta = self.manifest.find_step(&key.domain, &key.tag, exec_batch)?.clone();
            let mut rng = Pcg64::substream(seed, chunk_index as u64, DRAFT_LANE);
            let init = self.draft_generate(
                &key.domain,
                key.draft,
                exec_batch,
                meta.seq_len,
                meta.vocab,
                &mut rng,
            )?;
            chunks.push(DraftedChunk { chunk_len, meta, init, chunk_index });
        }

        // Controller decision: a pure function of (bundle contents,
        // config). Scored modes see only the useful (non-padding) rows,
        // so the score is the quality of the drafts requests will
        // actually receive.
        let score = if self.controller.needs_score() {
            let rows: Vec<&[i32]> = chunks
                .iter()
                .flat_map(|c| (0..c.chunk_len).map(move |r| c.init.row(r)))
                .collect();
            let vocab = chunks.first().map(|c| c.meta.vocab).unwrap_or(0);
            Some(crate::control::proxy_score(&rows, vocab))
        } else {
            None
        };
        let mut decision = self.controller.decide(key.draft, key.t0(), score);
        // An adaptive choice below the artifact's trained warm-start time
        // would evaluate the denoiser outside its trained range
        // [trained_t0, 1]; clamp up to it. Raising t0 only lowers NFE, so
        // the guarantee floor is unaffected. Static mode stays verbatim
        // (the legacy contract: the client picked tag and t0 together).
        if self.controller.mode() != ControllerMode::Static {
            let trained = chunks
                .iter()
                .filter_map(|c| c.meta.t0)
                .fold(0.0f64, f64::max)
                .min(1.0 - 1e-9);
            if decision.t0 < trained {
                decision.t0 = trained;
            }
        }

        let draft_time = started.elapsed();
        self.metrics.obs.span(0, bundle.bundle_id, SpanKind::Draft, 0, started, draft_time);
        Ok(DraftedBundle { bundle, bundle_seed: seed, chunks, decision, draft_time, started })
    }

    /// REFINE phase: the warm-start Euler loop over each drafted chunk,
    /// padding strip, and FIFO scatter back to per-request responses.
    ///
    /// Opens an observability scope ([`crate::obs::scope`]) keyed by the
    /// bundle id for the duration, so fleet engine-call spans and the
    /// replica/reroute trail attribute to this bundle without widening
    /// the [`Executor`] trait. The scope (like all of [`crate::obs`]) is
    /// write-only from the sampler's perspective: nothing it carries
    /// feeds RNG, batching, or scheduling.
    pub fn refine_bundle(&self, drafted: DraftedBundle) -> Result<Vec<GenResponse>> {
        let prev = scope::begin(drafted.bundle.bundle_id);
        let out = self.refine_inner(drafted);
        let trail = scope::end(prev);
        let (mut responses, record) = out?;
        if let Some(trail) = &trail {
            for resp in &mut responses {
                if let Some(ti) = resp.timing.as_mut() {
                    ti.replicas = trail.replicas.clone();
                    ti.reroutes = trail.reroutes;
                }
            }
        }
        if let Some(mut rec) = record {
            if let Some(trail) = trail {
                rec.replicas = trail.replicas;
                rec.reroutes = trail.reroutes;
            }
            self.metrics.obs.ledger.append(rec);
        }
        Ok(responses)
    }

    /// REFINE body. The second return is the bundle's decision-ledger
    /// record (`None` with the ledger disabled — the record build, hash
    /// included, is skipped entirely so the off path pays one atomic
    /// load); `refine_bundle` patches in the replica trail and appends.
    fn refine_inner(
        &self,
        drafted: DraftedBundle,
    ) -> Result<(Vec<GenResponse>, Option<crate::obs::ledger::DecisionRecord>)> {
        let DraftedBundle { bundle, bundle_seed: seed, chunks, decision, draft_time, started } =
            drafted;
        let mut record = self
            .metrics
            .obs
            .ledger
            .enabled()
            .then(|| self.decision_record_base(&bundle, seed, &decision));
        let key = &bundle.key;
        let n_total = bundle.total_samples();
        let bundle_id = bundle.bundle_id;
        let want_timing = bundle.requests.iter().any(|r| r.timing);
        // Opt-in timing accumulators ([`TimingInfo`]); dead weight only
        // when some request asked for the breakdown.
        let mut seg_timing: Vec<(usize, u64)> = Vec::new();
        let mut gate_us: Vec<u64> = Vec::new();

        // The controller's per-bundle t0 (== the requested t0 under the
        // static controller). The guarantee floor: adaptive schedules can
        // never exceed the static-t0_min NFE budget.
        let t0 = decision.t0;
        let nfe_budget = self.controller.nfe_budget(key.steps_cold, key.t0());
        self.metrics.chosen_t0.record(t0);

        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(n_total);
        let mut nfe = 0;
        let mut refine_time = Duration::ZERO;
        // Cascade stage accounting, aggregated over chunks (None when the
        // cascade is off — the wire stays byte-for-byte the legacy format).
        let mut cascade_info: Option<CascadeInfo> = None;

        for chunk in chunks {
            let mut rng = Pcg64::substream(seed, chunk.chunk_index as u64, REFINE_LANE);
            let mut tokens = if self.cascade.is_off() {
                // Legacy path: one uninterrupted engine-resident segment.
                let params = SamplerParams {
                    artifact: chunk.meta.name.clone(),
                    steps_cold: key.steps_cold,
                    t0,
                    warp_mode: key.warp_mode(),
                };
                let t_refine = Instant::now();
                let out = sample_warm_with_scratch(
                    self.exec,
                    &params,
                    chunk.init,
                    &mut rng,
                    false,
                    &mut self.scratch.borrow_mut(),
                )?;
                let seg_elapsed = t_refine.elapsed();
                refine_time += seg_elapsed;
                self.metrics.obs.span(
                    0,
                    bundle_id,
                    SpanKind::RefineSegment,
                    0,
                    t_refine,
                    seg_elapsed,
                );
                nfe = out.nfe; // same schedule for every chunk in the bundle
                debug_assert!(out.nfe <= nfe_budget, "NFE guarantee floor violated");
                self.metrics.nfe_saved.add(nfe_budget.saturating_sub(out.nfe) as u64);
                self.metrics.denoiser_calls.add(out.nfe as u64);
                self.metrics.batches_executed.inc();
                self.metrics.padded_rows.add((out.tokens.batch - chunk.chunk_len) as u64);
                out.tokens
            } else {
                // Cascade path: the same run split into ladder segments,
                // with optional quality gates between them. The run seed
                // draw matches the legacy path exactly (`sample_warm`
                // draws one u64), so `fixed` mode is bitwise-identical.
                let plan = self.cascade.plan(key.steps_cold, t0, &chunk.meta.name);
                let run_seed = rng.next_u64();
                let warp = key.warp_mode().warp_factor(t0) as f32;
                let mut init = chunk.init;
                crate::sampler::dfm::check_shape(
                    chunk.meta.batch,
                    chunk.meta.seq_len,
                    &chunk.meta.name,
                    &init,
                )?;
                let t_refine = Instant::now();
                let outcome = cascade::run_segments(
                    self.exec,
                    &plan,
                    key.steps_cold,
                    t0,
                    warp,
                    run_seed,
                    &mut init.tokens,
                    chunk.chunk_len,
                    chunk.meta.seq_len,
                    chunk.meta.vocab,
                    self.cascade.gate_threshold(),
                    &mut self.scratch.borrow_mut(),
                )?;
                refine_time += t_refine.elapsed();
                let total = outcome.total_nfe();
                nfe = nfe.max(total); // chunks may gate out at different stages
                debug_assert!(total <= nfe_budget, "NFE guarantee floor violated");
                self.metrics.nfe_saved.add(nfe_budget.saturating_sub(total) as u64);
                if outcome.early_exit {
                    self.metrics.cascade_early_exits.inc();
                }
                for (si, stage) in outcome.stages.iter().enumerate() {
                    self.metrics.cascade_stage_nfe.record(stage.nfe as f64);
                    self.metrics.obs.span(
                        0,
                        bundle_id,
                        SpanKind::RefineSegment,
                        si as u32,
                        t_refine,
                        stage.elapsed,
                    );
                    if let Some(d) = stage.gate_eval {
                        self.metrics.gate_eval.record(d);
                        self.metrics.obs.span(
                            0,
                            bundle_id,
                            SpanKind::GateEval,
                            si as u32,
                            t_refine,
                            d,
                        );
                        gate_us.push(d.as_micros() as u64);
                    }
                }
                let info = cascade_info.get_or_insert(CascadeInfo {
                    stages_used: 0,
                    nfe_per_stage: Vec::new(),
                    early_exit: false,
                });
                if outcome.stages_used() > info.stages_used {
                    info.stages_used = outcome.stages_used();
                    info.nfe_per_stage = outcome.stages.iter().map(|s| s.nfe).collect();
                    seg_timing = outcome
                        .stages
                        .iter()
                        .map(|s| (s.nfe, s.elapsed.as_micros() as u64))
                        .collect();
                    if let Some(rec) = record.as_mut() {
                        rec.gate_scores = outcome.stages.iter().filter_map(|s| s.score).collect();
                    }
                }
                info.early_exit |= outcome.early_exit;
                if outcome.early_exit {
                    // The exiting chunk's last gate score is the
                    // auditor's witness that the exit was earned.
                    if let Some(rec) = record.as_mut() {
                        if rec.exit_score.is_none() {
                            rec.exit_score = outcome.stages.last().and_then(|s| s.score);
                        }
                    }
                }
                self.metrics.denoiser_calls.add(total as u64);
                self.metrics.batches_executed.inc();
                self.metrics.padded_rows.add((init.batch - chunk.chunk_len) as u64);
                init
            };
            tokens.truncate(chunk.chunk_len); // strip padding — never leaks out
            for r in 0..chunk.chunk_len {
                rows.push(tokens.row(r).to_vec());
            }
        }
        debug_assert_eq!(rows.len(), n_total);

        // Scatter rows back to requests in FIFO order.
        let total_time = started.elapsed();
        let now = Instant::now();
        if self.cascade.is_off() {
            // Single-segment path: one breakdown entry covering the whole
            // refine loop (summed over chunks, like `refine_time`).
            seg_timing = vec![(nfe, refine_time.as_micros() as u64)];
        }
        let timing_proto = want_timing.then(|| TimingInfo {
            nfe_floor: nfe_budget,
            segments: seg_timing,
            gate_us,
            replicas: Vec::new(), // filled from the scope trail by the wrapper
            reroutes: 0,
        });
        if let Some(rec) = record.as_mut() {
            rec.nfe = nfe;
            if let Some(info) = &cascade_info {
                rec.nfe_per_stage = info.nfe_per_stage.clone();
                rec.early_exit = info.early_exit;
            }
        }
        let mut responses = Vec::with_capacity(bundle.requests.len());
        let mut cursor = 0;
        for (ri, req) in bundle.requests.iter().enumerate() {
            let samples = rows[cursor..cursor + req.n_samples].to_vec();
            cursor += req.n_samples;
            if let Some(rec) = record.as_mut() {
                rec.requests[ri].out_hash = crate::obs::ledger::hash_samples(&samples);
            }
            responses.push(GenResponse {
                id: req.id,
                samples,
                nfe,
                t0_used: t0,
                cascade: cascade_info.clone(),
                queue_wait: now.saturating_duration_since(req.submitted).saturating_sub(total_time),
                draft_time,
                refine_time,
                total_time,
                degraded: None,
                timing: if req.timing { timing_proto.clone() } else { None },
            });
            self.metrics.requests_completed.inc();
            self.metrics.samples.record(req.n_samples as u64);
        }
        self.metrics.batch_exec.record(total_time);
        Ok((responses, record))
    }

    /// Execute one bundle serially (DRAFT then REFINE on the calling
    /// thread), producing one response per request (same order).
    pub fn run_bundle(&self, bundle: WorkBundle) -> Result<Vec<GenResponse>> {
        self.refine_bundle(self.draft_bundle(bundle)?)
    }

    /// Convenience for single local requests (CLI `wsfm generate`).
    pub fn run_single(&self, req: GenRequest) -> Result<GenResponse> {
        req.validate()?;
        let key = req.bundle_key();
        let bundle = WorkBundle::new(key, vec![req]);
        let mut rs = self.run_bundle(bundle)?;
        Ok(rs.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::{mock_manifest, request, TestExec};
    use std::sync::atomic::Ordering;

    #[test]
    fn bundle_scatters_rows_in_order() {
        let exec = TestExec::drift(vec![1, 4, 8], 3, 4, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4, 8], 3, 4);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let reqs = vec![request(1, 2), request(2, 3), request(3, 1)];
        let key = reqs[0].bundle_key();
        let bundle = WorkBundle::new(key, reqs);
        let responses = sched.run_bundle(bundle).unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(responses[0].samples.len(), 2);
        assert_eq!(responses[1].samples.len(), 3);
        assert_eq!(responses[2].samples.len(), 1);
        // Everything converged to token 1 (drift target); padding stripped.
        for r in &responses {
            for s in &r.samples {
                assert_eq!(s.len(), 3);
                assert!(s.iter().all(|&t| t == 1));
            }
        }
        // NFE guarantee: t0=0.5, steps_cold=10 -> 5.
        assert_eq!(responses[0].nfe, 5);
        assert_eq!(metrics.requests_completed.get(), 3);
        assert!(metrics.padded_rows.get() <= 8);
    }

    #[test]
    fn single_request_roundtrip() {
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let resp = sched.run_single(request(9, 1)).unwrap();
        assert_eq!(resp.id, 9);
        assert_eq!(resp.samples.len(), 1);
        assert_eq!(resp.nfe, 5);
    }

    #[test]
    fn large_request_splits_into_chunks() {
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let resp = sched.run_single(request(1, 9)).unwrap();
        assert_eq!(resp.samples.len(), 9);
        // 9 = 4 + 4 + 1 -> 3 chunks x 5 NFE each.
        assert_eq!(exec.steps.load(Ordering::SeqCst), 15);
        assert_eq!(metrics.batches_executed.get(), 3);
    }

    #[test]
    fn missing_artifacts_error() {
        let exec = TestExec::drift(vec![1], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let mut r = request(1, 1);
        r.tag = "ws_t099".into();
        assert!(sched.run_single(r).is_err());
    }

    #[test]
    fn draft_models_are_cached_per_domain_spec_batch() {
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        // 9 samples plan as 4+4+1: two distinct batch sizes -> two cache
        // entries, but the second b4 chunk reuses the first resolution.
        sched.run_single(request(1, 9)).unwrap();
        assert_eq!(metrics.draft_calls.get(), 3);
        assert_eq!(metrics.draft_models_resolved.get(), 2);
        // A whole second bundle re-resolves nothing.
        sched.run_single(request(2, 9)).unwrap();
        assert_eq!(metrics.draft_calls.get(), 6);
        assert_eq!(metrics.draft_models_resolved.get(), 2);
    }

    #[test]
    fn drafted_bundle_exposes_phase_boundary() {
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let bundle = WorkBundle::new(request(1, 5).bundle_key(), vec![request(1, 5)]);
        let drafted = sched.draft_bundle(bundle).unwrap();
        // 5 = 4 + 1 chunks; init tokens exist but no denoiser ran yet.
        assert_eq!(drafted.chunks.len(), 2);
        assert_eq!(drafted.chunks[0].init.batch, 4);
        assert_eq!(drafted.chunks[1].init.batch, 1);
        assert_eq!(exec.steps.load(Ordering::SeqCst), 0);
        let responses = sched.refine_bundle(drafted).unwrap();
        assert_eq!(responses[0].samples.len(), 5);
        assert!(exec.steps.load(Ordering::SeqCst) > 0);
    }

    #[test]
    fn bundle_seed_is_stateless_and_seed_sensitive() {
        let mk = |config_seed: u64, req_seed: u64| {
            let mut r = request(1, 2);
            r.seed = req_seed;
            bundle_seed(config_seed, &WorkBundle::new(r.bundle_key(), vec![r]))
        };
        assert_eq!(mk(0, 7), mk(0, 7));
        assert_ne!(mk(0, 7), mk(0, 8));
        assert_ne!(mk(0, 7), mk(1, 7));
        // Request id/timestamps don't participate: two requests differing
        // only by id hash identically.
        let mut a = request(1, 2);
        a.seed = 3;
        let mut b = request(99, 2);
        b.seed = 3;
        assert_eq!(
            bundle_seed(5, &WorkBundle::new(a.bundle_key(), vec![a])),
            bundle_seed(5, &WorkBundle::new(b.bundle_key(), vec![b])),
        );
    }

    #[test]
    fn adaptive_controller_respects_nfe_floor_and_records_metrics() {
        use crate::config::ControlConfig;
        use crate::control::Controller;
        for mode in ["prior", "scored"] {
            let exec = TestExec::drift(vec![1, 4, 8], 3, 8, 1);
            let manifest = mock_manifest(&["cold"], &[1, 4, 8], 3, 8);
            let metrics = ServingMetrics::default();
            let cfg = ControlConfig { mode: mode.into(), ..ControlConfig::default() };
            let controller = Controller::from_config(&cfg).unwrap();
            let sched = Scheduler::with_controller(&exec, &manifest, &metrics, 0, controller);
            let resp = sched.run_single(request(1, 4)).unwrap();
            // request() asks t0=0.5, steps_cold=10. The guarantee floor:
            // adaptive never exceeds the static-t0_min budget
            // guaranteed_nfe(10, 0.35) = 7 — regardless of what the
            // proxies scored.
            assert!(resp.nfe <= 7, "{mode}: nfe {} > floor budget 7", resp.nfe);
            assert!(resp.nfe >= 1);
            assert!(
                (0.35..=0.95).contains(&resp.t0_used),
                "{mode}: t0_used {} outside [t0_min, t0_max]",
                resp.t0_used
            );
            assert_eq!(metrics.chosen_t0.snapshot().count, 1);
            let saved_per_chunk = 7 - resp.nfe;
            assert_eq!(metrics.nfe_saved.get(), saved_per_chunk as u64);
        }
    }

    #[test]
    fn adaptive_t0_clamps_up_to_artifact_trained_range() {
        use crate::config::ControlConfig;
        use crate::control::Controller;
        // A WS artifact trained at t0 = 0.8 must never be evaluated below
        // t = 0.8 by an adaptive choice (out-of-distribution times); the
        // decision clamps up to the trained floor. Static mode is exempt
        // (client picked tag and t0 together).
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let mut manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        for a in &mut manifest.artifacts {
            a.t0 = Some(0.8);
        }
        let metrics = ServingMetrics::default();
        // Prior mode + noise draft scores 0 -> would pick the 0.35 floor
        // without the clamp.
        let cfg = ControlConfig { mode: "prior".into(), ..ControlConfig::default() };
        let controller = Controller::from_config(&cfg).unwrap();
        let sched = Scheduler::with_controller(&exec, &manifest, &metrics, 0, controller);
        let resp = sched.run_single(request(1, 2)).unwrap();
        assert_eq!(resp.t0_used, 0.8);
        assert_eq!(resp.nfe, 2); // guaranteed_nfe(10, 0.8)

        // Static mode on the same artifacts keeps the requested t0.
        let metrics2 = ServingMetrics::default();
        let sched2 = Scheduler::new(&exec, &manifest, &metrics2, 0);
        assert_eq!(sched2.run_single(request(1, 2)).unwrap().t0_used, 0.5);
    }

    #[test]
    fn static_controller_reports_requested_t0_and_saves_nothing() {
        let exec = TestExec::drift(vec![1, 4], 2, 3, 1);
        let manifest = mock_manifest(&["cold"], &[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics, 0);
        let resp = sched.run_single(request(1, 2)).unwrap();
        assert_eq!(resp.t0_used, 0.5); // the request's own t0
        assert_eq!(resp.nfe, 5);
        assert_eq!(metrics.nfe_saved.get(), 0, "static mode saves nothing by definition");
        assert_eq!(metrics.chosen_t0.snapshot().count, 1);
        assert_eq!(metrics.chosen_t0.snapshot().max, 0.5);
    }

    #[test]
    fn scored_controller_is_deterministic_across_scheduler_instances() {
        use crate::config::ControlConfig;
        use crate::control::Controller;
        // The controller extends the determinism contract: (t0 choice,
        // tokens) depend only on (config seed, bundle) — fresh scheduler,
        // fresh caches, same decision.
        let run = |config_seed: u64| {
            let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
            let metrics = ServingMetrics::default();
            let cfg = ControlConfig { mode: "scored".into(), ..ControlConfig::default() };
            let controller = Controller::from_config(&cfg).unwrap();
            let sched =
                Scheduler::with_controller(&exec, &manifest, &metrics, config_seed, controller);
            let reqs = vec![request(1, 3), request(2, 2)];
            let bundle = WorkBundle::new(reqs[0].bundle_key(), reqs);
            sched
                .run_bundle(bundle)
                .unwrap()
                .into_iter()
                .map(|r| (r.t0_used, r.samples))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
    }

    #[test]
    fn cascade_fixed_is_bitwise_identical_to_off_and_tiles_the_budget() {
        use crate::cascade::Cascade;
        use crate::config::CascadeConfig;
        let run = |mode: &str| {
            let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
            let metrics = ServingMetrics::default();
            let cascade = Cascade::from_config(&CascadeConfig {
                mode: mode.into(),
                ..CascadeConfig::default()
            })
            .unwrap();
            let sched = Scheduler::with_policies(
                &exec,
                &manifest,
                &metrics,
                9,
                Controller::static_default(),
                cascade,
            );
            let reqs = vec![request(1, 3), request(2, 2)];
            let bundle = WorkBundle::new(reqs[0].bundle_key(), reqs);
            sched.run_bundle(bundle).unwrap()
        };
        let off = run("off");
        let fixed = run("fixed");
        assert_eq!(off.len(), fixed.len());
        for (a, b) in off.iter().zip(&fixed) {
            // Split == unsplit, end to end through the scheduler.
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.nfe, b.nfe);
            assert_eq!(a.t0_used, b.t0_used);
            // Off stays wire-invisible; fixed reports its stage tiling.
            assert!(a.cascade.is_none());
            let info = b.cascade.as_ref().unwrap();
            // Default ladder [0.75, 0.9] over t0=0.5 / 10 cold steps:
            // segments of 3 + 1 + 1 evaluations.
            assert_eq!(info.stages_used, 3);
            assert_eq!(info.nfe_per_stage, vec![3, 1, 1]);
            assert!(!info.early_exit);
            assert_eq!(info.nfe_per_stage.iter().sum::<usize>(), b.nfe);
        }
    }

    #[test]
    fn gated_cascade_exits_early_within_the_guarantee() {
        use crate::cascade::Cascade;
        use crate::config::CascadeConfig;
        use crate::core::schedule::guaranteed_nfe;
        let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
        let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
        let metrics = ServingMetrics::default();
        // Threshold 0: the first gate always passes — the deterministic
        // early-exit scenario.
        let cascade = Cascade::from_config(&CascadeConfig {
            mode: "gated".into(),
            gate_threshold: 0.0,
            ..CascadeConfig::default()
        })
        .unwrap();
        let sched = Scheduler::with_policies(
            &exec,
            &manifest,
            &metrics,
            9,
            Controller::static_default(),
            cascade,
        );
        let resp = sched.run_single(request(1, 4)).unwrap();
        let info = resp.cascade.as_ref().unwrap();
        assert!(info.early_exit);
        assert_eq!(info.stages_used, 1);
        assert_eq!(info.nfe_per_stage, vec![3]);
        assert_eq!(resp.nfe, 3);
        // The guarantee: early exit only ever *saves* against the budget.
        assert!(resp.nfe <= guaranteed_nfe(10, 0.5));
        assert_eq!(metrics.nfe_saved.get(), 2);
        assert_eq!(metrics.cascade_early_exits.get(), 1);
        assert_eq!(metrics.cascade_stage_nfe.snapshot().count, 1);
        assert!(metrics.gate_eval.snapshot().count >= 1);
    }

    #[test]
    fn cascade_under_adaptive_controller_keeps_the_floor_budget() {
        use crate::cascade::Cascade;
        use crate::config::{CascadeConfig, ControlConfig};
        use crate::core::schedule::guaranteed_nfe;
        // Every cascade mode × the scored controller: summed per-stage
        // NFE never exceeds guaranteed_nfe(steps_cold, t0_min) — the
        // paper's floor, with both adaptivity layers stacked.
        for mode in ["off", "fixed", "gated"] {
            let exec = TestExec::stochastic(vec![1, 4, 8], 3, 8, 1);
            let manifest = mock_manifest(&["cold"], &[1, 4, 8], 3, 8);
            let metrics = ServingMetrics::default();
            let controller = Controller::from_config(&ControlConfig {
                mode: "scored".into(),
                ..ControlConfig::default()
            })
            .unwrap();
            let cascade = Cascade::from_config(&CascadeConfig {
                mode: mode.into(),
                ..CascadeConfig::default()
            })
            .unwrap();
            let sched =
                Scheduler::with_policies(&exec, &manifest, &metrics, 0, controller, cascade);
            let resp = sched.run_single(request(1, 4)).unwrap();
            let floor = guaranteed_nfe(10, 0.35); // t0_min default
            assert!(resp.nfe <= floor, "{mode}: nfe {} > floor {floor}", resp.nfe);
            if let Some(info) = &resp.cascade {
                assert_eq!(info.nfe_per_stage.iter().sum::<usize>(), resp.nfe, "{mode}");
                assert!(info.stages_used >= 1);
            } else {
                assert_eq!(mode, "off");
            }
        }
    }

    #[test]
    fn identical_bundles_sample_identically_across_scheduler_instances() {
        // The determinism contract at scheduler level: a fresh scheduler
        // (fresh caches, fresh scratch) produces bitwise-identical tokens
        // for the same (config seed, bundle) — the property pipelining
        // relies on, since any stage thread may run any bundle.
        let run = |config_seed: u64| {
            let exec = TestExec::stochastic(vec![1, 4], 4, 5, 2);
            let manifest = mock_manifest(&["cold"], &[1, 4], 4, 5);
            let metrics = ServingMetrics::default();
            let sched = Scheduler::new(&exec, &manifest, &metrics, config_seed);
            let reqs = vec![request(1, 3), request(2, 2)];
            let bundle = WorkBundle::new(reqs[0].bundle_key(), reqs);
            sched
                .run_bundle(bundle)
                .unwrap()
                .into_iter()
                .map(|r| r.samples)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
