//! The two-phase scheduler: DRAFT → REFINE over one flushed bundle.
//!
//! For a bundle of `n` total samples it plans executor chunks over the
//! compiled batch shapes ([`crate::runtime::pool`]), generates draft
//! samples for each chunk (LSTM/PCA artifact, two-moons mixture, or
//! uniform noise), runs the warm-start Euler loop, strips batch padding,
//! and scatters rows back to the originating requests in FIFO order.

use crate::coordinator::batcher::WorkBundle;
use crate::coordinator::request::{DraftSpec, GenRequest, GenResponse};
use crate::core::rng::Pcg64;
use crate::draft::{Draft, DraftNoise, HloDraft, MixtureDraft, NoiseDraft};
use crate::metrics::ServingMetrics;
use crate::runtime::engine::{Executor, LoopScratch};
use crate::runtime::{plan_chunks, Manifest};
use crate::sampler::dfm::{sample_warm_with_scratch, SamplerParams};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::time::{Duration, Instant};

/// Executes bundles against an [`Executor`].
///
/// The refinement loop runs engine-resident (`Executor::run_loop`): one
/// engine round-trip per executor chunk, not per Euler step. `scratch` is
/// the loop staging buffer reused across bundles for in-process executors
/// (the production [`crate::runtime::EngineHandle`] keeps its own per
/// artifact on the engine thread); a `RefCell` because the scheduler runs
/// on a single coordinator thread.
pub struct Scheduler<'a> {
    pub exec: &'a dyn Executor,
    pub manifest: &'a Manifest,
    pub metrics: &'a ServingMetrics,
    scratch: RefCell<LoopScratch>,
}

impl<'a> Scheduler<'a> {
    pub fn new(exec: &'a dyn Executor, manifest: &'a Manifest, metrics: &'a ServingMetrics) -> Self {
        Scheduler { exec, manifest, metrics, scratch: RefCell::new(LoopScratch::default()) }
    }

    /// Resolve the draft model for a bundle at a given compiled batch size.
    fn draft_for(&self, key_domain: &str, spec: DraftSpec, batch: usize, vocab: usize) -> Result<Box<dyn Draft + 'a>> {
        Ok(match spec {
            DraftSpec::Noise => Box::new(NoiseDraft { vocab }),
            DraftSpec::Mixture(kind) => Box::new(MixtureDraft { draft_kind: kind }),
            DraftSpec::Lstm => {
                let meta = self.manifest.find_draft(key_domain, "lstm", batch)?;
                Box::new(HloDraft::new(self.exec, meta.name.clone(), DraftNoise::Gumbel))
            }
            DraftSpec::Pca => {
                let meta = self.manifest.find_draft(key_domain, "pca", batch)?;
                Box::new(HloDraft::new(self.exec, meta.name.clone(), DraftNoise::Gaussian))
            }
        })
    }

    /// Execute one bundle, producing one response per request (same order).
    pub fn run_bundle(&self, bundle: &WorkBundle, rng: &mut Pcg64) -> Result<Vec<GenResponse>> {
        let key = &bundle.key;
        let n_total = bundle.total_samples();
        if n_total == 0 {
            bail!("empty bundle");
        }
        let compiled = self.manifest.step_batches(&key.domain, &key.tag);
        if compiled.is_empty() {
            bail!("no step artifacts for {}/{}", key.domain, key.tag);
        }
        let plan = plan_chunks(n_total, &compiled)?;
        let started = Instant::now();

        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(n_total);
        let mut nfe = 0;
        let mut draft_time = Duration::ZERO;
        let mut refine_time = Duration::ZERO;

        for &(chunk_len, exec_batch) in &plan {
            let step_meta = self.manifest.find_step(&key.domain, &key.tag, exec_batch)?;
            let (seq_len, vocab) = (step_meta.seq_len, step_meta.vocab);

            // Phase DRAFT: generate exec_batch sequences (padding rows get
            // real draft samples too — simplest shape-correct choice; they
            // are stripped below and never leave the scheduler).
            let t_draft = Instant::now();
            let draft = self.draft_for(&key.domain, key.draft, exec_batch, vocab)?;
            let init = draft
                .generate(exec_batch, seq_len, rng)
                .with_context(|| format!("draft {} for {}", draft.kind(), step_meta.name))?;
            draft_time += t_draft.elapsed();
            self.metrics.draft_calls.inc();

            // Phase REFINE: the warm-start Euler loop.
            let params = SamplerParams {
                artifact: step_meta.name.clone(),
                steps_cold: key.steps_cold,
                t0: key.t0(),
                warp_mode: key.warp_mode(),
            };
            let t_refine = Instant::now();
            let out = sample_warm_with_scratch(
                self.exec,
                &params,
                init,
                rng,
                false,
                &mut self.scratch.borrow_mut(),
            )?;
            refine_time += t_refine.elapsed();
            nfe = out.nfe; // same schedule for every chunk in the bundle
            self.metrics.denoiser_calls.add(out.nfe as u64);
            self.metrics.batches_executed.inc();
            self.metrics.padded_rows.add((exec_batch - chunk_len) as u64);

            let mut tokens = out.tokens;
            tokens.truncate(chunk_len); // strip padding — never leaks out
            for r in 0..chunk_len {
                rows.push(tokens.row(r).to_vec());
            }
        }
        debug_assert_eq!(rows.len(), n_total);

        // Scatter rows back to requests in FIFO order.
        let total_time = started.elapsed();
        let now = Instant::now();
        let mut responses = Vec::with_capacity(bundle.requests.len());
        let mut cursor = 0;
        for req in &bundle.requests {
            let samples = rows[cursor..cursor + req.n_samples].to_vec();
            cursor += req.n_samples;
            responses.push(GenResponse {
                id: req.id,
                samples,
                nfe,
                queue_wait: now.saturating_duration_since(req.submitted).saturating_sub(total_time),
                draft_time,
                refine_time,
                total_time,
            });
            self.metrics.requests_completed.inc();
            self.metrics.samples.record(req.n_samples as u64);
        }
        self.metrics.batch_exec.record(total_time);
        Ok(responses)
    }

    /// Convenience for single local requests (CLI `wsfm generate`).
    pub fn run_single(&self, req: GenRequest, rng: &mut Pcg64) -> Result<GenResponse> {
        req.validate()?;
        let key = req.bundle_key();
        let bundle = WorkBundle { key, requests: vec![req] };
        let mut rs = self.run_bundle(&bundle, rng)?;
        Ok(rs.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::DraftSpec;
    use crate::core::schedule::WarpMode;
    use crate::runtime::artifact::{ArtifactMeta, TensorSpec};
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Mock executor emulating the step artifact family at several batch
    /// sizes; always moves tokens toward a fixed p1.
    struct MockExec {
        batches: Vec<usize>,
        seq_len: usize,
        vocab: usize,
        steps: AtomicUsize,
    }

    impl MockExec {
        fn meta_for(&self, name: &str) -> Option<ArtifactMeta> {
            // names: mock_cold_step_b{B}
            let b: usize = name.rsplit('b').next()?.parse().ok()?;
            if !self.batches.contains(&b) {
                return None;
            }
            Some(ArtifactMeta {
                name: name.to_string(),
                hlo_file: String::new(),
                domain: "mock".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: b,
                seq_len: self.seq_len,
                vocab: self.vocab,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![],
                outputs: vec![TensorSpec {
                    name: "probs".into(),
                    shape: vec![b, self.seq_len, self.vocab],
                    dtype: "f32".into(),
                }],
            })
        }
    }

    impl Executor for MockExec {
        fn step(&self, _a: &str, tokens: &[i32], _t: f32, _h: f32, _w: f32) -> Result<Vec<f32>> {
            self.steps.fetch_add(1, Ordering::SeqCst);
            // Deterministic drift: everything becomes token 1.
            let mut out = vec![0.0f32; tokens.len() * self.vocab];
            for (i, _) in tokens.iter().enumerate() {
                out[i * self.vocab + 1] = 1.0;
            }
            Ok(out)
        }
        fn draft(&self, _a: &str, _n: &[f32]) -> Result<Vec<i32>> {
            bail!("no hlo drafts in mock")
        }
        fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
            self.meta_for(artifact).context("unknown")
        }
    }

    fn mock_manifest(batches: &[usize], seq_len: usize, vocab: usize) -> Manifest {
        let artifacts = batches
            .iter()
            .map(|&b| ArtifactMeta {
                name: format!("mock_cold_step_b{b}"),
                hlo_file: String::new(),
                domain: "mock".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: b,
                seq_len,
                vocab,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![],
                outputs: vec![],
            })
            .collect();
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts,
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
        }
    }

    fn request(id: u64, n: usize) -> GenRequest {
        GenRequest {
            id,
            domain: "mock".into(),
            tag: "cold".into(),
            draft: DraftSpec::Noise,
            n_samples: n,
            t0: 0.5,
            steps_cold: 10,
            warp_mode: WarpMode::Exact,
            seed: id,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn bundle_scatters_rows_in_order() {
        let exec = MockExec { batches: vec![1, 4, 8], seq_len: 3, vocab: 4, steps: AtomicUsize::new(0) };
        let manifest = mock_manifest(&[1, 4, 8], 3, 4);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics);
        let reqs = vec![request(1, 2), request(2, 3), request(3, 1)];
        let key = reqs[0].bundle_key();
        let bundle = WorkBundle { key, requests: reqs };
        let mut rng = Pcg64::new(0);
        let responses = sched.run_bundle(&bundle, &mut rng).unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(responses[0].samples.len(), 2);
        assert_eq!(responses[1].samples.len(), 3);
        assert_eq!(responses[2].samples.len(), 1);
        // Everything converged to token 1 (drift target); padding stripped.
        for r in &responses {
            for s in &r.samples {
                assert_eq!(s.len(), 3);
                assert!(s.iter().all(|&t| t == 1));
            }
        }
        // NFE guarantee: t0=0.5, steps_cold=10 -> 5.
        assert_eq!(responses[0].nfe, 5);
        assert_eq!(metrics.requests_completed.get(), 3);
        assert!(metrics.padded_rows.get() <= 8);
    }

    #[test]
    fn single_request_roundtrip() {
        let exec = MockExec { batches: vec![1, 4], seq_len: 2, vocab: 3, steps: AtomicUsize::new(0) };
        let manifest = mock_manifest(&[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics);
        let mut rng = Pcg64::new(1);
        let resp = sched.run_single(request(9, 1), &mut rng).unwrap();
        assert_eq!(resp.id, 9);
        assert_eq!(resp.samples.len(), 1);
        assert_eq!(resp.nfe, 5);
    }

    #[test]
    fn large_request_splits_into_chunks() {
        let exec = MockExec { batches: vec![1, 4], seq_len: 2, vocab: 3, steps: AtomicUsize::new(0) };
        let manifest = mock_manifest(&[1, 4], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics);
        let mut rng = Pcg64::new(2);
        let resp = sched.run_single(request(1, 9), &mut rng).unwrap();
        assert_eq!(resp.samples.len(), 9);
        // 9 = 4 + 4 + 1 -> 3 chunks x 5 NFE each.
        assert_eq!(exec.steps.load(Ordering::SeqCst), 15);
        assert_eq!(metrics.batches_executed.get(), 3);
    }

    #[test]
    fn missing_artifacts_error() {
        let exec = MockExec { batches: vec![1], seq_len: 2, vocab: 3, steps: AtomicUsize::new(0) };
        let manifest = mock_manifest(&[1], 2, 3);
        let metrics = ServingMetrics::default();
        let sched = Scheduler::new(&exec, &manifest, &metrics);
        let mut rng = Pcg64::new(3);
        let mut r = request(1, 1);
        r.tag = "ws_t099".into();
        assert!(sched.run_single(r, &mut rng).is_err());
    }
}
