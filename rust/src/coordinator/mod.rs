//! The serving coordinator — the paper's system contribution realized as a
//! vLLM-style inference data plane (DESIGN.md §5).
//!
//! Request lifecycle:
//!
//! ```text
//! client → [request] → admission queue (bounded, backpressure)
//!        → dynamic batcher (group by bundle key, flush on size/deadline)
//!        → scheduler: phase DRAFT (lightweight model, negligible)
//!                     phase REFINE (K = ceil(steps·(1-t0)) fused steps)
//!        → per-request responses (+ NFE, timings)
//! ```
//!
//! Invariants (property-tested): no request lost or duplicated; batch
//! shapes ∈ compiled set; padding rows never leak into responses; FIFO
//! order within a bundle; NFE == the paper's guaranteed formula.

pub mod batcher;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod service;

pub use batcher::{Batcher, FlushPolicy};
pub use queue::BoundedQueue;
pub use request::{BundleKey, DraftSpec, GenRequest, GenResponse};
pub use scheduler::Scheduler;
pub use service::Service;
