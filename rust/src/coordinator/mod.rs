//! The serving coordinator — the paper's system contribution realized as a
//! vLLM-style inference data plane (DESIGN.md §5), pipelined so admission
//! is never blocked behind execution.
//!
//! Request lifecycle:
//!
//! ```text
//! client → [request] → admission queue (bounded, backpressure)
//!        → admission thread: validate, dynamic batcher (group by bundle
//!          key, flush on size/deadline) — never executes
//!        → DRAFT stage (draft_workers threads): plan executor chunks,
//!          generate warm-start init tokens (lightweight model)
//!        → REFINE stage (fleet.refine_workers threads, each driving the
//!          engine-resident Euler loop against the replicated executor
//!          fleet): K = ceil(steps·(1-t0)) fused steps per chunk
//!        → per-request responses (+ NFE, timings)
//! ```
//!
//! Stages are connected by bounded channels and an inflight gate capped at
//! `pipeline_depth` bundles, so drafting bundle N+1 overlaps refining
//! bundle N and deadline flushes proceed while the engine is busy. With
//! `fleet.refine_workers >= 2` over a multi-replica [`crate::fleet`],
//! independent bundles also refine concurrently on distinct engine
//! replicas. `pipeline_depth = 1` collapses to the serial path (the
//! admission thread runs bundles inline). All bundle RNG derives
//! statelessly from `(config.seed, bundle key, request seeds)` — outputs
//! are bitwise-identical across pipeline *and fleet* settings
//! ([`scheduler`]).
//!
//! Invariants (property-tested): no request lost or duplicated; batch
//! shapes ∈ compiled set; padding rows never leak into responses; FIFO
//! order within a bundle; NFE == the paper's guaranteed formula.

pub mod batcher;
pub mod composer;
pub mod queue;
pub mod replay;
pub mod request;
pub mod scheduler;
pub mod service;

pub use batcher::{Batcher, FlushPolicy, WorkBundle};
pub use composer::ComposedRefiner;
pub use queue::BoundedQueue;
pub use request::{BundleKey, DraftSpec, GenRequest, GenResponse};
pub use scheduler::{DraftedBundle, DraftedChunk, Scheduler};
pub use service::Service;

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared mock executor + manifest for coordinator/server tests: a
    //! drift denoiser over `mock_{tag}_step_b{B}` artifact families, with
    //! optional stochastic spread, per-step sleep, and a gate that blocks
    //! refinement of "slow"-tagged artifacts until released (for the
    //! pipeline-overlap tests).

    use crate::coordinator::request::{DraftSpec, GenRequest};
    use crate::core::schedule::WarpMode;
    use crate::runtime::artifact::{ArtifactMeta, TensorSpec};
    use crate::runtime::engine::Executor;
    use crate::util::json::Json;
    use anyhow::{bail, Context, Result};
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// Controls for gating a [`TestExec`]'s "slow" artifacts.
    #[derive(Debug, Default)]
    pub struct GateCtl {
        /// Set by the executor when a gated refinement step begins.
        pub started: AtomicBool,
        /// Set by the test to let gated steps proceed.
        pub release: AtomicBool,
    }

    /// Mock executor emulating the `mock_{tag}_step_b{B}` step-artifact
    /// family: a denoiser drifting every position toward `target`.
    pub struct TestExec {
        pub batches: Vec<usize>,
        pub seq_len: usize,
        pub vocab: usize,
        /// Drift target token.
        pub target: usize,
        /// 0.0 = fully deterministic drift; >0 spreads that fraction of
        /// the moving mass uniformly (makes sampling seed-sensitive).
        pub spread: f32,
        /// Artificial per-step cost (throughput/backpressure tests).
        pub step_sleep: Duration,
        pub steps: AtomicUsize,
        /// When set, steps on artifacts whose name contains "slow" block
        /// until `gate.release` (bounded at 10 s to avoid hangs).
        pub gate: Option<Arc<GateCtl>>,
    }

    impl TestExec {
        pub fn drift(batches: Vec<usize>, seq_len: usize, vocab: usize, target: usize) -> Self {
            TestExec {
                batches,
                seq_len,
                vocab,
                target,
                spread: 0.0,
                step_sleep: Duration::ZERO,
                steps: AtomicUsize::new(0),
                gate: None,
            }
        }

        pub fn stochastic(batches: Vec<usize>, seq_len: usize, vocab: usize, target: usize) -> Self {
            TestExec { spread: 0.5, ..TestExec::drift(batches, seq_len, vocab, target) }
        }
    }

    impl Executor for TestExec {
        fn step_into(
            &self,
            artifact: &str,
            tokens: &[i32],
            t: f32,
            h: f32,
            warp: f32,
            out: &mut Vec<f32>,
        ) -> Result<()> {
            self.steps.fetch_add(1, Ordering::SeqCst);
            if let Some(gate) = &self.gate {
                if artifact.contains("slow") {
                    gate.started.store(true, Ordering::SeqCst);
                    let deadline = Instant::now() + Duration::from_secs(10);
                    while !gate.release.load(Ordering::SeqCst) {
                        if Instant::now() > deadline {
                            bail!("gated step never released");
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            if !self.step_sleep.is_zero() {
                std::thread::sleep(self.step_sleep);
            }
            let coef = (h * warp / (1.0 - t).max(1e-6)).min(1.0);
            out.clear();
            out.reserve(tokens.len() * self.vocab);
            for &tok in tokens {
                for j in 0..self.vocab {
                    let stay = if j as i32 == tok { 1.0 - coef } else { 0.0 };
                    let pull = if j == self.target { coef * (1.0 - self.spread) } else { 0.0 };
                    out.push(stay + pull + coef * self.spread / self.vocab as f32);
                }
            }
            Ok(())
        }

        fn draft(&self, _a: &str, _n: &[f32]) -> Result<Vec<i32>> {
            bail!("no hlo drafts in mock")
        }

        fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
            // names: mock_{tag}_step_b{B}
            let b: usize = artifact.rsplit('b').next().context("bad name")?.parse()?;
            if !self.batches.contains(&b) {
                bail!("unknown batch {b}");
            }
            Ok(ArtifactMeta {
                name: artifact.to_string(),
                hlo_file: String::new(),
                domain: "mock".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: b,
                seq_len: self.seq_len,
                vocab: self.vocab,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![],
                outputs: vec![TensorSpec {
                    name: "probs".into(),
                    shape: vec![b, self.seq_len, self.vocab],
                    dtype: "f32".into(),
                }],
                content_hash: None,
            })
        }
    }

    /// A manifest with step artifacts for every `(tag, batch)` pair.
    pub fn mock_manifest(
        tags: &[&str],
        batches: &[usize],
        seq_len: usize,
        vocab: usize,
    ) -> crate::runtime::Manifest {
        let mut artifacts = Vec::new();
        for &tag in tags {
            for &b in batches {
                artifacts.push(ArtifactMeta {
                    name: format!("mock_{tag}_step_b{b}"),
                    hlo_file: String::new(),
                    domain: "mock".into(),
                    kind: "step".into(),
                    tag: tag.into(),
                    draft: None,
                    batch: b,
                    seq_len,
                    vocab,
                    t0: Some(0.0),
                    latent_dim: None,
                    inputs: vec![],
                    outputs: vec![],
                    content_hash: None,
                });
            }
        }
        crate::runtime::Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts,
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
            schema_version: 1,
        }
    }

    /// A mock-domain request (tag "cold", noise draft, t0 0.5, 10 cold
    /// steps, seed = id).
    pub fn request(id: u64, n: usize) -> GenRequest {
        GenRequest {
            id,
            domain: "mock".into(),
            tag: "cold".into(),
            draft: DraftSpec::Noise,
            n_samples: n,
            t0: 0.5,
            steps_cold: 10,
            warp_mode: WarpMode::Exact,
            seed: id,
            timing: false,
            submitted: Instant::now(),
        }
    }
}
