//! Adaptive warm-start controller: per-bundle `t0` from draft quality.
//!
//! The paper's guaranteed speed-up is `1/(1-t0)`, but a single static
//! `t0` treats every draft the same: a good draft wastes refinement
//! budget it does not need, a poor one gets too little. This subsystem
//! estimates draft quality per bundle with cheap proxies and maps it to
//! a `t0` from a discrete grid, **clamped to `[t0_min, t0_max]`** so the
//! guarantee keeps a hard floor: in any adaptive mode a bundle never
//! pays more than `guaranteed_nfe(steps_cold, t0_min)` evaluations —
//! the static-`t0_min` budget (pinned by scheduler tests and the
//! Table 1 adaptive rows).
//!
//! Three modes ([`ControllerMode`], `config.control.mode`):
//!
//! * `static` — use the request's `t0` verbatim (legacy behaviour, the
//!   default; bitwise-identical to the pre-controller stack).
//! * `prior` — `t0` from the draft-model kind alone ([`prior_score`]):
//!   no per-bundle work, coarse but free.
//! * `scored` — `t0` from proxy scores computed on the drafted batch
//!   itself ([`proxy_score`]): the better of an n-gram self-consistency
//!   score ([`ngram_score`], via [`crate::eval::ngram::NgramLM`]) and an
//!   adjacent-position correlation energy score ([`energy_score`]).
//!
//! ## Determinism contract
//!
//! The decision is a **pure function of (bundle contents, config)**: the
//! draft tokens it scores derive statelessly from the bundle seed
//! (`coordinator::scheduler::bundle_seed`), and scoring itself performs
//! no RNG draws and no iteration over unordered containers. Outputs
//! therefore stay bitwise-identical across `pipeline_depth`,
//! `draft_workers`, and the serial path — the same contract the
//! pipelined coordinator established, extended to the controller
//! (pinned by `outputs_bitwise_identical_across_pipeline_settings`).
//!
//! ## Calibration
//!
//! Raw proxy scores compress into roughly `[0, 0.5]`; the optional
//! calibration table (`wsfm selfcheck --calibrate`,
//! [`calibrate_two_moons`]) scores reference draft batches with a fixed
//! seed and derives `(min_score, t0)` thresholds at the midpoints
//! between quality bands, so each band lands on its intended grid value
//! instead of the linear default. See EXPERIMENTS.md §Control.

use crate::config::ControlConfig;
use crate::coordinator::request::DraftSpec;
use crate::core::rng::Pcg64;
use crate::core::schedule::guaranteed_nfe;
use crate::data::two_moons::{self, DraftKind};
use crate::eval::ngram::NgramLM;
use anyhow::Result;

/// How the per-bundle `t0` is chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControllerMode {
    /// Use the request's `t0` verbatim (legacy behaviour).
    Static,
    /// Map the draft-model kind's prior score onto the grid.
    Prior,
    /// Map a proxy score of the drafted batch onto the grid.
    Scored,
}

impl ControllerMode {
    pub fn parse(s: &str) -> Result<ControllerMode> {
        match s {
            "static" => Ok(ControllerMode::Static),
            "prior" => Ok(ControllerMode::Prior),
            "scored" => Ok(ControllerMode::Scored),
            _ => anyhow::bail!("unknown control mode {s:?} (static|prior|scored)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ControllerMode::Static => "static",
            ControllerMode::Prior => "prior",
            ControllerMode::Scored => "scored",
        }
    }
}

/// The controller's choice for one bundle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControlDecision {
    /// The `t0` the refinement schedule actually runs with.
    pub t0: f64,
    /// The proxy score that produced it (`None` in static mode).
    pub score: Option<f64>,
}

/// The per-bundle t0 controller. Cheap to clone (pure data); each
/// scheduler instance owns one.
#[derive(Debug, Clone)]
pub struct Controller {
    mode: ControllerMode,
    t0_min: f64,
    t0_max: f64,
    /// Ascending, deduped, clamped into `[t0_min, t0_max]`; never empty.
    grid: Vec<f64>,
    /// `(min_score, t0)` sorted by `min_score` descending; first entry
    /// whose threshold the score reaches wins. Empty = linear grid map.
    calibration: Vec<(f64, f64)>,
}

impl Controller {
    /// The legacy behaviour: every bundle runs at its requested `t0`.
    pub fn static_default() -> Controller {
        Controller::from_config(&ControlConfig::default()).expect("default config is valid")
    }

    /// Build from a (validated) [`ControlConfig`]. Non-finite grid or
    /// calibration entries are dropped defensively (`config::validate`
    /// rejects them; direct callers may skip validation).
    pub fn from_config(cfg: &ControlConfig) -> Result<Controller> {
        let mode = ControllerMode::parse(&cfg.mode)?;
        if !cfg.t0_min.is_finite() || !cfg.t0_max.is_finite() || cfg.t0_min > cfg.t0_max {
            anyhow::bail!("control: need t0_min <= t0_max, got [{}, {}]", cfg.t0_min, cfg.t0_max);
        }
        let mut grid: Vec<f64> = cfg
            .grid
            .iter()
            .filter(|g| g.is_finite())
            .map(|&g| g.clamp(cfg.t0_min, cfg.t0_max))
            .collect();
        grid.sort_by(|a, b| a.partial_cmp(b).expect("finite grid has no NaN"));
        grid.dedup();
        if grid.is_empty() {
            anyhow::bail!("control.grid must be non-empty");
        }
        let mut calibration: Vec<(f64, f64)> = cfg
            .calibration
            .iter()
            .copied()
            .filter(|&(s, t)| s.is_finite() && t.is_finite())
            .collect();
        calibration.sort_by(|a, b| b.0.partial_cmp(&a.0).expect("finite scores have no NaN"));
        Ok(Controller { mode, t0_min: cfg.t0_min, t0_max: cfg.t0_max, grid, calibration })
    }

    pub fn mode(&self) -> ControllerMode {
        self.mode
    }

    pub fn t0_min(&self) -> f64 {
        self.t0_min
    }

    pub fn t0_max(&self) -> f64 {
        self.t0_max
    }

    /// The discrete t0 grid decisions are chosen from (ascending,
    /// deduped, clamped) — recorded per bundle by the decision ledger.
    pub fn grid(&self) -> &[f64] {
        &self.grid
    }

    /// Whether [`Controller::decide`] wants a [`proxy_score`] of the
    /// drafted batch (only the `scored` mode pays for scoring).
    pub fn needs_score(&self) -> bool {
        self.mode == ControllerMode::Scored
    }

    /// The guarantee-floor NFE budget for a bundle: what the schedule may
    /// never exceed. Static mode budgets exactly the request's own `t0`;
    /// adaptive modes budget the floor `t0_min`.
    pub fn nfe_budget(&self, steps_cold: usize, requested_t0: f64) -> usize {
        match self.mode {
            ControllerMode::Static => guaranteed_nfe(steps_cold, requested_t0),
            _ => guaranteed_nfe(steps_cold, self.t0_min),
        }
    }

    /// Choose the bundle's `t0`. `score` is the [`proxy_score`] of the
    /// drafted batch (required meaningfully only in `scored` mode; a
    /// missing score falls back to the draft-kind prior).
    pub fn decide(
        &self,
        draft: DraftSpec,
        requested_t0: f64,
        score: Option<f64>,
    ) -> ControlDecision {
        match self.mode {
            ControllerMode::Static => ControlDecision { t0: requested_t0, score: None },
            ControllerMode::Prior => self.from_score(prior_score(draft)),
            ControllerMode::Scored => {
                self.from_score(score.unwrap_or_else(|| prior_score(draft)))
            }
        }
    }

    /// Map a quality score in `[0, 1]` to a grid `t0` (clamped to the
    /// configured range — the guarantee floor).
    fn from_score(&self, score: f64) -> ControlDecision {
        let s = if score.is_finite() { score.clamp(0.0, 1.0) } else { 0.0 };
        let t0 = if self.calibration.is_empty() {
            // Linear map: better draft -> later start -> fewer steps.
            let idx = ((s * self.grid.len() as f64) as usize).min(self.grid.len() - 1);
            self.grid[idx]
        } else {
            self.calibration
                .iter()
                .find(|&&(min_score, _)| s >= min_score)
                .map(|&(_, t0)| t0)
                .unwrap_or(self.grid[0])
        };
        ControlDecision { t0: t0.clamp(self.t0_min, self.t0_max), score: Some(s) }
    }
}

/// Draft-kind prior quality score (the `prior` mode's only input): the
/// two-moons mixtures follow the paper's Fig. 4 quality ordering, the
/// trained LSTM/PCA drafts sit between good and fair, and uniform noise
/// is by definition the zero of the scale (cold DFM's implicit draft).
pub fn prior_score(draft: DraftSpec) -> f64 {
    match draft {
        DraftSpec::Noise => 0.0,
        DraftSpec::Mixture(DraftKind::Good) => 0.9,
        DraftSpec::Mixture(DraftKind::Fair) => 0.55,
        DraftSpec::Mixture(DraftKind::Poor) => 0.25,
        DraftSpec::Lstm | DraftSpec::Pca => 0.7,
    }
}

/// N-gram self-consistency score in `[0, 1]`: fit a bigram
/// [`NgramLM`] on the draft batch itself and normalize its mean
/// per-token NLL by `ln(vocab)` (the uniform-noise ceiling). Structured
/// drafts predict themselves well (score up), uniform noise scores ~0.
/// Deterministic: no RNG, no unordered iteration.
///
/// Degenerate inputs pin to the neutral score `0.0` (never NaN, never a
/// panic): no rows, no non-empty rows, a single-token vocabulary
/// (`ln(1) = 0` would divide by zero), or rows too short for any bigram
/// (`seq_len < 2` leaves self-consistency undefined — only unigram
/// concentration, which is not the structure this proxy measures).
pub fn ngram_score(rows: &[&[i32]], vocab: usize) -> f64 {
    if rows.is_empty() || vocab < 2 {
        return 0.0;
    }
    if rows.iter().all(|r| r.len() < 2) {
        return 0.0;
    }
    let stream: Vec<i32> = rows.iter().flat_map(|r| r.iter().copied()).collect();
    if stream.is_empty() {
        return 0.0;
    }
    let lm = NgramLM::fit(&stream, 2, vocab);
    let mean_nll = rows.iter().map(|r| lm.nll(r)).sum::<f64>() / rows.len() as f64;
    if !mean_nll.is_finite() {
        return 0.0;
    }
    (1.0 - mean_nll / (vocab as f64).ln()).clamp(0.0, 1.0)
}

/// Energy score in `[0, 1]`: mean absolute correlation between adjacent
/// positions of the draft batch — the same adjacent-pair covariances
/// `eval::stats::mean_cov` would produce, but accumulated directly in
/// two `O(rows · seq_len)` passes (the full `d×d` matrix would be
/// `O(rows · seq_len²)` for values this function never reads). Real
/// data couples neighbouring positions when token ids are ordinal
/// (two-moons grid coordinates, pixel intensities); uniform noise has
/// none. Positions with zero variance contribute nothing.
///
/// Degenerate inputs pin to the neutral score `0.0`: fewer than two
/// rows, any row shorter than two tokens (ragged batches are measured
/// over the shortest row — never an out-of-bounds panic), or a
/// zero-variance batch (e.g. a single-token vocabulary).
pub fn energy_score(rows: &[&[i32]], _vocab: usize) -> f64 {
    if rows.len() < 2 {
        return 0.0;
    }
    // Ragged guard: correlate only the prefix every row actually has.
    let seq_len = rows.iter().map(|r| r.len()).min().unwrap_or(0);
    if seq_len < 2 {
        return 0.0;
    }
    let m = rows.len() as f64;
    let mut mean = vec![0.0f64; seq_len];
    for r in rows {
        for (mi, &t) in mean.iter_mut().zip(r.iter()) {
            *mi += t as f64;
        }
    }
    for mi in &mut mean {
        *mi /= m;
    }
    let mut total = 0.0;
    for i in 0..seq_len - 1 {
        let (mut sxx, mut sxy, mut syy) = (0.0f64, 0.0f64, 0.0f64);
        for r in rows {
            let cx = r[i] as f64 - mean[i];
            let cy = r[i + 1] as f64 - mean[i + 1];
            sxx += cx * cx;
            sxy += cx * cy;
            syy += cy * cy;
        }
        let vxy = sxx * syy;
        if vxy > 0.0 {
            total += (sxy / vxy.sqrt()).abs();
        }
    }
    (total / (seq_len - 1) as f64).clamp(0.0, 1.0)
}

/// The `scored` mode's draft-quality proxy: the **max** of
/// [`ngram_score`] and [`energy_score`]. The two proxies detect
/// different kinds of structure — n-gram self-consistency sees
/// categorical regularity (text, where arbitrary token-id numbering
/// blinds the correlation proxy), the energy score sees ordinal
/// regularity (grids, pixels) — so a draft is as good as its
/// best-detected structure, and a proxy that is blind for a domain
/// cannot drag a good draft toward the noise band. Raw values still
/// compress into roughly `[0, 0.5]` — the calibration table exists to
/// spread them over the grid (EXPERIMENTS.md §Control).
pub fn proxy_score(rows: &[&[i32]], vocab: usize) -> f64 {
    ngram_score(rows, vocab).max(energy_score(rows, vocab))
}

/// Reference draft batches scored in [`calibrate_two_moons`], best
/// quality first. `None` = uniform noise.
const CALIBRATION_BANDS: &[(Option<DraftKind>, f64)] = &[
    // (band, target t0): the paper's Table 1 sweet spots per quality.
    (Some(DraftKind::Good), 0.9),
    (Some(DraftKind::Fair), 0.65),
    (Some(DraftKind::Poor), 0.5),
    (None, 0.0), // noise -> the configured floor
];

/// The `selfcheck --calibrate` pass: score fixed-seed reference
/// two-moons draft batches (good/fair/poor mixtures + uniform noise)
/// and derive `(min_score, t0)` thresholds at the midpoints between
/// adjacent bands. Pure (fixed internal seed), so the table is
/// reproducible; target t0s snap to the configured grid and range.
pub fn calibrate_two_moons(cfg: &ControlConfig) -> Result<Vec<(f64, f64)>> {
    let controller = Controller::from_config(cfg)?;
    const N: usize = 2048;
    let vocab = two_moons::GRID;
    let mut rng = Pcg64::new(0xCA11_B8A7);
    let mut scored: Vec<(f64, f64)> = Vec::with_capacity(CALIBRATION_BANDS.len());
    for &(band, target_t0) in CALIBRATION_BANDS {
        let pts: Vec<[i32; 2]> = match band {
            Some(kind) => two_moons::draft_batch(kind, N, &mut rng),
            None => (0..N)
                .map(|_| [rng.below(vocab as u32) as i32, rng.below(vocab as u32) as i32])
                .collect(),
        };
        let rows: Vec<&[i32]> = pts.iter().map(|p| &p[..]).collect();
        let score = proxy_score(&rows, vocab);
        // Snap the band's target to the nearest grid value in range.
        let target = target_t0.clamp(controller.t0_min, controller.t0_max);
        let t0 = controller
            .grid
            .iter()
            .copied()
            .min_by(|a, b| {
                (a - target).abs().partial_cmp(&(b - target).abs()).expect("grid has no NaN")
            })
            .expect("grid is non-empty");
        scored.push((score, t0));
    }
    // Thresholds at midpoints between adjacent band scores; the lowest
    // band catches everything (min_score 0).
    let mut table = Vec::with_capacity(scored.len());
    for i in 0..scored.len() {
        let min_score =
            if i + 1 < scored.len() { 0.5 * (scored[i].0 + scored[i + 1].0) } else { 0.0 };
        table.push((min_score, scored[i].1));
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: &str) -> ControlConfig {
        ControlConfig { mode: mode.into(), ..ControlConfig::default() }
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [ControllerMode::Static, ControllerMode::Prior, ControllerMode::Scored] {
            assert_eq!(ControllerMode::parse(m.name()).unwrap(), m);
        }
        assert!(ControllerMode::parse("vibes").is_err());
    }

    #[test]
    fn static_mode_passes_request_t0_through() {
        let c = Controller::static_default();
        for t0 in [0.0, 0.123, 0.8, 0.999] {
            let d = c.decide(DraftSpec::Noise, t0, None);
            assert_eq!(d.t0, t0); // verbatim, even outside [t0_min, t0_max]
            assert_eq!(d.score, None);
        }
        assert!(!c.needs_score());
        // Static budget is the request's own schedule.
        assert_eq!(c.nfe_budget(20, 0.8), 4);
    }

    #[test]
    fn adaptive_t0_respects_the_guarantee_floor() {
        for mode in ["prior", "scored"] {
            let c = Controller::from_config(&cfg(mode)).unwrap();
            for draft in [
                DraftSpec::Noise,
                DraftSpec::Lstm,
                DraftSpec::Pca,
                DraftSpec::Mixture(DraftKind::Good),
                DraftSpec::Mixture(DraftKind::Fair),
                DraftSpec::Mixture(DraftKind::Poor),
            ] {
                for score in [None, Some(-1.0), Some(0.0), Some(0.37), Some(1.0), Some(f64::NAN)] {
                    let d = c.decide(draft, 0.8, score);
                    assert!(
                        d.t0 >= c.t0_min() && d.t0 <= c.t0_max(),
                        "{mode} {draft:?} {score:?} -> {}",
                        d.t0
                    );
                    // The floor in NFE terms: never more work than the
                    // static-t0_min budget.
                    assert!(
                        guaranteed_nfe(20, d.t0) <= c.nfe_budget(20, 0.8),
                        "budget exceeded at t0={}",
                        d.t0
                    );
                }
            }
        }
    }

    #[test]
    fn prior_mode_orders_draft_kinds() {
        let c = Controller::from_config(&cfg("prior")).unwrap();
        let t0_of = |d: DraftSpec| c.decide(d, 0.8, None).t0;
        let good = t0_of(DraftSpec::Mixture(DraftKind::Good));
        let fair = t0_of(DraftSpec::Mixture(DraftKind::Fair));
        let poor = t0_of(DraftSpec::Mixture(DraftKind::Poor));
        let noise = t0_of(DraftSpec::Noise);
        assert!(good >= fair && fair >= poor && poor >= noise);
        assert!(good > noise, "the prior must separate best from worst");
    }

    #[test]
    fn score_mapping_is_monotone_and_clamped() {
        let c = Controller::from_config(&cfg("scored")).unwrap();
        let mut prev = -1.0;
        for i in 0..=20 {
            let s = i as f64 / 20.0;
            let d = c.decide(DraftSpec::Noise, 0.8, Some(s));
            assert!(d.t0 >= prev, "t0 must be monotone in score");
            assert!(d.t0 >= c.t0_min() && d.t0 <= c.t0_max());
            assert_eq!(d.score, Some(s));
            prev = d.t0;
        }
        // Extremes hit the ends of the grid.
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.0)).t0, c.t0_min());
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(1.0)).t0, c.t0_max());
    }

    #[test]
    fn calibration_table_overrides_linear_map() {
        let mut config = cfg("scored");
        config.calibration = vec![(0.6, 0.9), (0.3, 0.5), (0.0, 0.35)];
        let c = Controller::from_config(&config).unwrap();
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.7)).t0, 0.9);
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.45)).t0, 0.5);
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.1)).t0, 0.35);
        // Calibration t0s clamp into [t0_min, t0_max] too.
        config.calibration = vec![(0.0, 0.1)];
        config.t0_min = 0.35;
        let c = Controller::from_config(&config).unwrap();
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.9)).t0, 0.35);
    }

    #[test]
    fn grid_is_sorted_deduped_and_clamped() {
        let mut config = cfg("scored");
        config.grid = vec![0.9, 0.1, 0.5, 0.9, 0.99];
        config.t0_min = 0.3;
        config.t0_max = 0.95;
        let c = Controller::from_config(&config).unwrap();
        assert_eq!(c.grid, vec![0.3, 0.5, 0.9, 0.95]);
    }

    #[test]
    fn structured_rows_outscore_uniform_noise() {
        // Constant-structure batch: every row the same bigram -> the
        // self-fit LM predicts it nearly perfectly.
        let structured: Vec<Vec<i32>> = (0..256)
            .map(|i| vec![5 + (i % 2) as i32, 7 + (i % 2) as i32])
            .collect();
        let s_rows: Vec<&[i32]> = structured.iter().map(|r| &r[..]).collect();
        let mut rng = Pcg64::new(11);
        let noise: Vec<Vec<i32>> = (0..256)
            .map(|_| vec![rng.below(128) as i32, rng.below(128) as i32])
            .collect();
        let n_rows: Vec<&[i32]> = noise.iter().map(|r| &r[..]).collect();
        let s = proxy_score(&s_rows, 128);
        let n = proxy_score(&n_rows, 128);
        assert!(s > n + 0.2, "structured {s} vs noise {n}");
        assert!((0.0..=1.0).contains(&s) && (0.0..=1.0).contains(&n));
        // And the components behave at their edges.
        assert_eq!(proxy_score(&[], 128), 0.0);
        assert_eq!(energy_score(&s_rows[..1], 128), 0.0); // < 2 rows
    }

    #[test]
    fn degenerate_inputs_pin_the_neutral_score() {
        // Every proxy returns the pinned neutral 0.0 — never NaN, never a
        // panic — on degenerate batches.
        let empty: Vec<&[i32]> = vec![];
        let empty_rows: Vec<&[i32]> = vec![&[], &[], &[]];
        let single_tok_rows: Vec<&[i32]> = vec![&[3], &[1], &[2]];
        let one_row: Vec<&[i32]> = vec![&[1, 2, 3]];
        for (name, rows, vocab) in [
            ("no rows", &empty, 16),
            ("zero useful rows (all empty)", &empty_rows, 16),
            ("seq_len < 2", &single_tok_rows, 16),
            ("single-token vocab", &one_row, 1),
            ("zero vocab", &one_row, 0),
        ] {
            for (proxy, s) in [
                ("ngram", ngram_score(rows, vocab)),
                ("energy", energy_score(rows, vocab)),
                ("proxy", proxy_score(rows, vocab)),
            ] {
                assert!(s.is_finite(), "{proxy} on {name} returned non-finite {s}");
                assert_eq!(s, 0.0, "{proxy} on {name} must pin the neutral score");
            }
        }
        // Single-token vocab with >= 2 rows: the energy score sees zero
        // variance everywhere and also pins to 0.
        let const_rows: Vec<&[i32]> = vec![&[0, 0, 0], &[0, 0, 0]];
        assert_eq!(energy_score(&const_rows, 1), 0.0);
        assert_eq!(proxy_score(&const_rows, 1), 0.0);
        // Ragged batches measure the shared prefix instead of panicking.
        let ragged: Vec<&[i32]> = vec![&[1, 2, 3, 4], &[1, 2]];
        let s = proxy_score(&ragged, 16);
        assert!((0.0..=1.0).contains(&s));
        // A ragged batch whose shortest row is a single token is
        // correlation-degenerate for the energy proxy.
        let ragged_short: Vec<&[i32]> = vec![&[1, 2, 3, 4], &[1]];
        assert_eq!(energy_score(&ragged_short, 16), 0.0);
    }

    #[test]
    fn two_moons_draft_quality_ordering_in_proxy_score() {
        // The scored mode's whole premise: the paper's Fig. 4 quality
        // ordering is visible in the proxy. Large fixed-seed batches keep
        // the margins far from sampling noise.
        let n = 2048;
        let vocab = two_moons::GRID;
        let mut rng = Pcg64::new(42);
        let score_of = |pts: &[[i32; 2]]| {
            let rows: Vec<&[i32]> = pts.iter().map(|p| &p[..]).collect();
            proxy_score(&rows, vocab)
        };
        let good = score_of(&two_moons::draft_batch(DraftKind::Good, n, &mut rng));
        let poor = score_of(&two_moons::draft_batch(DraftKind::Poor, n, &mut rng));
        let noise: Vec<[i32; 2]> = (0..n)
            .map(|_| [rng.below(vocab as u32) as i32, rng.below(vocab as u32) as i32])
            .collect();
        let noise_s = score_of(&noise);
        assert!(good > poor, "good {good} <= poor {poor}");
        assert!(poor > noise_s, "poor {poor} <= noise {noise_s}");
        assert!(good > noise_s + 0.1, "good {good} too close to noise {noise_s}");
    }

    #[test]
    fn calibration_pass_is_deterministic_and_ordered() {
        let config = cfg("scored");
        let a = calibrate_two_moons(&config).unwrap();
        let b = calibrate_two_moons(&config).unwrap();
        assert_eq!(a, b, "fixed-seed calibration must be reproducible");
        assert_eq!(a.len(), 4);
        // Thresholds descend and t0s never go below the floor.
        for w in a.windows(2) {
            assert!(w[0].0 >= w[1].0, "{a:?}");
            assert!(w[0].1 >= w[1].1, "better band, later start: {a:?}");
        }
        assert_eq!(a.last().unwrap().0, 0.0, "lowest band catches everything");
        for &(_, t0) in &a {
            assert!((config.t0_min..=config.t0_max).contains(&t0));
        }
        // Feeding the table back into a controller maps a high score to
        // the top band and a garbage score to the floor.
        let mut cal_cfg = config.clone();
        cal_cfg.calibration = a.clone();
        let c = Controller::from_config(&cal_cfg).unwrap();
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(1.0)).t0, a[0].1);
        assert_eq!(c.decide(DraftSpec::Noise, 0.8, Some(0.0)).t0, a.last().unwrap().1);
    }
}
