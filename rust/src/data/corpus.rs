//! Loaders for the build-time datasets materialized in `artifacts/`.
//!
//! Formats (written by `python/compile/aot.py` / `data.py`):
//! * `text8_corpus.txt`, `text8_eval.txt` — raw text (a-z + space).
//! * `wiki_corpus.bin`, `wiki_eval.bin`   — little-endian i32 token stream.
//! * `wiki_vocab.json`                    — JSON array of 256 words.
//! * `img_{gray,color}_train.bin`         — u8 tokens, row-major `[M, N]`.
//! * `img_{gray,color}_labels.bin`        — u8 labels `[M]`.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Load a text corpus file and encode to char tokens.
pub fn load_text8(path: &Path) -> Result<Vec<i32>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    crate::data::tokenizer::CharTokenizer.encode(&text)
}

/// Load a little-endian i32 token stream.
pub fn load_i32_stream(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Load a u8 token matrix `[rows, row_len]`.
pub fn load_u8_matrix(path: &Path, row_len: usize) -> Result<Vec<Vec<i32>>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if row_len == 0 || bytes.len() % row_len != 0 {
        bail!("{path:?}: length {} not divisible by row_len {row_len}", bytes.len());
    }
    Ok(bytes
        .chunks_exact(row_len)
        .map(|row| row.iter().map(|&b| b as i32).collect())
        .collect())
}

/// Load u8 labels.
pub fn load_u8_labels(path: &Path) -> Result<Vec<usize>> {
    Ok(std::fs::read(path)
        .with_context(|| format!("reading {path:?}"))?
        .into_iter()
        .map(|b| b as usize)
        .collect())
}

/// Split a token stream into contiguous windows of `seq_len` (the eval-side
/// counterpart of python `text8_sequences`, but deterministic/striding).
pub fn windows(stream: &[i32], seq_len: usize, max_n: usize) -> Vec<Vec<i32>> {
    stream
        .chunks_exact(seq_len)
        .take(max_n)
        .map(|c| c.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("wsfm_corpus_{name}_{}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    fn i32_stream_roundtrip() {
        let vals: Vec<i32> = vec![0, 1, -5, 1_000_000];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let p = tmpfile("i32", &bytes);
        assert_eq!(load_i32_stream(&p).unwrap(), vals);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn i32_stream_bad_length() {
        let p = tmpfile("i32bad", &[1, 2, 3]);
        assert!(load_i32_stream(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn u8_matrix_shapes() {
        let p = tmpfile("mat", &[1, 2, 3, 4, 5, 6]);
        let m = load_u8_matrix(&p, 3).unwrap();
        assert_eq!(m, vec![vec![1, 2, 3], vec![4, 5, 6]]);
        assert!(load_u8_matrix(&p, 4).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn text8_loader_encodes() {
        let p = tmpfile("txt", b"abc z");
        // Rename to .txt-ish is irrelevant; content is what matters.
        let toks = load_text8(&p).unwrap();
        assert_eq!(toks, vec![0, 1, 2, 26, 25]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn windows_chunking() {
        let stream: Vec<i32> = (0..10).collect();
        let w = windows(&stream, 3, 10);
        assert_eq!(w.len(), 3);
        assert_eq!(w[2], vec![6, 7, 8]);
        assert_eq!(windows(&stream, 3, 2).len(), 2);
    }
}
