//! Synthetic English-like corpus generator (Rust mirror of
//! `python/compile/data.py`'s synth-text8 grammar).
//!
//! Used by unit tests and as a fallback corpus source when `artifacts/` is
//! absent; the canonical corpus for evaluation is the file written by the
//! AOT pipeline. The lexicon/grammar constants are copied verbatim from the
//! python side — `tests/cross_lang.rs` checks the two implementations'
//! character statistics agree.

use crate::core::rng::Pcg64;

pub const DET: &[&str] = &["the", "a", "one", "this", "that", "each", "some", "every"];
pub const ADJ: &[&str] = &[
    "small", "large", "old", "young", "red", "blue", "green", "dark", "bright", "quiet", "loud",
    "early", "late", "famous", "local", "ancient", "modern", "cold", "warm", "heavy", "light",
    "rapid", "slow", "simple", "complex",
];
pub const NOUN: &[&str] = &[
    "city", "river", "mountain", "forest", "village", "castle", "bridge", "library", "museum",
    "station", "garden", "island", "valley", "harbor", "temple", "market", "road", "tower",
    "school", "house", "king", "queen", "writer", "painter", "soldier", "farmer", "merchant",
    "scholar", "child", "bird", "horse", "wolf", "fish", "tree", "stone", "book", "song", "war",
    "storm", "winter", "summer", "country", "empire", "army", "ship", "train",
];
pub const VERB: &[&str] = &[
    "was", "became", "remained", "stood", "moved", "crossed", "entered", "left", "reached",
    "followed", "carried", "built", "destroyed", "found", "lost", "defended", "visited",
    "described", "painted", "wrote", "sang", "ruled", "served", "joined", "formed", "covered",
    "crossed", "opened",
];
pub const ADV: &[&str] =
    &["quickly", "slowly", "often", "rarely", "finally", "suddenly", "quietly", "nearly"];
pub const PREP: &[&str] =
    &["in", "on", "near", "under", "over", "beyond", "across", "through", "behind"];
pub const CONJ: &[&str] = &["and", "but", "while", "because", "although", "before", "after"];
pub const NUM: &[&str] =
    &["one", "two", "three", "four", "five", "six", "seven", "eight", "nine", "zero"];

fn pick<'a>(rng: &mut Pcg64, xs: &[&'a str]) -> &'a str {
    xs[rng.below(xs.len() as u32) as usize]
}

fn noun_phrase(rng: &mut Pcg64, out: &mut Vec<&'static str>) {
    out.push(pick(rng, DET));
    if rng.uniform() < 0.6 {
        out.push(pick(rng, ADJ));
    }
    out.push(pick(rng, NOUN));
}

/// One clause (mirrors python `_sentence`).
pub fn sentence(rng: &mut Pcg64) -> Vec<&'static str> {
    let mut words = Vec::with_capacity(16);
    noun_phrase(rng, &mut words);
    words.push(pick(rng, VERB));
    if rng.uniform() < 0.4 {
        words.push(pick(rng, ADV));
    }
    if rng.uniform() < 0.8 {
        words.push(pick(rng, PREP));
        noun_phrase(rng, &mut words);
    }
    if rng.uniform() < 0.15 {
        words.push("in");
        for _ in 0..4 {
            words.push(pick(rng, NUM));
        }
    }
    if rng.uniform() < 0.3 {
        words.push(pick(rng, CONJ));
        noun_phrase(rng, &mut words);
        words.push(pick(rng, VERB));
    }
    words
}

/// Generate a corpus of exactly `n_chars` characters (a-z + space).
pub fn corpus(n_chars: usize, seed: u64) -> String {
    let mut rng = Pcg64::new(seed);
    let mut text = String::with_capacity(n_chars + 80);
    while text.len() < n_chars + 64 {
        if !text.is_empty() {
            text.push(' ');
        }
        let words = sentence(&mut rng);
        text.push_str(&words.join(" "));
    }
    text.truncate(n_chars);
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_alphabet_and_length() {
        let c = corpus(10_000, 1);
        assert_eq!(c.len(), 10_000);
        assert!(c.chars().all(|ch| ch == ' ' || ch.is_ascii_lowercase()));
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        assert_eq!(corpus(500, 7), corpus(500, 7));
        assert_ne!(corpus(500, 7), corpus(500, 8));
    }

    #[test]
    fn sentences_have_grammar_shape() {
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let s = sentence(&mut rng);
            assert!(s.len() >= 3, "sentence too short: {s:?}");
            assert!(DET.contains(&s[0]), "must start with determiner: {s:?}");
            // A verb appears somewhere.
            assert!(s.iter().any(|w| VERB.contains(w)), "no verb: {s:?}");
        }
    }

    #[test]
    fn word_frequencies_reasonable() {
        // Space frequency in word-joined text should be ~1/6 (avg word ~5
        // chars); check a loose band to catch grammar regressions.
        let c = corpus(50_000, 5);
        let spaces = c.chars().filter(|&ch| ch == ' ').count() as f64 / c.len() as f64;
        assert!((0.10..0.25).contains(&spaces), "space freq {spaces}");
    }
}
