//! Tokenizers: char-level (synth-text8) and word-level (synth-wiki).
//!
//! Mirrors the python encodings exactly: text8 maps 'a'..'z' -> 0..25 and
//! ' ' -> 26; wiki uses the 256-word vocabulary shipped in
//! `artifacts/wiki_vocab.json`.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Character-level tokenizer over `a-z` + space (V = 27).
#[derive(Debug, Clone, Default)]
pub struct CharTokenizer;

pub const TEXT8_CHARS: &str = "abcdefghijklmnopqrstuvwxyz ";
pub const TEXT8_VOCAB: usize = 27;

impl CharTokenizer {
    pub fn vocab_size(&self) -> usize {
        TEXT8_VOCAB
    }

    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| match c {
                'a'..='z' => Ok(c as i32 - 'a' as i32),
                ' ' => Ok(26),
                _ => bail!("character {c:?} outside text8 alphabet"),
            })
            .collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| match t {
                0..=25 => (b'a' + t as u8) as char,
                _ => ' ',
            })
            .collect()
    }
}

/// Word-level tokenizer backed by an explicit vocabulary list.
#[derive(Debug, Clone)]
pub struct WordTokenizer {
    vocab: Vec<String>,
    lut: HashMap<String, i32>,
    unk: i32,
}

impl WordTokenizer {
    pub fn new(vocab: Vec<String>) -> Result<Self> {
        if vocab.is_empty() {
            bail!("empty vocabulary");
        }
        let lut: HashMap<String, i32> =
            vocab.iter().enumerate().map(|(i, w)| (w.clone(), i as i32)).collect();
        let unk = lut.get("<unk>").copied().unwrap_or(0);
        Ok(WordTokenizer { vocab, lut, unk })
    }

    /// Load from the JSON array written by the AOT pipeline.
    pub fn from_json(json_text: &str) -> Result<Self> {
        let v = crate::util::json::Json::parse(json_text)?;
        let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("vocab json must be an array"))?;
        let vocab: Vec<String> = arr
            .iter()
            .map(|j| j.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow::anyhow!("vocab entries must be strings"))?;
        Self::new(vocab)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| *self.lut.get(w).unwrap_or(&self.unk)).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                self.vocab
                    .get(t as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<unk>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_roundtrip() {
        let t = CharTokenizer;
        let s = "the quick brown fox";
        let toks = t.encode(s).unwrap();
        assert_eq!(t.decode(&toks), s);
        assert_eq!(toks[0], 19); // 't'
        assert_eq!(toks[3], 26); // ' '
    }

    #[test]
    fn char_rejects_outside_alphabet() {
        let t = CharTokenizer;
        assert!(t.encode("Hello").is_err());
        assert!(t.encode("a1b").is_err());
    }

    #[test]
    fn char_decode_clamps_unknown() {
        let t = CharTokenizer;
        assert_eq!(t.decode(&[0, 99, 25]), "a z");
    }

    #[test]
    fn word_roundtrip() {
        let t = WordTokenizer::new(
            ["<unk>", "the", "cat", "sat"].iter().map(|s| s.to_string()).collect(),
        )
        .unwrap();
        let toks = t.encode("the cat sat");
        assert_eq!(toks, vec![1, 2, 3]);
        assert_eq!(t.decode(&toks), "the cat sat");
    }

    #[test]
    fn word_unknown_maps_to_unk() {
        let t = WordTokenizer::new(
            ["<unk>", "the"].iter().map(|s| s.to_string()).collect(),
        )
        .unwrap();
        assert_eq!(t.encode("the zebra"), vec![1, 0]);
        assert_eq!(t.decode(&[1, 7]), "the <unk>");
    }

    #[test]
    fn word_from_json() {
        let t = WordTokenizer::from_json(r#"["<unk>","a","b"]"#).unwrap();
        assert_eq!(t.vocab_size(), 3);
        assert_eq!(t.encode("b a"), vec![2, 1]);
        assert!(WordTokenizer::from_json("{}").is_err());
        assert!(WordTokenizer::from_json("[1,2]").is_err());
    }
}
