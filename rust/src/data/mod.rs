//! Datasets & tokenization (Rust side).
//!
//! The canonical corpora/datasets are generated at build time by
//! `python/compile/data.py` and materialized into `artifacts/` — the
//! evaluators load those files ([`corpus`]). The generators here mirror the
//! same distributions (identical constants/grammar) for unit tests and for
//! request-path sampling of two-moons draft points; a cross-language
//! consistency test compares summary statistics of the two implementations.

pub mod corpus;
pub mod shapes;
pub mod textgen;
pub mod tokenizer;
pub mod two_moons;
