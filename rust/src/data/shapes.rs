//! Procedural shape images (Rust mirror of python synth-shapes).
//!
//! Renders the same 10 classes over the same quantization (V=32) for unit
//! tests and figure dumps; the canonical training set consumed by FID lives
//! in `artifacts/img_*_train.bin` ([`super::corpus`]).

use crate::core::rng::Pcg64;

pub const IMG_VOCAB: usize = 32;
pub const GRAY_SIDE: usize = 16;
pub const COLOR_SIDE: usize = 8;
pub const N_CLASSES: usize = 10;

/// Render one gray image: `side*side` tokens in `[0, 32)`.
pub fn render_gray(cls: usize, side: usize, rng: &mut Pcg64) -> Vec<i32> {
    render_float(cls, side, rng).iter().map(|&v| quantize(v)).collect()
}

/// Render one color image (channel-last `side*side*3` tokens).
pub fn render_color(cls: usize, side: usize, rng: &mut Pcg64) -> Vec<i32> {
    let base = render_float(cls, side, rng);
    let tint: Vec<f64> = (0..3).map(|_| 0.4 + rng.uniform() * 0.6).collect();
    let mut out = Vec::with_capacity(base.len() * 3);
    for &v in &base {
        for t in &tint {
            let noisy = (v * t + rng.normal() * 0.02).clamp(0.0, 1.0);
            out.push(quantize(noisy));
        }
    }
    out
}

fn quantize(v: f64) -> i32 {
    ((v * IMG_VOCAB as f64).floor()).clamp(0.0, (IMG_VOCAB - 1) as f64) as i32
}

/// Float image in [0,1] for a class (mirrors python `_render_shape`).
pub fn render_float(cls: usize, side: usize, rng: &mut Pcg64) -> Vec<f64> {
    let cx = 0.3 + rng.uniform() * 0.4;
    let cy = 0.3 + rng.uniform() * 0.4;
    let r = 0.15 + rng.uniform() * 0.2;
    let bg = 0.05 + rng.uniform() * 0.25;
    let fg = 0.6 + rng.uniform() * 0.35;
    let stripes_k = 2.0 + rng.below(3) as f64;
    let checker_k = 2 + rng.below(2) as i64;

    let mut img = vec![0.0f64; side * side];
    for yy in 0..side {
        for xx in 0..side {
            let x = (xx as f64 + 0.5) / side as f64;
            let y = (yy as f64 + 0.5) / side as f64;
            let d2 = (x - cx).powi(2) + (y - cy).powi(2);
            let v = match cls {
                0 => {
                    if d2 < r * r {
                        fg
                    } else {
                        bg
                    }
                }
                1 => {
                    if (x - cx).abs().max((y - cy).abs()) < r {
                        fg
                    } else {
                        bg
                    }
                }
                2 => {
                    if d2 < r * r && d2 > (0.55 * r).powi(2) {
                        fg
                    } else {
                        bg
                    }
                }
                3 => {
                    if (y * std::f64::consts::PI * 2.0 * stripes_k).sin() > 0.0 {
                        fg
                    } else {
                        bg
                    }
                }
                4 => {
                    if (x * std::f64::consts::PI * 2.0 * stripes_k).sin() > 0.0 {
                        fg
                    } else {
                        bg
                    }
                }
                5 => bg + (fg - bg) * (x + y) / 2.0,
                6 => {
                    let w = 0.4 * r;
                    if (x - cx).abs() < w || (y - cy).abs() < w {
                        fg
                    } else {
                        bg
                    }
                }
                7 => {
                    if ((x * checker_k as f64).floor() as i64 + (y * checker_k as f64).floor() as i64) % 2 != 0 {
                        fg
                    } else {
                        bg
                    }
                }
                8 => {
                    if (x - cx).abs() + (y - cy).abs() < r {
                        fg
                    } else {
                        bg
                    }
                }
                _ => bg + (fg - bg) * (1.0 - d2.sqrt() / 0.7).clamp(0.0, 1.0),
            };
            img[yy * side + xx] = (v + rng.normal() * 0.03).clamp(0.0, 1.0);
        }
    }
    img
}

/// A labeled batch.
pub fn batch_gray(n: usize, rng: &mut Pcg64) -> (Vec<Vec<i32>>, Vec<usize>) {
    let mut imgs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let cls = rng.below(N_CLASSES as u32) as usize;
        imgs.push(render_gray(cls, GRAY_SIDE, rng));
        labels.push(cls);
    }
    (imgs, labels)
}

/// Write a PGM (gray) image from tokens — for figure dumps (Fig 6/7/12).
pub fn write_pgm(path: &std::path::Path, tokens: &[i32], side: usize) -> std::io::Result<()> {
    let mut out = format!("P2\n{side} {side}\n255\n");
    for row in 0..side {
        let line: Vec<String> = (0..side)
            .map(|c| ((tokens[row * side + c].clamp(0, 31) * 255) / 31).to_string())
            .collect();
        out.push_str(&line.join(" "));
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// Write a PPM (color, channel-last tokens) image (Fig 8/9/13).
pub fn write_ppm(path: &std::path::Path, tokens: &[i32], side: usize) -> std::io::Result<()> {
    let mut out = format!("P3\n{side} {side}\n255\n");
    for row in 0..side {
        let mut line = Vec::with_capacity(side * 3);
        for c in 0..side {
            for ch in 0..3 {
                let t = tokens[(row * side + c) * 3 + ch].clamp(0, 31);
                line.push(((t * 255) / 31).to_string());
            }
        }
        out.push_str(&line.join(" "));
        out.push('\n');
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_tokens_in_vocab() {
        let mut rng = Pcg64::new(0);
        for cls in 0..N_CLASSES {
            let img = render_gray(cls, GRAY_SIDE, &mut rng);
            assert_eq!(img.len(), GRAY_SIDE * GRAY_SIDE);
            assert!(img.iter().all(|&t| (0..IMG_VOCAB as i32).contains(&t)));
        }
    }

    #[test]
    fn color_has_three_channels() {
        let mut rng = Pcg64::new(1);
        let img = render_color(0, COLOR_SIDE, &mut rng);
        assert_eq!(img.len(), COLOR_SIDE * COLOR_SIDE * 3);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Disk (0) vs gradient (5): different spatial variance profiles.
        let mut rng = Pcg64::new(2);
        let disk = render_float(0, 16, &mut rng);
        let grad = render_float(5, 16, &mut rng);
        let var = |v: &[f64]| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            v.iter().map(|x| (x - m).powi(2)).sum::<f64>() / v.len() as f64
        };
        // Both are valid images with nonzero variance.
        assert!(var(&disk) > 1e-4);
        assert!(var(&grad) > 1e-4);
    }

    #[test]
    fn pgm_ppm_written() {
        let dir = std::env::temp_dir();
        let mut rng = Pcg64::new(3);
        let g = render_gray(0, GRAY_SIDE, &mut rng);
        let p = dir.join("wsfm_test.pgm");
        write_pgm(&p, &g, GRAY_SIDE).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().starts_with("P2"));
        let c = render_color(1, COLOR_SIDE, &mut rng);
        let p2 = dir.join("wsfm_test.ppm");
        write_ppm(&p2, &c, COLOR_SIDE).unwrap();
        assert!(std::fs::read_to_string(&p2).unwrap().starts_with("P3"));
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(p2);
    }

    #[test]
    fn batch_labels_in_range() {
        let mut rng = Pcg64::new(4);
        let (imgs, labels) = batch_gray(50, &mut rng);
        assert_eq!(imgs.len(), 50);
        assert!(labels.iter().all(|&l| l < N_CLASSES));
    }
}
