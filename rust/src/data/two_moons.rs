//! Two-moons dataset + the three contrived draft models (paper §4.1, Fig 4).
//!
//! Mirrors `python/compile/data.py` exactly (same constants, same
//! quantization) so the Rust-side drafts/targets follow the same
//! distributions the WS-DFM artifacts were trained on.

use crate::core::rng::Pcg64;

pub const GRID: usize = 128;
pub const N_TOKENS: usize = 2;

/// Draft-model corruption levels (paper Fig. 4 c–e). Values mirror
/// `data.DRAFT_SPECS` in python.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DraftSpec {
    pub jitter: f64,
    pub uniform_frac: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DraftKind {
    Good,
    Fair,
    Poor,
}

impl DraftKind {
    pub fn spec(self) -> DraftSpec {
        match self {
            DraftKind::Good => DraftSpec { jitter: 3.0, uniform_frac: 0.02 },
            DraftKind::Fair => DraftSpec { jitter: 8.0, uniform_frac: 0.15 },
            DraftKind::Poor => DraftSpec { jitter: 16.0, uniform_frac: 0.40 },
        }
    }

    pub fn parse(s: &str) -> Option<DraftKind> {
        match s {
            "good" => Some(DraftKind::Good),
            "fair" => Some(DraftKind::Fair),
            "poor" => Some(DraftKind::Poor),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DraftKind::Good => "good",
            DraftKind::Fair => "fair",
            DraftKind::Poor => "poor",
        }
    }
}

/// One target sample: `[x, y]` tokens on the 128x128 grid.
pub fn sample(rng: &mut Pcg64, noise: f64) -> [i32; 2] {
    let theta = rng.uniform() * std::f64::consts::PI;
    let upper = rng.uniform() < 0.5;
    let (mut x, mut y) = if upper {
        (theta.cos(), theta.sin())
    } else {
        (1.0 - theta.cos(), 0.5 - theta.sin())
    };
    x += rng.normal() * noise;
    y += rng.normal() * noise;
    quantize(x, y)
}

/// Quantize raw moon coordinates into grid tokens (mirrors
/// `data.quantize_moons`).
pub fn quantize(x: f64, y: f64) -> [i32; 2] {
    let g = GRID as f64;
    let xs = (x + 1.25) / 3.5;
    let ys = (y + 0.75) / 2.0;
    let xi = (xs * g).floor().clamp(0.0, g - 1.0) as i32;
    let yi = (ys * g).floor().clamp(0.0, g - 1.0) as i32;
    [xi, yi]
}

/// A batch of target samples, shape `[n][2]`.
pub fn sample_batch(n: usize, rng: &mut Pcg64) -> Vec<[i32; 2]> {
    (0..n).map(|_| sample(rng, 0.06)).collect()
}

/// One draft-model sample (the lightweight generative model): a target
/// sample corrupted by jitter + uniform outliers.
pub fn draft_sample(kind: DraftKind, rng: &mut Pcg64) -> [i32; 2] {
    let spec = kind.spec();
    let base = sample(rng, 0.06);
    if rng.uniform() < spec.uniform_frac {
        return [rng.below(GRID as u32) as i32, rng.below(GRID as u32) as i32];
    }
    let x = base[0] as f64 + rng.normal() * spec.jitter;
    let y = base[1] as f64 + rng.normal() * spec.jitter;
    [
        x.round().clamp(0.0, (GRID - 1) as f64) as i32,
        y.round().clamp(0.0, (GRID - 1) as f64) as i32,
    ]
}

pub fn draft_batch(kind: DraftKind, n: usize, rng: &mut Pcg64) -> Vec<[i32; 2]> {
    (0..n).map(|_| draft_sample(kind, rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_grid() {
        let mut rng = Pcg64::new(0);
        for _ in 0..1000 {
            let [x, y] = sample(&mut rng, 0.06);
            assert!((0..GRID as i32).contains(&x));
            assert!((0..GRID as i32).contains(&y));
        }
    }

    #[test]
    fn quantize_corners() {
        // Extremes clamp into the grid.
        assert_eq!(quantize(-10.0, -10.0), [0, 0]);
        assert_eq!(quantize(10.0, 10.0), [(GRID - 1) as i32, (GRID - 1) as i32]);
    }

    #[test]
    fn two_modes_present() {
        // Both moons should appear: check y spread is bimodal-ish by
        // verifying samples above and below the grid midline.
        let mut rng = Pcg64::new(1);
        let batch = sample_batch(2000, &mut rng);
        let above = batch.iter().filter(|p| p[1] > 64).count();
        assert!(above > 400 && above < 1600, "above = {above}");
    }

    #[test]
    fn draft_quality_ordering() {
        // Poorer drafts deviate more from clean target samples: measure mean
        // min-distance to a reference target cloud.
        let mut rng = Pcg64::new(2);
        let target = sample_batch(1500, &mut rng);
        let mean_min_d2 = |kind: DraftKind, rng: &mut Pcg64| {
            let drafts = draft_batch(kind, 300, rng);
            drafts
                .iter()
                .map(|d| {
                    target
                        .iter()
                        .map(|t| {
                            let dx = (d[0] - t[0]) as f64;
                            let dy = (d[1] - t[1]) as f64;
                            dx * dx + dy * dy
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .sum::<f64>()
                / 300.0
        };
        let dg = mean_min_d2(DraftKind::Good, &mut rng);
        let df = mean_min_d2(DraftKind::Fair, &mut rng);
        let dp = mean_min_d2(DraftKind::Poor, &mut rng);
        assert!(dg < df && df < dp, "ordering violated: {dg} {df} {dp}");
    }

    #[test]
    fn draft_kind_parse_roundtrip() {
        for k in [DraftKind::Good, DraftKind::Fair, DraftKind::Poor] {
            assert_eq!(DraftKind::parse(k.name()), Some(k));
        }
        assert_eq!(DraftKind::parse("bogus"), None);
    }
}
