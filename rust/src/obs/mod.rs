//! Serving observability: request-scoped span tracing + structured event
//! journal (EXPERIMENTS.md §Observability).
//!
//! Two bounded, lock-cheap journals back the live stats surface:
//!
//! * [`SpanJournal`] — typed, fixed-size [`SpanRecord`]s (admit,
//!   batcher-wait, draft, refine-segment k, gate-eval, engine-call on
//!   replica r, composed-step) written by the serving hot path. Records
//!   are `Copy` and land in per-kind ring shards preallocated at
//!   construction, so a recording is one short shard-lock + one slot
//!   write — no allocation, no global contention across stages.
//! * [`EventJournal`] — sequence-numbered lifecycle [`EventRecord`]s for
//!   every fleet/fault transition (quarantine, respawn, reroute, watchdog
//!   timeout, artifact swap/rollback, degraded response, codec switch),
//!   turning the counter-only view into *when/which/why*.
//! * [`ledger`] — the decision ledger + guarantee auditor: one typed
//!   [`ledger::DecisionRecord`] per bundle outcome (controller/cascade
//!   decisions, realized NFE vs the guarantee floor, replay seeds and
//!   output hashes), ring-buffered with an optional append-only JSONL
//!   sink, audited on append, and windowed for calibration drift.
//!
//! All three are strictly bounded (ring caps from `config.obs`, pinned
//! by tests) and all gate on an enabled flag: with observability off
//! every recording call is a single relaxed atomic load. The contract
//! that matters most is **observation never perturbs outputs** — nothing
//! in this module touches RNG, scheduling decisions, or token data, so
//! the bitwise-determinism sweeps hold with tracing and the ledger on or
//! off.
//!
//! Identity threading: the admission path mints a `bundle_id` per flushed
//! [`crate::coordinator::WorkBundle`] (`Obs::next_bundle_id`), and spans
//! record `(request_id, bundle_id)`. Stages that work per-bundle (draft,
//! engine calls) record with `request_id = 0` and the bundle id; the
//! [`SpanJournal::for_request`] query joins the two by bundle id so a
//! `{"cmd":"trace"}` reply shows the full path of one request. Executor
//! internals (fleet dispatch) learn the ambient bundle through a
//! thread-local [`scope`] rather than a trait change, keeping the
//! `Executor` object surface stable.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

pub mod ledger;

/// Typed span kinds, one ring shard per kind. `#[repr(u8)]` so records
/// serialize to the binary wire as a single tag byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Request admitted into the batcher (duration = submit → admit).
    Admit = 0,
    /// Request waited in the batcher before its bundle flushed.
    BatcherWait = 1,
    /// DRAFT stage over one bundle.
    Draft = 2,
    /// One cascade REFINE segment (detail = segment index).
    RefineSegment = 3,
    /// Mid-cascade quality-gate evaluation (detail = segment index).
    GateEval = 4,
    /// One engine dispatch (detail = fleet replica index).
    EngineCall = 5,
    /// One composed cross-bundle step (detail = rows stepped).
    ComposedStep = 6,
}

impl SpanKind {
    /// Number of kinds == number of ring shards.
    pub const COUNT: usize = 7;

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::BatcherWait => "batcher_wait",
            SpanKind::Draft => "draft",
            SpanKind::RefineSegment => "refine_segment",
            SpanKind::GateEval => "gate_eval",
            SpanKind::EngineCall => "engine_call",
            SpanKind::ComposedStep => "composed_step",
        }
    }

    pub fn from_u8(v: u8) -> Option<SpanKind> {
        Some(match v {
            0 => SpanKind::Admit,
            1 => SpanKind::BatcherWait,
            2 => SpanKind::Draft,
            3 => SpanKind::RefineSegment,
            4 => SpanKind::GateEval,
            5 => SpanKind::EngineCall,
            6 => SpanKind::ComposedStep,
            _ => return None,
        })
    }

    fn all() -> [SpanKind; SpanKind::COUNT] {
        [
            SpanKind::Admit,
            SpanKind::BatcherWait,
            SpanKind::Draft,
            SpanKind::RefineSegment,
            SpanKind::GateEval,
            SpanKind::EngineCall,
            SpanKind::ComposedStep,
        ]
    }
}

/// One fixed-size span record. `Copy` so ring writes are slot stores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// Wire request id, or 0 for bundle-scoped spans (joined by bundle).
    pub request_id: u64,
    /// Bundle id minted at flush, or 0 before a request joins a bundle.
    pub bundle_id: u64,
    pub kind: SpanKind,
    /// Kind-specific detail: segment index, replica index, or row count.
    pub detail: u32,
    /// Span start, microseconds since the journal's origin.
    pub start_us: u64,
    pub dur_us: u64,
}

#[derive(Debug)]
struct ShardInner {
    /// Preallocated to the shard cap at construction; `next` wraps.
    slots: Vec<SpanRecord>,
    next: usize,
}

#[derive(Debug)]
struct Shard {
    inner: Mutex<ShardInner>,
    recorded: AtomicU64,
}

/// Bounded span storage: one ring of `cap_per_shard` preallocated slots
/// per [`SpanKind`]. Total memory is `COUNT * cap_per_shard *
/// size_of::<SpanRecord>()` forever — recording never allocates.
#[derive(Debug)]
pub struct SpanJournal {
    cap_per_shard: usize,
    origin: Instant,
    shards: [Shard; SpanKind::COUNT],
}

impl SpanJournal {
    pub fn new(cap_per_shard: usize) -> SpanJournal {
        let cap = cap_per_shard.max(1);
        SpanJournal {
            cap_per_shard: cap,
            origin: Instant::now(),
            shards: std::array::from_fn(|_| Shard {
                inner: Mutex::new(ShardInner { slots: Vec::with_capacity(cap), next: 0 }),
                recorded: AtomicU64::new(0),
            }),
        }
    }

    /// Ring capacity per kind (the bound pinned by tests).
    pub fn cap_per_shard(&self) -> usize {
        self.cap_per_shard
    }

    /// Microseconds since the journal's origin for `at` (0 if earlier).
    pub fn us_since_origin(&self, at: Instant) -> u64 {
        at.checked_duration_since(self.origin).unwrap_or(Duration::ZERO).as_micros() as u64
    }

    /// Record one span that started at `start` and ran for `dur`.
    pub fn record(
        &self,
        request_id: u64,
        bundle_id: u64,
        kind: SpanKind,
        detail: u32,
        start: Instant,
        dur: Duration,
    ) {
        let rec = SpanRecord {
            request_id,
            bundle_id,
            kind,
            detail,
            start_us: self.us_since_origin(start),
            dur_us: dur.as_micros() as u64,
        };
        let shard = &self.shards[kind as usize];
        let mut inner = shard.inner.lock().unwrap();
        if inner.slots.len() < self.cap_per_shard {
            inner.slots.push(rec);
        } else {
            let at = inner.next;
            inner.slots[at] = rec;
        }
        inner.next = (inner.next + 1) % self.cap_per_shard;
        drop(inner);
        shard.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Spans currently retained (≤ `COUNT * cap_per_shard`).
    pub fn retained(&self) -> usize {
        self.shards.iter().map(|s| s.inner.lock().unwrap().slots.len()).sum()
    }

    /// Lifetime spans recorded per kind (overflow means older ones were
    /// overwritten in that kind's ring).
    pub fn recorded_by_kind(&self) -> [(SpanKind, u64); SpanKind::COUNT] {
        let mut out = [(SpanKind::Admit, 0u64); SpanKind::COUNT];
        for (i, k) in SpanKind::all().into_iter().enumerate() {
            out[i] = (k, self.shards[i].recorded.load(Ordering::Relaxed));
        }
        out
    }

    /// All retained spans for one request, joined with bundle-scoped
    /// spans (`request_id == 0`) whose bundle id matches any of the
    /// request's spans, sorted by start time.
    pub fn for_request(&self, request_id: u64) -> Vec<SpanRecord> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.inner.lock().unwrap().slots.iter().copied());
        }
        let bundles: Vec<u64> = all
            .iter()
            .filter(|r| r.request_id == request_id && r.bundle_id != 0)
            .map(|r| r.bundle_id)
            .collect();
        let mut out: Vec<SpanRecord> = all
            .into_iter()
            .filter(|r| {
                r.request_id == request_id
                    || (r.request_id == 0 && r.bundle_id != 0 && bundles.contains(&r.bundle_id))
            })
            .collect();
        out.sort_by_key(|r| (r.start_us, r.kind as u8, r.detail));
        out
    }
}

/// Typed lifecycle events (the *when/which/why* behind the counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    Quarantine = 0,
    Respawn = 1,
    RespawnFailed = 2,
    Reroute = 3,
    EngineTimeout = 4,
    ArtifactSwap = 5,
    ArtifactRollback = 6,
    Degraded = 7,
    CodecSwitch = 8,
    /// Typed BUSY admission rejection (detail carries retry_after_ms),
    /// so overload episodes are reconstructible from the journal.
    Busy = 9,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Quarantine => "quarantine",
            EventKind::Respawn => "respawn",
            EventKind::RespawnFailed => "respawn_failed",
            EventKind::Reroute => "reroute",
            EventKind::EngineTimeout => "engine_timeout",
            EventKind::ArtifactSwap => "artifact_swap",
            EventKind::ArtifactRollback => "artifact_rollback",
            EventKind::Degraded => "degraded",
            EventKind::CodecSwitch => "codec_switch",
            EventKind::Busy => "busy",
        }
    }

    pub fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::Quarantine,
            1 => EventKind::Respawn,
            2 => EventKind::RespawnFailed,
            3 => EventKind::Reroute,
            4 => EventKind::EngineTimeout,
            5 => EventKind::ArtifactSwap,
            6 => EventKind::ArtifactRollback,
            7 => EventKind::Degraded,
            8 => EventKind::CodecSwitch,
            9 => EventKind::Busy,
            _ => return None,
        })
    }
}

/// One journal entry. `seq` is a gap-free global sequence number, so a
/// consumer can detect eviction (retained front's seq > last seen + 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EventRecord {
    pub seq: u64,
    /// Microseconds since the journal's origin.
    pub at_us: u64,
    pub kind: EventKind,
    /// Fleet replica index, when the event concerns one.
    pub replica: Option<usize>,
    /// Short human-readable cause ("probe failed", reroute reason, …).
    pub detail: String,
}

/// Bounded, sequence-numbered event storage (FIFO eviction at `cap`).
#[derive(Debug)]
pub struct EventJournal {
    cap: usize,
    origin: Instant,
    seq: AtomicU64,
    evicted: AtomicU64,
    inner: Mutex<VecDeque<EventRecord>>,
}

impl EventJournal {
    pub fn new(cap: usize) -> EventJournal {
        let cap = cap.max(1);
        EventJournal {
            cap,
            origin: Instant::now(),
            seq: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            inner: Mutex::new(VecDeque::with_capacity(cap)),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Append one event; evicts the oldest entry at the cap.
    pub fn record(&self, kind: EventKind, replica: Option<usize>, detail: impl Into<String>) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let at_us =
            Instant::now().checked_duration_since(self.origin).unwrap_or(Duration::ZERO).as_micros()
                as u64;
        let rec = EventRecord { seq, at_us, kind, replica, detail: detail.into() };
        let mut q = self.inner.lock().unwrap();
        if q.len() == self.cap {
            q.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(rec);
    }

    /// Lifetime events recorded (== next seq).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Lifetime events FIFO-evicted at the cap: `recorded - evicted`
    /// entries are retained, and a consumer that sees the retained
    /// front's seq exceed its last-seen seq + 1 knows history was
    /// dropped rather than silently lost.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Retained entries, oldest first.
    pub fn snapshot(&self) -> Vec<EventRecord> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    /// Retained entries of one kind, oldest first.
    pub fn of_kind(&self, kind: EventKind) -> Vec<EventRecord> {
        self.inner.lock().unwrap().iter().filter(|e| e.kind == kind).cloned().collect()
    }
}

/// The per-service observability hub: both journals plus the bundle-id
/// mint, behind a single enable gate. Lives on
/// [`crate::metrics::ServingMetrics`] so everything that already holds
/// the metrics (scheduler, fleet wiring, server) can record.
#[derive(Debug)]
pub struct Obs {
    enabled: AtomicBool,
    pub spans: SpanJournal,
    pub events: EventJournal,
    /// Decision ledger + guarantee auditor. Gated by its own enabled
    /// flag (`config.obs.ledger`), independent of span/event tracing.
    pub ledger: ledger::Ledger,
    next_bundle: AtomicU64,
}

impl Default for Obs {
    fn default() -> Obs {
        Obs::new(true, 4096, 1024)
    }
}

impl Obs {
    pub fn new(enabled: bool, span_cap: usize, event_cap: usize) -> Obs {
        Obs {
            enabled: AtomicBool::new(enabled),
            spans: SpanJournal::new(span_cap),
            events: EventJournal::new(event_cap),
            ledger: ledger::Ledger::default(),
            next_bundle: AtomicU64::new(1),
        }
    }

    /// Replace the default (in-memory, cap 1024) ledger — used by
    /// service startup to apply `config.obs.ledger`.
    pub fn with_ledger(mut self, ledger: ledger::Ledger) -> Obs {
        self.ledger = ledger;
        self
    }

    /// Disabled hub: every record call short-circuits on one atomic load.
    pub fn disabled() -> Obs {
        Obs::new(false, 1, 1).with_ledger(ledger::Ledger::disabled())
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Mint a bundle id (1-based; 0 means "no bundle"). Minting stays
    /// live even when disabled so toggling obs mid-run can't collide ids.
    pub fn next_bundle_id(&self) -> u64 {
        self.next_bundle.fetch_add(1, Ordering::Relaxed)
    }

    /// Record a span iff enabled.
    pub fn span(
        &self,
        request_id: u64,
        bundle_id: u64,
        kind: SpanKind,
        detail: u32,
        start: Instant,
        dur: Duration,
    ) {
        if self.enabled() {
            self.spans.record(request_id, bundle_id, kind, detail, start, dur);
        }
    }

    /// Record a lifecycle event iff enabled.
    pub fn event(&self, kind: EventKind, replica: Option<usize>, detail: impl Into<String>) {
        if self.enabled() {
            self.events.record(kind, replica, detail);
        }
    }
}

/// Ambient per-thread refine scope: carries the current bundle id into
/// executor internals (fleet dispatch) without widening the `Executor`
/// trait, and accumulates the replica-id / reroute trail for the opt-in
/// per-response timing breakdown. All calls are no-ops when no scope is
/// open, so executors used outside the coordinator are unaffected.
pub mod scope {
    use std::cell::RefCell;

    #[derive(Debug, Default, Clone)]
    pub struct ScopeData {
        pub bundle_id: u64,
        /// Fleet replica indices touched, in dispatch order (deduped).
        pub replicas: Vec<u32>,
        pub reroutes: u32,
    }

    thread_local! {
        static SCOPE: RefCell<Option<ScopeData>> = const { RefCell::new(None) };
    }

    /// Open a scope for the current thread's in-flight bundle. The
    /// previous scope (if any) is returned for restore-on-drop callers;
    /// the coordinator's stages never nest, so they pass it straight to
    /// [`end`].
    pub fn begin(bundle_id: u64) -> Option<ScopeData> {
        SCOPE.with(|s| s.replace(Some(ScopeData { bundle_id, ..ScopeData::default() })))
    }

    /// Close the current scope, returning its accumulated trail and
    /// restoring `prev`.
    pub fn end(prev: Option<ScopeData>) -> Option<ScopeData> {
        SCOPE.with(|s| s.replace(prev))
    }

    /// Current bundle id, or 0 outside any scope.
    pub fn bundle_id() -> u64 {
        SCOPE.with(|s| s.borrow().as_ref().map_or(0, |d| d.bundle_id))
    }

    /// Note a dispatch landing on fleet replica `idx`.
    pub fn note_replica(idx: u32) {
        SCOPE.with(|s| {
            if let Some(d) = s.borrow_mut().as_mut() {
                if !d.replicas.contains(&idx) {
                    d.replicas.push(idx);
                }
            }
        });
    }

    /// Note a fleet reroute (failed dispatch retried elsewhere).
    pub fn note_reroute() {
        SCOPE.with(|s| {
            if let Some(d) = s.borrow_mut().as_mut() {
                d.reroutes += 1;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ring_is_strictly_bounded_and_overwrites_oldest() {
        let j = SpanJournal::new(4);
        let t0 = Instant::now();
        for i in 0..10u64 {
            j.record(i, 1, SpanKind::Draft, 0, t0, Duration::from_micros(i));
        }
        assert_eq!(j.retained(), 4, "ring must cap at 4");
        let by_kind = j.recorded_by_kind();
        assert_eq!(by_kind[SpanKind::Draft as usize].1, 10);
        // The survivors are the 4 newest records (6..=9).
        let mut ids: Vec<u64> = j
            .for_request(6)
            .iter()
            .chain(j.for_request(7).iter())
            .chain(j.for_request(8).iter())
            .chain(j.for_request(9).iter())
            .map(|r| r.request_id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert!(j.for_request(3).is_empty(), "overwritten record must be gone");
    }

    #[test]
    fn span_memory_bound_holds_across_all_shards() {
        let j = SpanJournal::new(2);
        let t0 = Instant::now();
        for k in SpanKind::all() {
            for i in 0..5u64 {
                j.record(i, 0, k, 0, t0, Duration::ZERO);
            }
        }
        assert_eq!(j.retained(), 2 * SpanKind::COUNT);
    }

    #[test]
    fn for_request_joins_bundle_scoped_spans_and_sorts() {
        let j = SpanJournal::new(64);
        let t0 = Instant::now();
        let t = |us: u64| t0 + Duration::from_micros(us);
        // Request 42 rode bundle 7; request 43 rode bundle 8.
        j.record(42, 7, SpanKind::BatcherWait, 0, t(5), Duration::from_micros(3));
        j.record(42, 7, SpanKind::Admit, 0, t(1), Duration::ZERO);
        j.record(0, 7, SpanKind::Draft, 0, t(10), Duration::from_micros(20));
        j.record(0, 7, SpanKind::EngineCall, 2, t(31), Duration::from_micros(9));
        j.record(0, 8, SpanKind::Draft, 0, t(11), Duration::from_micros(20));
        j.record(43, 8, SpanKind::Admit, 0, t(2), Duration::ZERO);
        let spans = j.for_request(42);
        let kinds: Vec<SpanKind> = spans.iter().map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![SpanKind::Admit, SpanKind::BatcherWait, SpanKind::Draft, SpanKind::EngineCall],
            "sorted by start, bundle-7 spans joined, bundle-8 excluded"
        );
        assert_eq!(spans[3].detail, 2, "replica index rides detail");
        assert!(j.for_request(999).is_empty());
    }

    #[test]
    fn event_journal_caps_fifo_and_keeps_gap_free_seq() {
        let j = EventJournal::new(3);
        for i in 0..7 {
            j.record(EventKind::Quarantine, Some(i % 2), format!("e{i}"));
        }
        assert_eq!(j.recorded(), 7);
        assert_eq!(j.evicted(), 4, "7 recorded - 3 retained = 4 evicted");
        let kept = j.snapshot();
        assert_eq!(kept.len(), 3, "FIFO eviction at cap");
        let seqs: Vec<u64> = kept.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![4, 5, 6], "oldest evicted, seq gap-free");
        assert_eq!(kept[0].detail, "e4");
        assert_eq!(j.recorded() - j.evicted(), kept.len() as u64);
    }

    #[test]
    fn disabled_obs_records_nothing_but_still_mints_bundle_ids() {
        let o = Obs::disabled();
        o.span(1, 1, SpanKind::Admit, 0, Instant::now(), Duration::ZERO);
        o.event(EventKind::Reroute, None, "x");
        assert_eq!(o.spans.retained(), 0);
        assert_eq!(o.events.recorded(), 0);
        assert!(!o.ledger.enabled(), "disabled hub disables the ledger too");
        assert_eq!(o.next_bundle_id(), 1);
        assert_eq!(o.next_bundle_id(), 2);
        o.set_enabled(true);
        o.event(EventKind::Reroute, None, "y");
        assert_eq!(o.events.recorded(), 1);
    }

    #[test]
    fn scope_carries_bundle_and_trail_and_is_noop_outside() {
        scope::note_replica(5); // no scope open: must not panic, must not leak
        assert_eq!(scope::bundle_id(), 0);
        let prev = scope::begin(17);
        assert_eq!(scope::bundle_id(), 17);
        scope::note_replica(2);
        scope::note_replica(2);
        scope::note_replica(0);
        scope::note_reroute();
        let data = scope::end(prev).expect("scope was open");
        assert_eq!(data.bundle_id, 17);
        assert_eq!(data.replicas, vec![2, 0], "deduped, dispatch order");
        assert_eq!(data.reroutes, 1);
        assert_eq!(scope::bundle_id(), 0, "scope closed");
    }

    #[test]
    fn span_kind_and_event_kind_round_trip_u8() {
        for k in SpanKind::all() {
            assert_eq!(SpanKind::from_u8(k as u8), Some(k));
        }
        assert_eq!(SpanKind::from_u8(200), None);
        for v in 0..=9u8 {
            let k = EventKind::from_u8(v).unwrap();
            assert_eq!(k as u8, v);
        }
        assert_eq!(EventKind::from_u8(10), None);
    }
}
