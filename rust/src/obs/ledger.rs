//! The decision ledger and guarantee auditor (EXPERIMENTS.md §Audit).
//!
//! The paper's headline claim is a *guaranteed* speed-up: adaptive
//! warm-start NFE must never exceed the static `t0_min` floor, and a
//! degraded response must never bill refinement it did not run. Until
//! now that contract lived in `debug_assert`s and fixed-seed tests —
//! invisible in production. This module makes it a live, queryable
//! surface:
//!
//! * [`DecisionRecord`] — one typed record per refined (or degraded)
//!   bundle: what the controller/cascade *decided* (chosen t0 and the
//!   grid it came from, proxy score, gate threshold/verdicts) and what
//!   it *cost* (per-stage NFE, realized NFE vs the guarantee floor,
//!   replica trail), plus everything deterministic replay needs
//!   (config/bundle seeds, per-request seeds, output hashes).
//! * [`Ledger`] — a bounded in-memory ring of records plus an optional
//!   append-only JSONL sink (`config.obs.ledger.{enabled,cap,path}`).
//!   Each record is one line, written and flushed atomically under the
//!   sink lock, so a crash mid-write loses at most the final record —
//!   [`read_ledger`] tolerates exactly that torn tail.
//! * [`audit`] — the production invariant checker run on every append:
//!   realized NFE ≤ floor, per-stage NFE sums to the total, early exit
//!   implies a passed gate, degraded implies NFE 0. Violations bump the
//!   `guarantee_violations` counter surfaced in the stats snapshot; in
//!   a healthy deployment it is 0 forever.
//! * [`Ledger::drift_report`] — windowed Welford statistics (mean/var +
//!   p50/p95) of proxy scores and `nfe_saved` per `(domain, draft)`
//!   cell, banded against the calibration table so an operator can see
//!   a draft model drifting away from its calibrated score range before
//!   quality regresses.
//!
//! Like everything in [`crate::obs`], the ledger is strictly write-only
//! with respect to scheduling: records are built *after* the tokens
//! exist, nothing here feeds RNG or batching, and the determinism
//! sweeps pin that outputs are bitwise-identical with the ledger on or
//! off.

use crate::core::rng::{fnv1a64, FNV_OFFSET};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-request slice of a [`DecisionRecord`]: identity, demand, the
/// request's RNG seed (a `bundle_seed` input), and the FNV-1a hash of
/// the response's sample rows — the replay comparison target.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub n_samples: usize,
    pub seed: u64,
    /// [`hash_samples`] over the rows this request received.
    pub out_hash: u64,
}

/// One bundle's decision + outcome, as recorded by the refine paths
/// (per-bundle, composed, and degraded-fallback).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    pub bundle_id: u64,
    pub domain: String,
    pub tag: String,
    /// Draft kind name ([`crate::coordinator::request::DraftSpec`]).
    pub draft: String,
    pub steps_cold: usize,
    /// The *requested* t0 (bundle-key resolution) — a `bundle_seed`
    /// input, distinct from `chosen_t0` under adaptive controllers.
    pub requested_t0: f64,
    pub warp_literal: bool,
    /// Controller mode name plus the clamp range and discrete grid the
    /// choice was made from — enough to rebuild the controller offline.
    pub control_mode: String,
    pub t0_min: f64,
    pub t0_max: f64,
    pub grid: Vec<f64>,
    /// Draft-quality proxy score (scored mode only).
    pub score: Option<f64>,
    pub chosen_t0: f64,
    pub cascade_mode: String,
    pub ladder: Vec<f64>,
    /// Gate threshold in effect (`gated` mode only).
    pub gate_threshold: Option<f64>,
    /// Gate scores of the deepest chunk, in stage order (the chunk that
    /// defined `nfe_per_stage`).
    pub gate_scores: Vec<f64>,
    /// The gate score that triggered the earliest exit among chunks,
    /// when any chunk exited early — the auditor's gate-pass witness.
    pub exit_score: Option<f64>,
    /// Per-stage NFE of the deepest chunk (empty when the cascade is
    /// off).
    pub nfe_per_stage: Vec<usize>,
    pub early_exit: bool,
    /// Realized NFE billed to every response in the bundle.
    pub nfe: usize,
    /// `guaranteed_nfe` floor the controller budgeted against.
    pub nfe_floor: usize,
    pub degraded: bool,
    /// Fleet replica trail (deduped, dispatch order); empty on the
    /// composed path, where dispatches serve many bundles at once.
    pub replicas: Vec<u32>,
    pub reroutes: u32,
    pub config_seed: u64,
    pub bundle_seed: u64,
    pub requests: Vec<RequestRecord>,
}

/// Process-stable FNV-1a hash of sample rows, length-framed so row
/// boundaries cannot alias. The replay comparison target.
pub fn hash_samples(samples: &[Vec<i32>]) -> u64 {
    let mut h = FNV_OFFSET;
    for row in samples {
        h = fnv1a64(h, &(row.len() as u64).to_le_bytes());
        for &t in row {
            h = fnv1a64(h, &t.to_le_bytes());
        }
    }
    h
}

fn f64_arr(xs: &[f64]) -> Json {
    Json::arr(xs.iter().map(|&x| Json::num(x)))
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::arr(xs.iter().map(|&x| Json::num(x as f64)))
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::num(v),
        None => Json::Null,
    }
}

fn parse_f64_arr(j: &Json, field: &str) -> Result<Vec<f64>> {
    j.as_arr()
        .with_context(|| format!("ledger record: {field} must be an array"))?
        .iter()
        .map(|v| v.as_f64().with_context(|| format!("ledger record: {field} entry not a number")))
        .collect()
}

fn parse_usize_arr(j: &Json, field: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .with_context(|| format!("ledger record: {field} must be an array"))?
        .iter()
        .map(|v| {
            v.as_usize().with_context(|| format!("ledger record: {field} entry not an integer"))
        })
        .collect()
}

impl RequestRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::u64(self.id)),
            ("n", Json::num(self.n_samples as f64)),
            ("seed", Json::u64(self.seed)),
            ("out_hash", Json::u64(self.out_hash)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RequestRecord> {
        Ok(RequestRecord {
            id: j.get("id").as_u64().context("request record: id")?,
            n_samples: j.get("n").as_usize().context("request record: n")?,
            seed: j.get("seed").as_u64().context("request record: seed")?,
            out_hash: j.get("out_hash").as_u64().context("request record: out_hash")?,
        })
    }
}

impl DecisionRecord {
    /// Canonical JSON object (fixed key order; seeds and hashes as exact
    /// u64, so values ≥ 2^53 survive the round trip).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bundle_id", Json::u64(self.bundle_id)),
            ("domain", Json::str(self.domain.clone())),
            ("tag", Json::str(self.tag.clone())),
            ("draft", Json::str(self.draft.clone())),
            ("steps_cold", Json::num(self.steps_cold as f64)),
            ("requested_t0", Json::num(self.requested_t0)),
            ("warp_literal", Json::Bool(self.warp_literal)),
            ("control_mode", Json::str(self.control_mode.clone())),
            ("t0_min", Json::num(self.t0_min)),
            ("t0_max", Json::num(self.t0_max)),
            ("grid", f64_arr(&self.grid)),
            ("score", opt_num(self.score)),
            ("chosen_t0", Json::num(self.chosen_t0)),
            ("cascade_mode", Json::str(self.cascade_mode.clone())),
            ("ladder", f64_arr(&self.ladder)),
            ("gate_threshold", opt_num(self.gate_threshold)),
            ("gate_scores", f64_arr(&self.gate_scores)),
            ("exit_score", opt_num(self.exit_score)),
            ("nfe_per_stage", usize_arr(&self.nfe_per_stage)),
            ("early_exit", Json::Bool(self.early_exit)),
            ("nfe", Json::num(self.nfe as f64)),
            ("nfe_floor", Json::num(self.nfe_floor as f64)),
            ("degraded", Json::Bool(self.degraded)),
            ("replicas", Json::arr(self.replicas.iter().map(|&r| Json::num(r as f64)))),
            ("reroutes", Json::num(self.reroutes as f64)),
            ("config_seed", Json::u64(self.config_seed)),
            ("bundle_seed", Json::u64(self.bundle_seed)),
            ("requests", Json::arr(self.requests.iter().map(|r| r.to_json()))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DecisionRecord> {
        let opt = |key: &str| -> Result<Option<f64>> {
            let v = j.get(key);
            if v.is_null() {
                Ok(None)
            } else {
                Ok(Some(v.as_f64().with_context(|| format!("ledger record: {key}"))?))
            }
        };
        Ok(DecisionRecord {
            bundle_id: j.get("bundle_id").as_u64().context("ledger record: bundle_id")?,
            domain: j.get("domain").as_str().context("ledger record: domain")?.to_string(),
            tag: j.get("tag").as_str().context("ledger record: tag")?.to_string(),
            draft: j.get("draft").as_str().context("ledger record: draft")?.to_string(),
            steps_cold: j.get("steps_cold").as_usize().context("ledger record: steps_cold")?,
            requested_t0: j.get("requested_t0").as_f64().context("ledger record: requested_t0")?,
            warp_literal: j.get("warp_literal").as_bool().context("ledger record: warp_literal")?,
            control_mode: j
                .get("control_mode")
                .as_str()
                .context("ledger record: control_mode")?
                .to_string(),
            t0_min: j.get("t0_min").as_f64().context("ledger record: t0_min")?,
            t0_max: j.get("t0_max").as_f64().context("ledger record: t0_max")?,
            grid: parse_f64_arr(j.get("grid"), "grid")?,
            score: opt("score")?,
            chosen_t0: j.get("chosen_t0").as_f64().context("ledger record: chosen_t0")?,
            cascade_mode: j
                .get("cascade_mode")
                .as_str()
                .context("ledger record: cascade_mode")?
                .to_string(),
            ladder: parse_f64_arr(j.get("ladder"), "ladder")?,
            gate_threshold: opt("gate_threshold")?,
            gate_scores: parse_f64_arr(j.get("gate_scores"), "gate_scores")?,
            exit_score: opt("exit_score")?,
            nfe_per_stage: parse_usize_arr(j.get("nfe_per_stage"), "nfe_per_stage")?,
            early_exit: j.get("early_exit").as_bool().context("ledger record: early_exit")?,
            nfe: j.get("nfe").as_usize().context("ledger record: nfe")?,
            nfe_floor: j.get("nfe_floor").as_usize().context("ledger record: nfe_floor")?,
            degraded: j.get("degraded").as_bool().context("ledger record: degraded")?,
            replicas: parse_usize_arr(j.get("replicas"), "replicas")?
                .into_iter()
                .map(|r| r as u32)
                .collect(),
            reroutes: j.get("reroutes").as_usize().context("ledger record: reroutes")? as u32,
            config_seed: j.get("config_seed").as_u64().context("ledger record: config_seed")?,
            bundle_seed: j.get("bundle_seed").as_u64().context("ledger record: bundle_seed")?,
            requests: j
                .get("requests")
                .as_arr()
                .context("ledger record: requests")?
                .iter()
                .map(RequestRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Total samples across the bundle's requests.
    pub fn total_samples(&self) -> usize {
        self.requests.iter().map(|r| r.n_samples).sum()
    }
}

/// The guarantee auditor: check one record against the serving
/// invariants. `Err` names the violated invariant (the caller counts it
/// in `guarantee_violations`).
///
/// 1. A refined bundle never exceeds the guarantee floor:
///    `!degraded ⇒ nfe ≤ nfe_floor`.
/// 2. Stage accounting is consistent: a non-empty `nfe_per_stage` sums
///    to `nfe`.
/// 3. An early exit is only ever the result of a *passed* gate:
///    `early_exit ⇒ exit_score ≥ gate_threshold`.
/// 4. A degraded response bills no refinement: `degraded ⇒ nfe == 0`.
pub fn audit(rec: &DecisionRecord) -> Result<(), String> {
    if !rec.degraded && rec.nfe > rec.nfe_floor {
        return Err(format!(
            "guarantee violated: nfe {} > floor {} (bundle {})",
            rec.nfe, rec.nfe_floor, rec.bundle_id
        ));
    }
    if !rec.nfe_per_stage.is_empty() && rec.nfe_per_stage.iter().sum::<usize>() != rec.nfe {
        return Err(format!(
            "stage accounting inconsistent: {:?} does not sum to nfe {} (bundle {})",
            rec.nfe_per_stage, rec.nfe, rec.bundle_id
        ));
    }
    if rec.early_exit {
        match (rec.exit_score, rec.gate_threshold) {
            (Some(s), Some(th)) if s >= th => {}
            _ => {
                return Err(format!(
                    "early exit without a passed gate: exit_score {:?} threshold {:?} (bundle {})",
                    rec.exit_score, rec.gate_threshold, rec.bundle_id
                ));
            }
        }
    }
    if rec.degraded && rec.nfe != 0 {
        return Err(format!(
            "degraded response bills nfe {} (bundle {})",
            rec.nfe, rec.bundle_id
        ));
    }
    Ok(())
}

/// Sliding per-cell sample window for drift detection.
const DRIFT_WINDOW: usize = 256;
/// Below this many samples a cell reports `warming`, not a verdict.
const DRIFT_MIN_SAMPLES: u64 = 16;

#[derive(Debug, Default)]
struct DriftWindow {
    /// `(proxy score or NaN when unscored, nfe_saved)` per record,
    /// oldest first, capped at [`DRIFT_WINDOW`].
    samples: VecDeque<(f64, f64)>,
    seen: u64,
}

/// Windowed summary statistics (Welford mean/variance + rank p50/p95)
/// over one drift-cell dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftStats {
    pub count: u64,
    pub mean: f64,
    /// Population variance of the window.
    pub var: f64,
    pub p50: f64,
    pub p95: f64,
}

impl DriftStats {
    /// Welford's online algorithm over the window (single pass, no
    /// catastrophic cancellation), plus sorted-rank percentiles.
    fn compute(values: &[f64]) -> DriftStats {
        let (mut mean, mut m2, mut n) = (0.0f64, 0.0f64, 0u64);
        for &x in values {
            n += 1;
            let d = x - mean;
            mean += d / n as f64;
            m2 += d * (x - mean);
        }
        let var = if n > 0 { m2 / n as f64 } else { 0.0 };
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
            sorted[idx.min(sorted.len() - 1)]
        };
        DriftStats { count: n, mean, var, p50: pct(50.0), p95: pct(95.0) }
    }
}

/// One `(domain, draft)` drift cell: windowed stats for the proxy score
/// and `nfe_saved`, banded against the calibration table.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftCellReport {
    pub domain: String,
    pub draft: String,
    /// Stats over *scored* records only (unscored modes leave no score).
    pub score: DriftStats,
    pub nfe_saved: DriftStats,
    /// Calibration band index of the windowed mean score (row in the
    /// descending `(min_score, t0)` table), when scores exist.
    pub band: Option<usize>,
    /// `warming` (window not yet full enough), `ok`, or `drifting`.
    pub status: &'static str,
}

/// Calibration band lookup: index of the first row (descending
/// `min_score` order, the controller's own convention) whose threshold
/// the score meets.
fn band_of(score: f64, calibration: &[(f64, f64)]) -> Option<usize> {
    calibration.iter().position(|&(min_score, _)| score >= min_score)
}

/// The bounded decision ledger: in-memory ring + guarantee auditor +
/// drift windows + optional JSONL sink. Lives on [`crate::obs::Obs`];
/// every refine path appends exactly one record per bundle outcome.
#[derive(Debug)]
pub struct Ledger {
    enabled: AtomicBool,
    cap: usize,
    appended: AtomicU64,
    evicted: AtomicU64,
    violations: AtomicU64,
    sink_errors: AtomicU64,
    inner: Mutex<LedgerInner>,
    sink: Option<Mutex<File>>,
}

#[derive(Debug, Default)]
struct LedgerInner {
    ring: VecDeque<DecisionRecord>,
    drift: BTreeMap<(String, String), DriftWindow>,
}

impl Default for Ledger {
    fn default() -> Ledger {
        Ledger::new(true, 1024)
    }
}

impl Ledger {
    /// In-memory ledger (no sink).
    pub fn new(enabled: bool, cap: usize) -> Ledger {
        Ledger {
            enabled: AtomicBool::new(enabled),
            cap: cap.max(1),
            appended: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            violations: AtomicU64::new(0),
            sink_errors: AtomicU64::new(0),
            inner: Mutex::new(LedgerInner::default()),
            sink: None,
        }
    }

    /// Disabled ledger: every append short-circuits on one atomic load.
    pub fn disabled() -> Ledger {
        Ledger::new(false, 1)
    }

    /// Build from `config.obs.ledger`, opening the append-only JSONL
    /// sink when a path is configured. A sink that cannot be opened
    /// degrades to in-memory (serving must not die for observability).
    pub fn from_config(cfg: &crate::config::LedgerConfig) -> Ledger {
        let mut ledger = Ledger::new(cfg.enabled, cfg.cap);
        if cfg.enabled && !cfg.path.is_empty() {
            match OpenOptions::new().create(true).append(true).open(&cfg.path) {
                Ok(f) => ledger.sink = Some(Mutex::new(f)),
                Err(e) => crate::error!("ledger sink {:?} unavailable ({e}); in-memory only", cfg.path),
            }
        }
        ledger
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Lifetime records appended.
    pub fn appended(&self) -> u64 {
        self.appended.load(Ordering::Relaxed)
    }

    /// Records evicted from the in-memory ring (the JSONL sink, when
    /// configured, still has them).
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Auditor failures observed ([`audit`]); 0 in a healthy deployment.
    pub fn violations(&self) -> u64 {
        self.violations.load(Ordering::Relaxed)
    }

    /// Sink write failures (the record still landed in the ring).
    pub fn sink_errors(&self) -> u64 {
        self.sink_errors.load(Ordering::Relaxed)
    }

    /// Ring capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Retained records, oldest first.
    pub fn snapshot(&self) -> Vec<DecisionRecord> {
        self.inner.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Append one record: audit it, window it for drift, ring it, and
    /// (when configured) write one JSONL line. Strictly observational —
    /// never returns an error to the serving path.
    pub fn append(&self, rec: DecisionRecord) {
        if !self.enabled() {
            return;
        }
        if let Err(why) = audit(&rec) {
            self.violations.fetch_add(1, Ordering::Relaxed);
            crate::error!("ledger auditor: {why}");
        }
        let line = if self.sink.is_some() { Some(rec.to_json().to_string()) } else { None };
        {
            let mut inner = self.inner.lock().unwrap();
            let nfe_saved = rec.nfe_floor.saturating_sub(rec.nfe) as f64;
            let cell = inner
                .drift
                .entry((rec.domain.clone(), rec.draft.clone()))
                .or_default();
            if cell.samples.len() == DRIFT_WINDOW {
                cell.samples.pop_front();
            }
            cell.samples.push_back((rec.score.unwrap_or(f64::NAN), nfe_saved));
            cell.seen += 1;
            if inner.ring.len() == self.cap {
                inner.ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
            inner.ring.push_back(rec);
        }
        if let (Some(sink), Some(line)) = (&self.sink, line) {
            let mut f = sink.lock().unwrap();
            // One line per record, flushed under the lock: a crash can
            // tear at most the final line, which `read_ledger` drops.
            if writeln!(f, "{line}").and_then(|_| f.flush()).is_err() {
                self.sink_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.appended.fetch_add(1, Ordering::Relaxed);
    }

    /// Drift report over every `(domain, draft)` cell, banded against a
    /// calibration table (descending `(min_score, t0)` rows — the
    /// controller's own table). A cell is `drifting` when its windowed
    /// mean and median land in different calibration bands (the score
    /// distribution straddles a decision boundary, so the controller's
    /// t0 choices have become unstable for that draft source);
    /// `warming` until the window holds [`DRIFT_MIN_SAMPLES`] records.
    pub fn drift_report(&self, calibration: &[(f64, f64)]) -> Vec<DriftCellReport> {
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(inner.drift.len());
        for ((domain, draft), win) in inner.drift.iter() {
            let scores: Vec<f64> =
                win.samples.iter().map(|&(s, _)| s).filter(|s| !s.is_nan()).collect();
            let saved: Vec<f64> = win.samples.iter().map(|&(_, v)| v).collect();
            let score = DriftStats::compute(&scores);
            let nfe_saved = DriftStats::compute(&saved);
            let band = (score.count > 0).then(|| band_of(score.mean, calibration)).flatten();
            let status = if win.seen < DRIFT_MIN_SAMPLES {
                "warming"
            } else if score.count > 0
                && band_of(score.mean, calibration) != band_of(score.p50, calibration)
            {
                "drifting"
            } else {
                "ok"
            };
            out.push(DriftCellReport {
                domain: domain.clone(),
                draft: draft.clone(),
                score,
                nfe_saved,
                band,
                status,
            });
        }
        out
    }
}

/// Parse a JSONL ledger file. Returns the records plus a `torn` flag:
/// an unparseable **final** line on a file without a trailing newline is
/// the documented crash-mid-write case and is dropped silently-but-
/// flagged; garbage anywhere else is a real error.
pub fn read_ledger(path: &Path) -> Result<(Vec<DecisionRecord>, bool)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading ledger {}", path.display()))?;
    let clean_tail = text.ends_with('\n');
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut records = Vec::with_capacity(lines.len());
    let mut torn = false;
    for (i, line) in lines.iter().enumerate() {
        let last = i + 1 == lines.len();
        let parsed = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .and_then(|j| DecisionRecord::from_json(&j));
        match parsed {
            Ok(rec) => records.push(rec),
            Err(_) if last && !clean_tail => {
                // The torn-final-line contract: at most one record lost.
                torn = true;
            }
            Err(e) => bail!("ledger {} line {}: {e:#}", path.display(), i + 1),
        }
    }
    Ok((records, torn))
}

/// Render per-`(domain, draft)` decision/outcome tables for `wsfm
/// audit`: record counts, NFE totals vs floors, early exits, degraded
/// counts, and chosen-t0 spread — the offline view of what the
/// controller did with each draft source.
pub fn render_audit(records: &[DecisionRecord]) -> String {
    use std::fmt::Write as _;
    let mut cells: BTreeMap<(String, String), Vec<&DecisionRecord>> = BTreeMap::new();
    for r in records {
        cells.entry((r.domain.clone(), r.draft.clone())).or_default().push(r);
    }
    let mut out = String::new();
    let mut violations = 0usize;
    let _ = writeln!(out, "ledger: {} records, {} cells", records.len(), cells.len());
    let _ = writeln!(
        out,
        "{:<24} {:>7} {:>9} {:>9} {:>7} {:>9} {:>9} {:>10}",
        "domain/draft", "records", "nfe", "floor", "saved", "early", "degraded", "t0 range"
    );
    for ((domain, draft), rs) in &cells {
        let nfe: usize = rs.iter().map(|r| r.nfe).sum();
        let floor: usize = rs.iter().map(|r| r.nfe_floor).sum();
        let early = rs.iter().filter(|r| r.early_exit).count();
        let degraded = rs.iter().filter(|r| r.degraded).count();
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for r in rs.iter() {
            lo = lo.min(r.chosen_t0);
            hi = hi.max(r.chosen_t0);
        }
        violations += rs.iter().filter(|r| audit(r).is_err()).count();
        let _ = writeln!(
            out,
            "{:<24} {:>7} {:>9} {:>9} {:>7} {:>9} {:>9} {:>4.2}-{:<4.2}",
            format!("{domain}/{draft}"),
            rs.len(),
            nfe,
            floor,
            floor.saturating_sub(nfe),
            early,
            degraded,
            lo,
            hi
        );
    }
    let _ = writeln!(out, "guarantee violations: {violations}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(bundle_id: u64) -> DecisionRecord {
        DecisionRecord {
            bundle_id,
            domain: "two_moons".into(),
            tag: "cold".into(),
            draft: "noise".into(),
            steps_cold: 10,
            requested_t0: 0.5,
            warp_literal: true,
            control_mode: "scored".into(),
            t0_min: 0.35,
            t0_max: 0.95,
            grid: vec![0.35, 0.5, 0.8, 0.95],
            score: Some(0.41),
            chosen_t0: 0.5,
            cascade_mode: "gated".into(),
            ladder: vec![0.75, 0.9],
            gate_threshold: Some(0.45),
            gate_scores: vec![0.3, 0.5],
            exit_score: Some(0.5),
            nfe_per_stage: vec![3, 1],
            early_exit: true,
            nfe: 4,
            nfe_floor: 7,
            degraded: false,
            replicas: vec![2, 0],
            reroutes: 1,
            config_seed: 99,
            // Above 2^53: pins the exact-u64 JSON path.
            bundle_seed: 0xDEAD_BEEF_CAFE_F00D,
            requests: vec![
                RequestRecord { id: 7, n_samples: 2, seed: 1000, out_hash: u64::MAX - 3 },
                RequestRecord { id: 8, n_samples: 1, seed: 1001, out_hash: 42 },
            ],
        }
    }

    #[test]
    fn record_json_round_trips_exactly() {
        let rec = record(3);
        let j = rec.to_json().to_string();
        let back = DecisionRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, rec);
        // Seeds/hashes above 2^53 survive (the Json::u64 path).
        assert_eq!(back.bundle_seed, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(back.requests[0].out_hash, u64::MAX - 3);
        // A second serialization is byte-identical (canonical key order).
        assert_eq!(back.to_json().to_string(), j);
    }

    #[test]
    fn sample_hash_frames_row_boundaries() {
        let a = hash_samples(&[vec![1, 2], vec![3]]);
        let b = hash_samples(&[vec![1], vec![2, 3]]);
        let c = hash_samples(&[vec![1, 2, 3]]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, hash_samples(&[vec![1, 2], vec![3]]));
    }

    #[test]
    fn auditor_accepts_healthy_records() {
        assert!(audit(&record(1)).is_ok());
        // Cascade off: empty stages, no gates.
        let mut plain = record(2);
        plain.cascade_mode = "off".into();
        plain.nfe_per_stage.clear();
        plain.gate_scores.clear();
        plain.gate_threshold = None;
        plain.exit_score = None;
        plain.early_exit = false;
        plain.nfe = 5;
        assert!(audit(&plain).is_ok());
    }

    #[test]
    fn auditor_flags_each_invariant() {
        // 1. NFE above the guarantee floor.
        let mut r = record(1);
        r.nfe = 8;
        r.nfe_per_stage = vec![4, 4];
        r.early_exit = false;
        assert!(audit(&r).unwrap_err().contains("guarantee violated"));
        // 2. Stage sum mismatch.
        let mut r = record(2);
        r.nfe_per_stage = vec![3, 3];
        assert!(audit(&r).unwrap_err().contains("stage accounting"));
        // 3. Early exit without a passed gate.
        let mut r = record(3);
        r.exit_score = Some(0.1);
        assert!(audit(&r).unwrap_err().contains("early exit"));
        let mut r = record(4);
        r.exit_score = None;
        assert!(audit(&r).unwrap_err().contains("early exit"));
        // 4. Degraded response billing refinement.
        let mut r = record(5);
        r.degraded = true;
        r.early_exit = false;
        r.nfe_per_stage.clear();
        assert!(audit(&r).unwrap_err().contains("degraded"));
    }

    #[test]
    fn ledger_rings_audits_and_counts() {
        let ledger = Ledger::new(true, 2);
        for i in 0..3 {
            ledger.append(record(i));
        }
        assert_eq!(ledger.appended(), 3);
        assert_eq!(ledger.evicted(), 1);
        assert_eq!(ledger.violations(), 0);
        let kept = ledger.snapshot();
        assert_eq!(kept.len(), 2, "ring caps at 2");
        assert_eq!(kept[0].bundle_id, 1, "oldest evicted first");
        // A violating record is retained AND counted.
        let mut bad = record(9);
        bad.nfe = 99;
        bad.nfe_per_stage.clear();
        bad.early_exit = false;
        ledger.append(bad);
        assert_eq!(ledger.violations(), 1);
        assert_eq!(ledger.snapshot().last().unwrap().bundle_id, 9);
    }

    #[test]
    fn disabled_ledger_records_nothing() {
        let ledger = Ledger::disabled();
        ledger.append(record(1));
        assert_eq!(ledger.appended(), 0);
        assert!(ledger.snapshot().is_empty());
        assert_eq!(ledger.violations(), 0);
    }

    #[test]
    fn jsonl_sink_round_trips_through_read_ledger() {
        let dir = std::env::temp_dir().join(format!("wsfm_ledger_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.jsonl");
        let _ = std::fs::remove_file(&path);
        let cfg = crate::config::LedgerConfig {
            enabled: true,
            cap: 8,
            path: path.to_string_lossy().into_owned(),
        };
        let ledger = Ledger::from_config(&cfg);
        let want: Vec<DecisionRecord> = (0..3).map(record).collect();
        for r in &want {
            ledger.append(r.clone());
        }
        assert_eq!(ledger.sink_errors(), 0);
        let (got, torn) = read_ledger(&path).unwrap();
        assert!(!torn);
        assert_eq!(got, want, "write → parse must be identical records");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_dropped_and_flagged() {
        let dir = std::env::temp_dir().join(format!("wsfm_ledger_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        let mut text = String::new();
        text.push_str(&record(1).to_json().to_string());
        text.push('\n');
        text.push_str(&record(2).to_json().to_string());
        text.push('\n');
        // Crash mid-write: the final record is cut off, no newline.
        let full = record(3).to_json().to_string();
        text.push_str(&full[..full.len() / 2]);
        std::fs::write(&path, &text).unwrap();
        let (got, torn) = read_ledger(&path).unwrap();
        assert!(torn, "torn tail must be flagged");
        assert_eq!(got.len(), 2, "at most the final record is lost");
        assert_eq!(got[0].bundle_id, 1);
        assert_eq!(got[1].bundle_id, 2);
        // Garbage mid-file is NOT the torn case: hard error.
        let bad = format!("{}\nnot json\n{}\n", record(1).to_json(), record(2).to_json());
        std::fs::write(&path, &bad).unwrap();
        assert!(read_ledger(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn drift_report_bands_and_flags_straddling_distributions() {
        // Calibration table in the controller's descending convention.
        let table = [(0.9, 0.95), (0.65, 0.8), (0.5, 0.65), (0.0, 0.35)];
        let ledger = Ledger::new(true, 1024);
        // Cell A: tight scores inside one band -> ok.
        for i in 0..20 {
            let mut r = record(i);
            r.draft = "good".into();
            r.score = Some(0.91 + (i % 3) as f64 * 0.01);
            r.exit_score = Some(0.91);
            ledger.append(r);
        }
        // Cell B: bimodal scores straddling a band boundary -> the mean
        // lands in a different band than the median -> drifting.
        for i in 0..20 {
            let mut r = record(100 + i);
            r.draft = "fair".into();
            r.score = Some(if i % 2 == 0 { 0.95 } else { 0.05 });
            r.exit_score = Some(0.95);
            ledger.append(r);
        }
        // Cell C: too few samples -> warming.
        for i in 0..3 {
            let mut r = record(200 + i);
            r.draft = "poor".into();
            r.score = Some(0.3);
            r.exit_score = Some(0.5);
            ledger.append(r);
        }
        let report = ledger.drift_report(&table);
        assert_eq!(report.len(), 3);
        let cell = |d: &str| report.iter().find(|c| c.draft == d).unwrap();
        let good = cell("good");
        assert_eq!(good.status, "ok");
        assert_eq!(good.band, Some(0));
        assert_eq!(good.score.count, 20);
        assert!(good.score.mean > 0.9 && good.score.var < 0.01);
        assert_eq!(good.nfe_saved.count, 20);
        assert_eq!(good.nfe_saved.p50, 3.0); // floor 7 - nfe 4
        let fair = cell("fair");
        assert_eq!(fair.status, "drifting", "straddling distribution must flag");
        let poor = cell("poor");
        assert_eq!(poor.status, "warming");
        // Welford mean/var sanity on the bimodal cell: mean 0.5, var 0.2025.
        assert!((fair.score.mean - 0.5).abs() < 1e-12);
        assert!((fair.score.var - 0.2025).abs() < 1e-12);
    }

    #[test]
    fn drift_window_is_bounded() {
        let ledger = Ledger::new(true, 4);
        for i in 0..(DRIFT_WINDOW as u64 + 50) {
            ledger.append(record(i));
        }
        let report = ledger.drift_report(&[(0.0, 0.35)]);
        assert_eq!(report.len(), 1);
        assert_eq!(report[0].score.count as usize, DRIFT_WINDOW, "window must cap");
        // The ring stayed at its own (smaller) cap.
        assert_eq!(ledger.snapshot().len(), 4);
    }

    #[test]
    fn audit_rendering_summarizes_cells() {
        let mut records: Vec<DecisionRecord> = (0..4).map(record).collect();
        records[3].degraded = true;
        records[3].nfe = 0;
        records[3].early_exit = false;
        records[3].nfe_per_stage.clear();
        let text = render_audit(&records);
        assert!(text.contains("4 records"), "{text}");
        assert!(text.contains("two_moons/noise"), "{text}");
        assert!(text.contains("guarantee violations: 0"), "{text}");
    }
}
