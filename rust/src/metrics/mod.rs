//! Serving metrics: counters, latency histograms with percentile queries,
//! and throughput meters. Lock-cheap (atomics + a mutex-guarded histogram)
//! and shared across coordinator workers.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (e.g. bundles currently in the pipeline).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exact percentiles over a bounded reservoir.
///
/// Keeps up to `cap` most-recent samples (ring buffer); p50/p95/p99 queries
/// sort a snapshot. At serving rates of ~1e3-1e5 samples this is exact
/// enough and allocation-stable.
#[derive(Debug)]
pub struct LatencyHistogram {
    cap: usize,
    inner: Mutex<HistInner>,
}

#[derive(Debug)]
struct HistInner {
    samples: Vec<u64>, // nanos, ring buffer
    next: usize,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> Self {
        LatencyHistogram {
            cap: cap.max(16),
            inner: Mutex::new(HistInner { samples: Vec::new(), next: 0, count: 0, sum_ns: 0, max_ns: 0 }),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let mut g = self.inner.lock().unwrap();
        if g.samples.len() < self.cap {
            g.samples.push(ns);
        } else {
            let idx = g.next;
            g.samples[idx] = ns;
            g.next = (g.next + 1) % self.cap;
        }
        g.count += 1;
        g.sum_ns += ns as u128;
        g.max_ns = g.max_ns.max(ns);
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let g = self.inner.lock().unwrap();
        let mut v = g.samples.clone();
        v.sort_unstable();
        let pct = |p: f64| -> Duration {
            if v.is_empty() {
                return Duration::ZERO;
            }
            let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
            Duration::from_nanos(v[idx])
        };
        LatencySnapshot {
            count: g.count,
            mean: if g.count > 0 {
                Duration::from_nanos((g.sum_ns / g.count as u128) as u64)
            } else {
                Duration::ZERO
            },
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: Duration::from_nanos(g.max_ns),
        }
    }
}

/// Point-in-time percentile view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl LatencySnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.2?} p50={:.2?} p95={:.2?} p99={:.2?} max={:.2?}",
            self.count, self.mean, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Throughput meter: events per second over the meter's lifetime.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    events: Counter,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), events: Counter::default() }
    }
    pub fn record(&self, n: u64) {
        self.events.add(n);
    }
    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.events.get() as f64 / secs
    }
    pub fn total(&self) -> u64 {
        self.events.get()
    }
}

/// The serving metrics bundle shared by the coordinator.
#[derive(Debug)]
pub struct ServingMetrics {
    pub requests_admitted: Counter,
    pub requests_rejected: Counter,
    pub requests_completed: Counter,
    pub batches_executed: Counter,
    pub denoiser_calls: Counter,
    pub draft_calls: Counter,
    /// Draft models actually resolved (cache misses); compare against
    /// `draft_calls` to see the scheduler's draft-model cache working.
    pub draft_models_resolved: Counter,
    pub padded_rows: Counter,
    /// Bundles dispatched into the pipeline and not yet completed.
    pub inflight_bundles: Gauge,
    /// Flushed bundle → DRAFT-stage pickup wait (pipeline only).
    pub draft_queue_wait: LatencyHistogram,
    /// How far past its deadline a deadline-flushed bundle was dispatched.
    pub flush_lag: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub batch_exec: LatencyHistogram,
    pub request_latency: LatencyHistogram,
    pub samples: Throughput,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            requests_admitted: Counter::default(),
            requests_rejected: Counter::default(),
            requests_completed: Counter::default(),
            batches_executed: Counter::default(),
            denoiser_calls: Counter::default(),
            draft_calls: Counter::default(),
            draft_models_resolved: Counter::default(),
            padded_rows: Counter::default(),
            inflight_bundles: Gauge::default(),
            draft_queue_wait: LatencyHistogram::new(4096),
            flush_lag: LatencyHistogram::new(4096),
            queue_wait: LatencyHistogram::new(4096),
            batch_exec: LatencyHistogram::new(4096),
            request_latency: LatencyHistogram::new(4096),
            samples: Throughput::new(),
        }
    }
}

impl ServingMetrics {
    pub fn report(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} batches={} denoiser_calls={} draft_calls={} draft_models_resolved={} padded_rows={} inflight_bundles={} samples/s={:.2}\n  {}\n  {}\n  {}\n  {}\n  {}",
            self.requests_admitted.get(),
            self.requests_rejected.get(),
            self.requests_completed.get(),
            self.batches_executed.get(),
            self.denoiser_calls.get(),
            self.draft_calls.get(),
            self.draft_models_resolved.get(),
            self.padded_rows.get(),
            self.inflight_bundles.get(),
            self.samples.per_second(),
            self.queue_wait.snapshot().report("queue_wait"),
            self.draft_queue_wait.snapshot().report("draft_queue_wait"),
            self.flush_lag.snapshot().report("flush_lag"),
            self.batch_exec.snapshot().report("batch_exec"),
            self.request_latency.snapshot().report("request_latency"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(100));
        assert!((s.p50.as_micros() as i64 - 50).abs() <= 2, "{:?}", s.p50);
    }

    #[test]
    fn histogram_ring_buffer_wraps() {
        let h = LatencyHistogram::new(16);
        for i in 0..100u64 {
            h.record(Duration::from_nanos(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // Only recent 16 retained; p50 should be among the high values.
        assert!(s.p50 >= Duration::from_nanos(84));
    }

    #[test]
    fn empty_histogram_snapshot() {
        let s = LatencyHistogram::new(64).snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.record(10);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(t.total(), 10);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn gauge_goes_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn serving_metrics_report_contains_fields() {
        let m = ServingMetrics::default();
        m.requests_admitted.inc();
        m.inflight_bundles.inc();
        let r = m.report();
        assert!(r.contains("admitted=1"));
        assert!(r.contains("inflight_bundles=1"));
        assert!(r.contains("draft_queue_wait"));
        assert!(r.contains("flush_lag"));
        assert!(r.contains("request_latency"));
    }
}
