//! Serving metrics: counters, latency histograms with percentile queries,
//! and throughput meters. Lock-cheap (atomics + a mutex-guarded histogram)
//! and shared across coordinator workers.
//!
//! Since PR 9 everything renders through typed snapshots: [`ServingMetrics
//! ::snapshot`]/[`FleetMetrics::snapshot`] capture a point-in-time
//! [`ServingSnapshot`]/[`FleetSnapshot`], and [`MetricsSnapshot`] bundles
//! both for the live stats wire surface (`{"cmd":"stats"}`). The legacy
//! one-shot summary strings are *renderings* of the same snapshot
//! ([`ServingSnapshot::render_legacy`], pinned byte-identical by a golden
//! test), alongside JSON (`to_json`/`from_json`, durations as exact
//! nanosecond integers) and Prometheus-style text exposition
//! ([`MetricsSnapshot::render_prometheus`], served by `wsfm stats`).

use crate::obs::Obs;
use crate::util::json::Json;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (e.g. bundles currently in the pipeline).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }
    /// Overwrite with a point-in-time level (e.g. batch occupancy after a
    /// composed step).
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exact percentiles over a bounded reservoir.
///
/// Keeps up to `cap` most-recent samples (ring buffer); p50/p95/p99 queries
/// sort a snapshot. At serving rates of ~1e3-1e5 samples this is exact
/// enough and allocation-stable.
#[derive(Debug)]
pub struct LatencyHistogram {
    cap: usize,
    inner: Mutex<HistInner>,
}

#[derive(Debug)]
struct HistInner {
    samples: Vec<u64>, // nanos, ring buffer
    next: usize,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> Self {
        LatencyHistogram {
            cap: cap.max(16),
            inner: Mutex::new(HistInner { samples: Vec::new(), next: 0, count: 0, sum_ns: 0, max_ns: 0 }),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let mut g = self.inner.lock().unwrap();
        if g.samples.len() < self.cap {
            g.samples.push(ns);
        } else {
            let idx = g.next;
            g.samples[idx] = ns;
            g.next = (g.next + 1) % self.cap;
        }
        g.count += 1;
        g.sum_ns += ns as u128;
        g.max_ns = g.max_ns.max(ns);
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let g = self.inner.lock().unwrap();
        let mut v = g.samples.clone();
        v.sort_unstable();
        let pct = |p: f64| -> Duration {
            if v.is_empty() {
                return Duration::ZERO;
            }
            let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
            Duration::from_nanos(v[idx])
        };
        LatencySnapshot {
            count: g.count,
            mean: if g.count > 0 {
                Duration::from_nanos((g.sum_ns / g.count as u128) as u64)
            } else {
                Duration::ZERO
            },
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: Duration::from_nanos(g.max_ns),
        }
    }
}

/// Point-in-time percentile view.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl LatencySnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.2?} p50={:.2?} p95={:.2?} p99={:.2?} max={:.2?}",
            self.count, self.mean, self.p50, self.p95, self.p99, self.max
        )
    }

    /// Durations as exact nanosecond integers, so a wire round-trip on
    /// either codec reproduces the snapshot bit-for-bit.
    pub fn to_json(&self) -> Json {
        let ns = |d: Duration| Json::u64(d.as_nanos().min(u64::MAX as u128) as u64);
        Json::obj(vec![
            ("count", Json::u64(self.count)),
            ("mean_ns", ns(self.mean)),
            ("p50_ns", ns(self.p50)),
            ("p95_ns", ns(self.p95)),
            ("p99_ns", ns(self.p99)),
            ("max_ns", ns(self.max)),
        ])
    }

    pub fn from_json(j: &Json) -> LatencySnapshot {
        let ns = |k: &str| Duration::from_nanos(j.get(k).as_u64().unwrap_or(0));
        LatencySnapshot {
            count: j.get("count").as_u64().unwrap_or(0),
            mean: ns("mean_ns"),
            p50: ns("p50_ns"),
            p95: ns("p95_ns"),
            p99: ns("p99_ns"),
            max: ns("max_ns"),
        }
    }
}

/// Unitless value histogram (e.g. the controller's chosen t0 per bundle):
/// bounded most-recent reservoir like [`LatencyHistogram`], but over f64
/// samples instead of durations.
#[derive(Debug)]
pub struct ValueHistogram {
    cap: usize,
    inner: Mutex<ValueInner>,
}

#[derive(Debug)]
struct ValueInner {
    samples: Vec<f64>, // ring buffer
    next: usize,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl ValueHistogram {
    pub fn new(cap: usize) -> Self {
        ValueHistogram {
            cap: cap.max(16),
            inner: Mutex::new(ValueInner {
                samples: Vec::new(),
                next: 0,
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }),
        }
    }

    pub fn record(&self, v: f64) {
        let mut g = self.inner.lock().unwrap();
        if g.samples.len() < self.cap {
            g.samples.push(v);
        } else {
            let idx = g.next;
            g.samples[idx] = v;
            g.next = (g.next + 1) % self.cap;
        }
        g.count += 1;
        g.sum += v;
        g.min = g.min.min(v);
        g.max = g.max.max(v);
    }

    pub fn snapshot(&self) -> ValueSnapshot {
        let g = self.inner.lock().unwrap();
        let mut v = g.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pct = |p: f64| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v[((p / 100.0) * (v.len() - 1) as f64).round() as usize]
        };
        ValueSnapshot {
            count: g.count,
            mean: if g.count > 0 { g.sum / g.count as f64 } else { 0.0 },
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            min: if g.count > 0 { g.min } else { 0.0 },
            max: if g.count > 0 { g.max } else { 0.0 },
        }
    }
}

/// Point-in-time view of a [`ValueHistogram`], with percentile summaries
/// (p50/p95/p99 over the retained reservoir) like its latency
/// counterpart.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ValueSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl ValueSnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.count, self.mean, self.p50, self.p95, self.p99, self.min, self.max
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::u64(self.count)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("min", Json::num(self.min)),
            ("max", Json::num(self.max)),
        ])
    }

    pub fn from_json(j: &Json) -> ValueSnapshot {
        let f = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
        ValueSnapshot {
            count: j.get("count").as_u64().unwrap_or(0),
            mean: f("mean"),
            p50: f("p50"),
            p95: f("p95"),
            p99: f("p99"),
            min: f("min"),
            max: f("max"),
        }
    }
}

/// Metrics for the replicated executor fleet ([`crate::fleet`]). Owned by
/// the `FleetHandle` rather than [`ServingMetrics`] because the fleet is
/// constructed before the serving service exists (and is useful without
/// one, e.g. under `wsfm selfcheck`); the CLI prints
/// [`FleetMetrics::summary`] alongside the serving report.
#[derive(Debug)]
pub struct FleetMetrics {
    /// Executor calls currently running on each replica (index = replica
    /// id). The router picks the healthy replica with the lowest value.
    pub replica_inflight: Vec<Gauge>,
    /// Calls routed to each replica over the fleet's lifetime.
    pub replica_dispatched: Vec<Counter>,
    /// Replicas marked unhealthy after their engine thread died (or a
    /// watchdog timeout quarantined them).
    pub replica_unhealthy: Counter,
    /// Calls re-routed to another replica after a dead one was observed.
    pub fleet_reroutes: Counter,
    /// Quarantined replicas brought back by the health loop (fresh engine
    /// + re-preload + passing probe).
    pub replica_respawns: Counter,
    /// Respawn attempts that failed (spawn error or failed probe); the
    /// circuit breaker retires a replica after `max_respawns` consecutive
    /// ones.
    pub respawn_failures: Counter,
    /// Calls that tripped the engine-call watchdog (`EngineTimeout`).
    pub engine_timeouts: Counter,
    /// Completed all-or-nothing artifact swaps ([`swap_artifacts`]:
    /// every replica now serves the new manifest).
    ///
    /// [`swap_artifacts`]: ../fleet/struct.FleetHandle.html#method.swap_artifacts
    pub artifact_swaps: Counter,
    /// Artifact swaps abandoned before publication (a replacement failed
    /// to build, preload, or probe — the old fleet kept serving).
    pub artifact_swap_rollbacks: Counter,
}

impl FleetMetrics {
    pub fn new(replicas: usize) -> Self {
        FleetMetrics {
            replica_inflight: (0..replicas).map(|_| Gauge::default()).collect(),
            replica_dispatched: (0..replicas).map(|_| Counter::default()).collect(),
            replica_unhealthy: Counter::default(),
            fleet_reroutes: Counter::default(),
            replica_respawns: Counter::default(),
            respawn_failures: Counter::default(),
            engine_timeouts: Counter::default(),
            artifact_swaps: Counter::default(),
            artifact_swap_rollbacks: Counter::default(),
        }
    }

    /// Capture a point-in-time typed view.
    pub fn snapshot(&self) -> FleetSnapshot {
        FleetSnapshot {
            replicas: self.replica_inflight.len(),
            replica_inflight: self.replica_inflight.iter().map(|g| g.get()).collect(),
            replica_dispatched: self.replica_dispatched.iter().map(|c| c.get()).collect(),
            replica_unhealthy: self.replica_unhealthy.get(),
            fleet_reroutes: self.fleet_reroutes.get(),
            replica_respawns: self.replica_respawns.get(),
            respawn_failures: self.respawn_failures.get(),
            engine_timeouts: self.engine_timeouts.get(),
            artifact_swaps: self.artifact_swaps.get(),
            artifact_swap_rollbacks: self.artifact_swap_rollbacks.get(),
        }
    }

    /// One-line rendering for the serve/selfcheck summary.
    pub fn summary(&self) -> String {
        self.snapshot().render_legacy()
    }
}

/// Point-in-time typed view of [`FleetMetrics`] (the `fleet:` summary
/// line, the stats wire surface, and the Prometheus exposition all render
/// from this).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FleetSnapshot {
    pub replicas: usize,
    pub replica_inflight: Vec<i64>,
    pub replica_dispatched: Vec<u64>,
    pub replica_unhealthy: u64,
    pub fleet_reroutes: u64,
    pub replica_respawns: u64,
    pub respawn_failures: u64,
    pub engine_timeouts: u64,
    pub artifact_swaps: u64,
    pub artifact_swap_rollbacks: u64,
}

impl FleetSnapshot {
    /// The pre-PR-9 `FleetMetrics::summary` string, byte-identical.
    pub fn render_legacy(&self) -> String {
        let join = |it: Vec<String>| it.join(",");
        format!(
            "replicas={} replica_inflight=[{}] replica_dispatched=[{}] replica_unhealthy={} fleet_reroutes={} replica_respawns={} respawn_failures={} engine_timeouts={} artifact_swaps={} artifact_swap_rollbacks={}",
            self.replicas,
            join(self.replica_inflight.iter().map(|g| g.to_string()).collect()),
            join(self.replica_dispatched.iter().map(|c| c.to_string()).collect()),
            self.replica_unhealthy,
            self.fleet_reroutes,
            self.replica_respawns,
            self.respawn_failures,
            self.engine_timeouts,
            self.artifact_swaps,
            self.artifact_swap_rollbacks
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::u64(self.replicas as u64)),
            (
                "replica_inflight",
                Json::arr(self.replica_inflight.iter().map(|&g| Json::num(g as f64))),
            ),
            (
                "replica_dispatched",
                Json::arr(self.replica_dispatched.iter().map(|&c| Json::u64(c))),
            ),
            ("replica_unhealthy", Json::u64(self.replica_unhealthy)),
            ("fleet_reroutes", Json::u64(self.fleet_reroutes)),
            ("replica_respawns", Json::u64(self.replica_respawns)),
            ("respawn_failures", Json::u64(self.respawn_failures)),
            ("engine_timeouts", Json::u64(self.engine_timeouts)),
            ("artifact_swaps", Json::u64(self.artifact_swaps)),
            ("artifact_swap_rollbacks", Json::u64(self.artifact_swap_rollbacks)),
        ])
    }

    pub fn from_json(j: &Json) -> FleetSnapshot {
        let u = |k: &str| j.get(k).as_u64().unwrap_or(0);
        FleetSnapshot {
            replicas: j.get("replicas").as_usize().unwrap_or(0),
            replica_inflight: j
                .get("replica_inflight")
                .as_arr()
                .map(|a| a.iter().map(|v| v.as_i64().unwrap_or(0)).collect())
                .unwrap_or_default(),
            replica_dispatched: j
                .get("replica_dispatched")
                .as_arr()
                .map(|a| a.iter().map(|v| v.as_u64().unwrap_or(0)).collect())
                .unwrap_or_default(),
            replica_unhealthy: u("replica_unhealthy"),
            fleet_reroutes: u("fleet_reroutes"),
            replica_respawns: u("replica_respawns"),
            respawn_failures: u("respawn_failures"),
            engine_timeouts: u("engine_timeouts"),
            artifact_swaps: u("artifact_swaps"),
            artifact_swap_rollbacks: u("artifact_swap_rollbacks"),
        }
    }
}

/// Sliding-window width of [`Throughput::windowed_per_second`], seconds.
pub const THROUGHPUT_WINDOW_SECS: u64 = 10;

/// Throughput meter: lifetime events-per-second plus a sliding
/// 10-second-window rate.
///
/// The lifetime rate ([`per_second`]) divides total events by total
/// uptime, so an idle server dilutes it toward 0 no matter how fast the
/// last burst ran. [`windowed_per_second`] fixes that: events land in
/// ten one-second buckets keyed by absolute uptime second (a stale
/// bucket is reset on first write to its second), and the rate is the
/// sum of in-window buckets over the window width — a burst reads at
/// its true recent rate, and after ten idle seconds the windowed rate
/// is exactly 0 (idle, not diluted). Both are exposed on the stats
/// surface; the legacy `report()` line keeps the lifetime rate for
/// byte-compatibility.
///
/// [`per_second`]: Throughput::per_second
/// [`windowed_per_second`]: Throughput::windowed_per_second
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    events: Counter,
    window: Mutex<WindowInner>,
}

#[derive(Debug)]
struct WindowInner {
    /// Events counted during the second recorded in `stamps[i]`.
    buckets: [u64; THROUGHPUT_WINDOW_SECS as usize],
    /// Absolute uptime second each bucket belongs to (slot = sec % W).
    stamps: [u64; THROUGHPUT_WINDOW_SECS as usize],
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            start: Instant::now(),
            events: Counter::default(),
            window: Mutex::new(WindowInner {
                buckets: [0; THROUGHPUT_WINDOW_SECS as usize],
                stamps: [0; THROUGHPUT_WINDOW_SECS as usize],
            }),
        }
    }
    pub fn record(&self, n: u64) {
        self.events.add(n);
        self.record_at(self.start.elapsed().as_secs(), n);
    }
    /// Lifetime rate (diluted by idle time; kept for the legacy report).
    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.events.get() as f64 / secs
    }
    /// Rate over the trailing [`THROUGHPUT_WINDOW_SECS`] seconds.
    pub fn windowed_per_second(&self) -> f64 {
        self.rate_at(self.start.elapsed().as_secs())
    }
    pub fn total(&self) -> u64 {
        self.events.get()
    }

    /// Bucket an event batch under absolute uptime second `sec`
    /// (separated from [`record`](Throughput::record) so tests can pin
    /// the window arithmetic without sleeping).
    fn record_at(&self, sec: u64, n: u64) {
        let mut w = self.window.lock().unwrap();
        let slot = (sec % THROUGHPUT_WINDOW_SECS) as usize;
        if w.stamps[slot] != sec {
            w.stamps[slot] = sec;
            w.buckets[slot] = 0;
        }
        w.buckets[slot] += n;
    }

    /// Windowed rate as seen at absolute uptime second `now_sec`.
    fn rate_at(&self, now_sec: u64) -> f64 {
        let w = self.window.lock().unwrap();
        let sum: u64 = (0..THROUGHPUT_WINDOW_SECS as usize)
            .filter(|&i| now_sec.saturating_sub(w.stamps[i]) < THROUGHPUT_WINDOW_SECS)
            .map(|i| w.buckets[i])
            .sum();
        sum as f64 / THROUGHPUT_WINDOW_SECS as f64
    }
}

/// The serving metrics bundle shared by the coordinator.
#[derive(Debug)]
pub struct ServingMetrics {
    pub requests_admitted: Counter,
    pub requests_rejected: Counter,
    pub requests_completed: Counter,
    pub batches_executed: Counter,
    pub denoiser_calls: Counter,
    pub draft_calls: Counter,
    /// Draft models actually resolved (cache misses); compare against
    /// `draft_calls` to see the scheduler's draft-model cache working.
    pub draft_models_resolved: Counter,
    pub padded_rows: Counter,
    /// Bundles dispatched into the pipeline and not yet completed.
    pub inflight_bundles: Gauge,
    /// Per-bundle t0 the warm-start controller actually ran with
    /// (`control`): equals the requested t0 in `static` mode, the
    /// draft-quality-derived grid value in `prior`/`scored` modes.
    pub chosen_t0: ValueHistogram,
    /// Denoiser evaluations saved vs. the guarantee-floor budget
    /// (`guaranteed_nfe(steps_cold, t0_min)`), summed per executed chunk.
    /// Always 0 in `static` controller mode with the cascade off; a gated
    /// cascade's early exits land here too.
    pub nfe_saved: Counter,
    /// Chunks whose cascade quality gate passed before the final ladder
    /// stage ([`crate::cascade`], `gated` mode).
    pub cascade_early_exits: Counter,
    /// NFE of each executed cascade stage (the per-stage NFE histogram;
    /// only cascade modes record here).
    pub cascade_stage_nfe: ValueHistogram,
    /// Wall-clock of each mid-cascade quality-gate evaluation.
    pub gate_eval: LatencyHistogram,
    /// Flushed bundle → DRAFT-stage pickup wait (pipeline only).
    pub draft_queue_wait: LatencyHistogram,
    /// How far past its deadline a deadline-flushed bundle was dispatched.
    /// Only deadline-or-later dispatches are recorded here; a bundle that
    /// flushes *before* its deadline (size-triggered) lands in
    /// `early_flushes`/`flush_early` instead — a negative lag would
    /// otherwise clamp to a garbage 0 sample through the unsigned
    /// conversion.
    pub flush_lag: LatencyHistogram,
    /// Bundles dispatched before their flush deadline (size-triggered).
    pub early_flushes: Counter,
    /// Responses served from draft tokens after REFINE failed
    /// (`degraded: true` on the wire; counted per request, not per
    /// bundle).
    pub degraded_responses: Counter,
    /// How far *ahead* of its deadline an early-flushed bundle was
    /// dispatched (the headroom the size trigger bought).
    pub flush_early: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub batch_exec: LatencyHistogram,
    pub request_latency: LatencyHistogram,
    pub samples: Throughput,
    /// Useful rows advanced per composed engine step (the step-level
    /// batch composer's merge width; empty when the composer is off).
    pub rows_per_step: ValueHistogram,
    /// Mean row occupancy of the latest composed step's dispatches, in
    /// percent of the dispatch row budget (`composer.max_rows`, else the
    /// family's largest compiled batch; >100 = tiled over several
    /// compiled batches).
    pub batch_occupancy: Gauge,
    /// Codec hellos received on the wire ([`crate::server::codec`]).
    pub wire_hellos: Counter,
    /// Connections that switched off the default codec after a hello.
    pub wire_codec_switches: Counter,
    /// Undecodable inbound wire messages (malformed JSON lines, bad
    /// binary frames) answered with a typed error.
    pub wire_malformed: Counter,
    /// The observability hub ([`crate::obs`]): bounded span + event
    /// journals and the bundle-id mint, shared by everything that holds
    /// the serving metrics (coordinator stages, fleet wiring, server).
    pub obs: Arc<Obs>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            requests_admitted: Counter::default(),
            requests_rejected: Counter::default(),
            requests_completed: Counter::default(),
            batches_executed: Counter::default(),
            denoiser_calls: Counter::default(),
            draft_calls: Counter::default(),
            draft_models_resolved: Counter::default(),
            padded_rows: Counter::default(),
            inflight_bundles: Gauge::default(),
            chosen_t0: ValueHistogram::new(4096),
            nfe_saved: Counter::default(),
            cascade_early_exits: Counter::default(),
            cascade_stage_nfe: ValueHistogram::new(4096),
            gate_eval: LatencyHistogram::new(4096),
            draft_queue_wait: LatencyHistogram::new(4096),
            flush_lag: LatencyHistogram::new(4096),
            early_flushes: Counter::default(),
            degraded_responses: Counter::default(),
            flush_early: LatencyHistogram::new(4096),
            queue_wait: LatencyHistogram::new(4096),
            batch_exec: LatencyHistogram::new(4096),
            request_latency: LatencyHistogram::new(4096),
            samples: Throughput::new(),
            rows_per_step: ValueHistogram::new(4096),
            batch_occupancy: Gauge::default(),
            wire_hellos: Counter::default(),
            wire_codec_switches: Counter::default(),
            wire_malformed: Counter::default(),
            obs: Arc::new(Obs::default()),
        }
    }
}

impl ServingMetrics {
    /// Construct with an explicit observability hub (from `config.obs`).
    pub fn with_obs(obs: Arc<Obs>) -> ServingMetrics {
        ServingMetrics { obs, ..ServingMetrics::default() }
    }

    /// Capture a point-in-time typed view of every serving metric.
    pub fn snapshot(&self) -> ServingSnapshot {
        ServingSnapshot {
            admitted: self.requests_admitted.get(),
            rejected: self.requests_rejected.get(),
            completed: self.requests_completed.get(),
            batches: self.batches_executed.get(),
            denoiser_calls: self.denoiser_calls.get(),
            draft_calls: self.draft_calls.get(),
            draft_models_resolved: self.draft_models_resolved.get(),
            padded_rows: self.padded_rows.get(),
            inflight_bundles: self.inflight_bundles.get(),
            nfe_saved: self.nfe_saved.get(),
            cascade_early_exits: self.cascade_early_exits.get(),
            early_flushes: self.early_flushes.get(),
            degraded: self.degraded_responses.get(),
            batch_occupancy: self.batch_occupancy.get(),
            wire_hellos: self.wire_hellos.get(),
            wire_codec_switches: self.wire_codec_switches.get(),
            wire_malformed: self.wire_malformed.get(),
            samples_total: self.samples.total(),
            samples_per_sec: self.samples.per_second(),
            samples_per_sec_windowed: self.samples.windowed_per_second(),
            obs_spans_recorded: self.obs.spans.recorded_by_kind().iter().map(|&(_, n)| n).sum(),
            obs_events_recorded: self.obs.events.recorded(),
            obs_events_evicted: self.obs.events.evicted(),
            ledger_records: self.obs.ledger.appended(),
            guarantee_violations: self.obs.ledger.violations(),
            chosen_t0: self.chosen_t0.snapshot(),
            rows_per_step: self.rows_per_step.snapshot(),
            cascade_stage_nfe: self.cascade_stage_nfe.snapshot(),
            gate_eval: self.gate_eval.snapshot(),
            queue_wait: self.queue_wait.snapshot(),
            draft_queue_wait: self.draft_queue_wait.snapshot(),
            flush_lag: self.flush_lag.snapshot(),
            flush_early: self.flush_early.snapshot(),
            batch_exec: self.batch_exec.snapshot(),
            request_latency: self.request_latency.snapshot(),
        }
    }

    /// The one-shot serve/selfcheck summary (legacy format, rendered
    /// from [`snapshot`](ServingMetrics::snapshot)).
    pub fn report(&self) -> String {
        self.snapshot().render_legacy()
    }
}

/// Point-in-time typed view of [`ServingMetrics`]. One capture renders
/// the legacy summary string, the stats wire payload (JSON or binary),
/// and the Prometheus text exposition — the numbers can never disagree
/// across surfaces.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServingSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub denoiser_calls: u64,
    pub draft_calls: u64,
    pub draft_models_resolved: u64,
    pub padded_rows: u64,
    pub inflight_bundles: i64,
    pub nfe_saved: u64,
    pub cascade_early_exits: u64,
    pub early_flushes: u64,
    pub degraded: u64,
    pub batch_occupancy: i64,
    pub wire_hellos: u64,
    pub wire_codec_switches: u64,
    pub wire_malformed: u64,
    pub samples_total: u64,
    /// Lifetime samples/s (idle-diluted; what the legacy report prints).
    pub samples_per_sec: f64,
    /// Trailing-window samples/s ([`THROUGHPUT_WINDOW_SECS`]).
    pub samples_per_sec_windowed: f64,
    /// Lifetime spans recorded across all span-journal shards.
    pub obs_spans_recorded: u64,
    /// Lifetime events recorded in the event journal.
    pub obs_events_recorded: u64,
    /// Events FIFO-evicted from the bounded journal (`recorded -
    /// evicted` are retained; nonzero means history was dropped).
    pub obs_events_evicted: u64,
    /// Decision-ledger records appended ([`crate::obs::ledger`]).
    pub ledger_records: u64,
    /// Guarantee-auditor failures over appended ledger records. The
    /// paper's serving contract in one number: **must stay 0**.
    pub guarantee_violations: u64,
    pub chosen_t0: ValueSnapshot,
    pub rows_per_step: ValueSnapshot,
    pub cascade_stage_nfe: ValueSnapshot,
    pub gate_eval: LatencySnapshot,
    pub queue_wait: LatencySnapshot,
    pub draft_queue_wait: LatencySnapshot,
    pub flush_lag: LatencySnapshot,
    pub flush_early: LatencySnapshot,
    pub batch_exec: LatencySnapshot,
    pub request_latency: LatencySnapshot,
}

impl ServingSnapshot {
    /// The pre-PR-9 `ServingMetrics::report()` string, byte-identical
    /// (pinned by a golden test). The windowed rate and obs totals are
    /// deliberately absent — they render only on the new surfaces.
    pub fn render_legacy(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} batches={} denoiser_calls={} draft_calls={} draft_models_resolved={} padded_rows={} inflight_bundles={} nfe_saved={} cascade_early_exits={} early_flushes={} degraded={} batch_occupancy={} wire_hellos={} wire_codec_switches={} wire_malformed={} samples/s={:.2}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}",
            self.admitted,
            self.rejected,
            self.completed,
            self.batches,
            self.denoiser_calls,
            self.draft_calls,
            self.draft_models_resolved,
            self.padded_rows,
            self.inflight_bundles,
            self.nfe_saved,
            self.cascade_early_exits,
            self.early_flushes,
            self.degraded,
            self.batch_occupancy,
            self.wire_hellos,
            self.wire_codec_switches,
            self.wire_malformed,
            self.samples_per_sec,
            self.chosen_t0.report("chosen_t0"),
            self.rows_per_step.report("rows_per_step"),
            self.cascade_stage_nfe.report("cascade_stage_nfe"),
            self.gate_eval.report("gate_eval"),
            self.queue_wait.report("queue_wait"),
            self.draft_queue_wait.report("draft_queue_wait"),
            self.flush_lag.report("flush_lag"),
            self.flush_early.report("flush_early"),
            self.batch_exec.report("batch_exec"),
            self.request_latency.report("request_latency"),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admitted", Json::u64(self.admitted)),
            ("rejected", Json::u64(self.rejected)),
            ("completed", Json::u64(self.completed)),
            ("batches", Json::u64(self.batches)),
            ("denoiser_calls", Json::u64(self.denoiser_calls)),
            ("draft_calls", Json::u64(self.draft_calls)),
            ("draft_models_resolved", Json::u64(self.draft_models_resolved)),
            ("padded_rows", Json::u64(self.padded_rows)),
            ("inflight_bundles", Json::num(self.inflight_bundles as f64)),
            ("nfe_saved", Json::u64(self.nfe_saved)),
            ("cascade_early_exits", Json::u64(self.cascade_early_exits)),
            ("early_flushes", Json::u64(self.early_flushes)),
            ("degraded", Json::u64(self.degraded)),
            ("batch_occupancy", Json::num(self.batch_occupancy as f64)),
            ("wire_hellos", Json::u64(self.wire_hellos)),
            ("wire_codec_switches", Json::u64(self.wire_codec_switches)),
            ("wire_malformed", Json::u64(self.wire_malformed)),
            ("samples_total", Json::u64(self.samples_total)),
            ("samples_per_sec", Json::num(self.samples_per_sec)),
            ("samples_per_sec_windowed", Json::num(self.samples_per_sec_windowed)),
            ("obs_spans_recorded", Json::u64(self.obs_spans_recorded)),
            ("obs_events_recorded", Json::u64(self.obs_events_recorded)),
            ("obs_events_evicted", Json::u64(self.obs_events_evicted)),
            ("ledger_records", Json::u64(self.ledger_records)),
            ("guarantee_violations", Json::u64(self.guarantee_violations)),
            ("chosen_t0", self.chosen_t0.to_json()),
            ("rows_per_step", self.rows_per_step.to_json()),
            ("cascade_stage_nfe", self.cascade_stage_nfe.to_json()),
            ("gate_eval", self.gate_eval.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("draft_queue_wait", self.draft_queue_wait.to_json()),
            ("flush_lag", self.flush_lag.to_json()),
            ("flush_early", self.flush_early.to_json()),
            ("batch_exec", self.batch_exec.to_json()),
            ("request_latency", self.request_latency.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> ServingSnapshot {
        let u = |k: &str| j.get(k).as_u64().unwrap_or(0);
        let f = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
        ServingSnapshot {
            admitted: u("admitted"),
            rejected: u("rejected"),
            completed: u("completed"),
            batches: u("batches"),
            denoiser_calls: u("denoiser_calls"),
            draft_calls: u("draft_calls"),
            draft_models_resolved: u("draft_models_resolved"),
            padded_rows: u("padded_rows"),
            inflight_bundles: j.get("inflight_bundles").as_i64().unwrap_or(0),
            nfe_saved: u("nfe_saved"),
            cascade_early_exits: u("cascade_early_exits"),
            early_flushes: u("early_flushes"),
            degraded: u("degraded"),
            batch_occupancy: j.get("batch_occupancy").as_i64().unwrap_or(0),
            wire_hellos: u("wire_hellos"),
            wire_codec_switches: u("wire_codec_switches"),
            wire_malformed: u("wire_malformed"),
            samples_total: u("samples_total"),
            samples_per_sec: f("samples_per_sec"),
            samples_per_sec_windowed: f("samples_per_sec_windowed"),
            obs_spans_recorded: u("obs_spans_recorded"),
            obs_events_recorded: u("obs_events_recorded"),
            obs_events_evicted: u("obs_events_evicted"),
            ledger_records: u("ledger_records"),
            guarantee_violations: u("guarantee_violations"),
            chosen_t0: ValueSnapshot::from_json(j.get("chosen_t0")),
            rows_per_step: ValueSnapshot::from_json(j.get("rows_per_step")),
            cascade_stage_nfe: ValueSnapshot::from_json(j.get("cascade_stage_nfe")),
            gate_eval: LatencySnapshot::from_json(j.get("gate_eval")),
            queue_wait: LatencySnapshot::from_json(j.get("queue_wait")),
            draft_queue_wait: LatencySnapshot::from_json(j.get("draft_queue_wait")),
            flush_lag: LatencySnapshot::from_json(j.get("flush_lag")),
            flush_early: LatencySnapshot::from_json(j.get("flush_early")),
            batch_exec: LatencySnapshot::from_json(j.get("batch_exec")),
            request_latency: LatencySnapshot::from_json(j.get("request_latency")),
        }
    }
}

/// The full live stats payload: serving metrics plus the fleet's (when a
/// fleet is attached to the server). This is what `{"cmd":"stats"}`
/// returns on either codec and what `wsfm stats` renders as
/// Prometheus-style text.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub serving: ServingSnapshot,
    pub fleet: Option<FleetSnapshot>,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("serving", self.serving.to_json())];
        if let Some(fl) = &self.fleet {
            fields.push(("fleet", fl.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> MetricsSnapshot {
        MetricsSnapshot {
            serving: ServingSnapshot::from_json(j.get("serving")),
            fleet: (!j.get("fleet").is_null()).then(|| FleetSnapshot::from_json(j.get("fleet"))),
        }
    }

    /// Prometheus text exposition (`wsfm stats`): counters and gauges as
    /// plain samples, histograms as quantile-labelled samples + `_count`,
    /// per-replica fleet series with a `replica` label.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let s = &self.serving;
        let mut counter = |name: &str, v: u64| {
            out.push_str(&format!("# TYPE wsfm_{name} counter\nwsfm_{name} {v}\n"));
        };
        counter("requests_admitted_total", s.admitted);
        counter("requests_rejected_total", s.rejected);
        counter("requests_completed_total", s.completed);
        counter("batches_executed_total", s.batches);
        counter("denoiser_calls_total", s.denoiser_calls);
        counter("draft_calls_total", s.draft_calls);
        counter("draft_models_resolved_total", s.draft_models_resolved);
        counter("padded_rows_total", s.padded_rows);
        counter("nfe_saved_total", s.nfe_saved);
        counter("cascade_early_exits_total", s.cascade_early_exits);
        counter("early_flushes_total", s.early_flushes);
        counter("degraded_responses_total", s.degraded);
        counter("wire_hellos_total", s.wire_hellos);
        counter("wire_codec_switches_total", s.wire_codec_switches);
        counter("wire_malformed_total", s.wire_malformed);
        counter("samples_total", s.samples_total);
        counter("obs_spans_recorded_total", s.obs_spans_recorded);
        counter("obs_events_recorded_total", s.obs_events_recorded);
        counter("obs_events_evicted_total", s.obs_events_evicted);
        counter("ledger_records_total", s.ledger_records);
        counter("guarantee_violations_total", s.guarantee_violations);
        let mut gauge = |name: &str, v: f64| {
            out.push_str(&format!("# TYPE wsfm_{name} gauge\nwsfm_{name} {v}\n"));
        };
        gauge("inflight_bundles", s.inflight_bundles as f64);
        gauge("batch_occupancy", s.batch_occupancy as f64);
        gauge("samples_per_sec", s.samples_per_sec);
        gauge("samples_per_sec_windowed", s.samples_per_sec_windowed);
        let mut lat = |name: &str, h: &LatencySnapshot| {
            out.push_str(&format!("# TYPE wsfm_{name}_seconds summary\n"));
            for (q, d) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
                out.push_str(&format!(
                    "wsfm_{name}_seconds{{quantile=\"{q}\"}} {}\n",
                    d.as_secs_f64()
                ));
            }
            out.push_str(&format!("wsfm_{name}_seconds_count {}\n", h.count));
        };
        lat("gate_eval", &s.gate_eval);
        lat("queue_wait", &s.queue_wait);
        lat("draft_queue_wait", &s.draft_queue_wait);
        lat("flush_lag", &s.flush_lag);
        lat("flush_early", &s.flush_early);
        lat("batch_exec", &s.batch_exec);
        lat("request_latency", &s.request_latency);
        let mut val = |name: &str, h: &ValueSnapshot| {
            out.push_str(&format!("# TYPE wsfm_{name} summary\n"));
            for (q, v) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
                out.push_str(&format!("wsfm_{name}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("wsfm_{name}_count {}\n", h.count));
        };
        val("chosen_t0", &s.chosen_t0);
        val("rows_per_step", &s.rows_per_step);
        val("cascade_stage_nfe", &s.cascade_stage_nfe);
        if let Some(fl) = &self.fleet {
            out.push_str(&format!(
                "# TYPE wsfm_fleet_replicas gauge\nwsfm_fleet_replicas {}\n",
                fl.replicas
            ));
            out.push_str("# TYPE wsfm_fleet_replica_inflight gauge\n");
            for (i, g) in fl.replica_inflight.iter().enumerate() {
                out.push_str(&format!("wsfm_fleet_replica_inflight{{replica=\"{i}\"}} {g}\n"));
            }
            out.push_str("# TYPE wsfm_fleet_replica_dispatched_total counter\n");
            for (i, c) in fl.replica_dispatched.iter().enumerate() {
                out.push_str(&format!(
                    "wsfm_fleet_replica_dispatched_total{{replica=\"{i}\"}} {c}\n"
                ));
            }
            let mut fc = |name: &str, v: u64| {
                out.push_str(&format!("# TYPE wsfm_fleet_{name} counter\nwsfm_fleet_{name} {v}\n"));
            };
            fc("replica_unhealthy_total", fl.replica_unhealthy);
            fc("reroutes_total", fl.fleet_reroutes);
            fc("replica_respawns_total", fl.replica_respawns);
            fc("respawn_failures_total", fl.respawn_failures);
            fc("engine_timeouts_total", fl.engine_timeouts);
            fc("artifact_swaps_total", fl.artifact_swaps);
            fc("artifact_swap_rollbacks_total", fl.artifact_swap_rollbacks);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(100));
        assert!((s.p50.as_micros() as i64 - 50).abs() <= 2, "{:?}", s.p50);
    }

    #[test]
    fn histogram_ring_buffer_wraps() {
        let h = LatencyHistogram::new(16);
        for i in 0..100u64 {
            h.record(Duration::from_nanos(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // Only recent 16 retained; p50 should be among the high values.
        assert!(s.p50 >= Duration::from_nanos(84));
    }

    #[test]
    fn empty_histogram_snapshot() {
        let s = LatencyHistogram::new(64).snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.record(10);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(t.total(), 10);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn gauge_goes_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        assert_eq!(g.get(), 0);
        g.set(42);
        assert_eq!(g.get(), 42);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn serving_metrics_report_contains_fields() {
        let m = ServingMetrics::default();
        m.requests_admitted.inc();
        m.inflight_bundles.inc();
        let r = m.report();
        assert!(r.contains("admitted=1"));
        assert!(r.contains("inflight_bundles=1"));
        assert!(r.contains("draft_queue_wait"));
        assert!(r.contains("flush_lag"));
        assert!(r.contains("flush_early"));
        assert!(r.contains("nfe_saved=0"));
        assert!(r.contains("cascade_early_exits=0"));
        assert!(r.contains("cascade_stage_nfe"));
        assert!(r.contains("gate_eval"));
        assert!(r.contains("early_flushes=0"));
        assert!(r.contains("chosen_t0"));
        assert!(r.contains("request_latency"));
        assert!(r.contains("rows_per_step"));
        assert!(r.contains("batch_occupancy=0"));
        assert!(r.contains("wire_hellos=0"));
        assert!(r.contains("wire_codec_switches=0"));
        assert!(r.contains("wire_malformed=0"));
        m.degraded_responses.inc();
        m.batch_occupancy.set(87);
        let r = m.report();
        assert!(r.contains("degraded=1"));
        assert!(r.contains("batch_occupancy=87"));
    }

    #[test]
    fn value_histogram_tracks_stats() {
        let h = ValueHistogram::new(64);
        for v in [0.5, 0.8, 0.8, 0.95, 0.35] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert!((s.min - 0.35).abs() < 1e-12);
        assert!((s.max - 0.95).abs() < 1e-12);
        assert!((s.mean - 0.68).abs() < 1e-9);
        assert!(s.p50 >= s.min && s.p50 <= s.max);
        assert!(s.p95 >= s.p50 && s.p95 <= s.max, "percentiles must be ordered");
        assert!(s.p99 >= s.p95 && s.p99 <= s.max, "p99 sits between p95 and max");
        assert_eq!(s.p50, 0.8);
        assert_eq!(s.p95, 0.95);
        assert_eq!(s.p99, 0.95);
        let rep = s.report("chosen_t0");
        assert!(rep.contains("n=5") && rep.contains("p95=") && rep.contains("p99="), "{rep}");
    }

    #[test]
    fn value_histogram_percentiles_over_uniform_ramp() {
        let h = ValueHistogram::new(1024);
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert!((s.p50 - 50.0).abs() <= 2.0, "{}", s.p50);
        assert!((s.p95 - 95.0).abs() <= 2.0, "{}", s.p95);
        assert!((s.p99 - 99.0).abs() <= 2.0, "{}", s.p99);
        // Empty snapshot keeps all percentiles at zero.
        let e = ValueHistogram::new(16).snapshot();
        assert_eq!((e.p50, e.p95, e.p99), (0.0, 0.0, 0.0));
    }

    #[test]
    fn fleet_metrics_summary_tracks_per_replica_state() {
        let m = FleetMetrics::new(3);
        m.replica_inflight[1].inc();
        m.replica_dispatched[0].add(4);
        m.replica_dispatched[1].inc();
        m.replica_unhealthy.inc();
        m.fleet_reroutes.add(2);
        let s = m.summary();
        assert!(s.contains("replicas=3"), "{s}");
        assert!(s.contains("replica_inflight=[0,1,0]"), "{s}");
        assert!(s.contains("replica_dispatched=[4,1,0]"), "{s}");
        assert!(s.contains("replica_unhealthy=1"), "{s}");
        assert!(s.contains("fleet_reroutes=2"), "{s}");
        m.replica_respawns.inc();
        m.respawn_failures.add(3);
        m.engine_timeouts.add(2);
        let s = m.summary();
        assert!(s.contains("replica_respawns=1"), "{s}");
        assert!(s.contains("respawn_failures=3"), "{s}");
        assert!(s.contains("engine_timeouts=2"), "{s}");
    }

    #[test]
    fn report_renders_the_exact_legacy_string() {
        // Golden pin: the PR-9 snapshot refactor must keep the one-shot
        // serve/selfcheck summary byte-identical to the pre-refactor
        // format string. A default (all-zero) instance has a fully
        // deterministic rendering, including the lifetime samples/s.
        let m = ServingMetrics::default();
        let hist = |name: &str| format!("{name}: n=0 mean=0.00ns p50=0.00ns p95=0.00ns p99=0.00ns max=0.00ns");
        let vhist = |name: &str| format!("{name}: n=0 mean=0.000 p50=0.000 p95=0.000 p99=0.000 min=0.000 max=0.000");
        let expected = format!(
            "admitted=0 rejected=0 completed=0 batches=0 denoiser_calls=0 draft_calls=0 draft_models_resolved=0 padded_rows=0 inflight_bundles=0 nfe_saved=0 cascade_early_exits=0 early_flushes=0 degraded=0 batch_occupancy=0 wire_hellos=0 wire_codec_switches=0 wire_malformed=0 samples/s=0.00\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}",
            vhist("chosen_t0"),
            vhist("rows_per_step"),
            vhist("cascade_stage_nfe"),
            hist("gate_eval"),
            hist("queue_wait"),
            hist("draft_queue_wait"),
            hist("flush_lag"),
            hist("flush_early"),
            hist("batch_exec"),
            hist("request_latency"),
        );
        assert_eq!(m.report(), expected);
        // Poked counters land in the same positions as before.
        m.requests_admitted.add(3);
        m.nfe_saved.add(12);
        m.batch_occupancy.set(87);
        let r = m.report();
        assert!(r.starts_with("admitted=3 rejected=0"), "{r}");
        assert!(r.contains("nfe_saved=12"), "{r}");
        assert!(r.contains("batch_occupancy=87"), "{r}");
        // And the fleet summary delegates through its snapshot verbatim.
        let fm = FleetMetrics::new(2);
        assert_eq!(fm.summary(), fm.snapshot().render_legacy());
    }

    #[test]
    fn windowed_throughput_reads_bursts_and_goes_idle() {
        let t = Throughput::new();
        // A 50-sample burst during uptime second 3.
        t.record_at(3, 50);
        assert_eq!(t.rate_at(3), 5.0, "50 over a 10s window");
        assert_eq!(t.rate_at(12), 5.0, "second 3 is still inside [3, 12]");
        assert_eq!(t.rate_at(13), 0.0, "window slid past the burst: idle reads 0");
        // A second burst 10s later lands in the same slot (13 % 10 == 3)
        // and must displace the stale bucket, not add to it.
        t.record_at(13, 10);
        assert_eq!(t.rate_at(13), 1.0);
        // Spread across several buckets, all in-window.
        t.record_at(14, 10);
        t.record_at(15, 10);
        assert_eq!(t.rate_at(15), 3.0);
    }

    #[test]
    fn lifetime_rate_dilutes_while_windowed_rate_does_not() {
        // The satellite's motivating scenario: a burst followed by idle
        // time. The lifetime rate keeps shrinking as uptime grows; the
        // windowed rate reports the burst at full strength while it is
        // in-window and exactly 0 once it is not.
        let t = Throughput::new();
        t.record_at(0, 100);
        let early = t.rate_at(5);
        let late = t.rate_at(9);
        assert_eq!(early, 10.0);
        assert_eq!(late, 10.0, "windowed rate is idle-invariant in-window");
        assert_eq!(t.rate_at(100), 0.0, "and truly zero once idle");
    }

    #[test]
    fn metrics_snapshot_json_round_trips_exactly() {
        let m = ServingMetrics::default();
        m.requests_admitted.add(7);
        m.queue_wait.record(Duration::from_nanos(123_456_789));
        m.chosen_t0.record(0.8);
        m.samples.record(40);
        m.obs.event(crate::obs::EventKind::Reroute, Some(1), "x");
        let fm = FleetMetrics::new(2);
        fm.replica_dispatched[1].add(9);
        fm.fleet_reroutes.inc();
        let snap = MetricsSnapshot { serving: m.snapshot(), fleet: Some(fm.snapshot()) };
        let wire = snap.to_json().to_string();
        let back = MetricsSnapshot::from_json(&Json::parse(&wire).unwrap());
        assert_eq!(back, snap, "durations ride as exact ns integers");
        assert_eq!(back.serving.obs_events_recorded, 1);
        // Fleet-less snapshot omits the fleet key entirely.
        let solo = MetricsSnapshot { serving: m.snapshot(), fleet: None };
        assert!(!solo.to_json().to_string().contains("\"fleet\""));
        assert_eq!(MetricsSnapshot::from_json(&solo.to_json()).fleet, None);
    }

    #[test]
    fn prometheus_exposition_has_typed_samples() {
        let m = ServingMetrics::default();
        m.requests_completed.add(5);
        m.request_latency.record(Duration::from_millis(2));
        let fm = FleetMetrics::new(2);
        fm.replica_dispatched[0].add(3);
        let snap = MetricsSnapshot { serving: m.snapshot(), fleet: Some(fm.snapshot()) };
        let text = snap.render_prometheus();
        assert!(text.contains("# TYPE wsfm_requests_completed_total counter\n"), "{text}");
        assert!(text.contains("wsfm_requests_completed_total 5\n"), "{text}");
        assert!(text.contains("wsfm_request_latency_seconds{quantile=\"0.5\"} 0.002"), "{text}");
        assert!(text.contains("wsfm_request_latency_seconds{quantile=\"0.99\"} 0.002"), "{text}");
        assert!(text.contains("wsfm_request_latency_seconds_count 1\n"), "{text}");
        assert!(text.contains("wsfm_obs_events_evicted_total 0\n"), "{text}");
        assert!(text.contains("wsfm_ledger_records_total 0\n"), "{text}");
        assert!(text.contains("wsfm_guarantee_violations_total 0\n"), "{text}");
        assert!(text.contains("wsfm_fleet_replica_dispatched_total{replica=\"0\"} 3\n"), "{text}");
        assert!(text.contains("wsfm_fleet_replica_dispatched_total{replica=\"1\"} 0\n"), "{text}");
        assert!(text.contains("wsfm_samples_per_sec_windowed"), "{text}");
        // Fleet-less exposition omits fleet series.
        let solo = MetricsSnapshot { serving: m.snapshot(), fleet: None };
        assert!(!solo.render_prometheus().contains("wsfm_fleet_"));
    }

    #[test]
    fn prometheus_rendering_is_the_exact_golden_string() {
        // Golden pin: the scrape surface is a contract. A default
        // (all-zero, fleet-less) snapshot renders deterministically;
        // any renamed, reordered, or newly added series must show up
        // here as an explicit diff.
        let counter = |n: &str| format!("# TYPE wsfm_{n} counter\nwsfm_{n} 0\n");
        let gauge = |n: &str| format!("# TYPE wsfm_{n} gauge\nwsfm_{n} 0\n");
        let summary = |n: &str| {
            format!(
                "# TYPE wsfm_{n} summary\nwsfm_{n}{{quantile=\"0.5\"}} 0\nwsfm_{n}{{quantile=\"0.95\"}} 0\nwsfm_{n}{{quantile=\"0.99\"}} 0\nwsfm_{n}_count 0\n"
            )
        };
        let mut expected = String::new();
        for c in [
            "requests_admitted_total",
            "requests_rejected_total",
            "requests_completed_total",
            "batches_executed_total",
            "denoiser_calls_total",
            "draft_calls_total",
            "draft_models_resolved_total",
            "padded_rows_total",
            "nfe_saved_total",
            "cascade_early_exits_total",
            "early_flushes_total",
            "degraded_responses_total",
            "wire_hellos_total",
            "wire_codec_switches_total",
            "wire_malformed_total",
            "samples_total",
            "obs_spans_recorded_total",
            "obs_events_recorded_total",
            "obs_events_evicted_total",
            "ledger_records_total",
            "guarantee_violations_total",
        ] {
            expected.push_str(&counter(c));
        }
        for g in
            ["inflight_bundles", "batch_occupancy", "samples_per_sec", "samples_per_sec_windowed"]
        {
            expected.push_str(&gauge(g));
        }
        for h in [
            "gate_eval_seconds",
            "queue_wait_seconds",
            "draft_queue_wait_seconds",
            "flush_lag_seconds",
            "flush_early_seconds",
            "batch_exec_seconds",
            "request_latency_seconds",
        ] {
            expected.push_str(&summary(h));
        }
        for v in ["chosen_t0", "rows_per_step", "cascade_stage_nfe"] {
            expected.push_str(&summary(v));
        }
        assert_eq!(MetricsSnapshot::default().render_prometheus(), expected);
    }

    #[test]
    fn value_histogram_empty_and_wrapping() {
        let h = ValueHistogram::new(16);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
        for i in 0..100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max, 99.0);
        // Ring retains the most recent 16; p50 among the high values.
        assert!(s.p50 >= 84.0);
    }
}
