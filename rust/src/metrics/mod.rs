//! Serving metrics: counters, latency histograms with percentile queries,
//! and throughput meters. Lock-cheap (atomics + a mutex-guarded histogram)
//! and shared across coordinator workers.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (e.g. bundles currently in the pipeline).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }
    /// Overwrite with a point-in-time level (e.g. batch occupancy after a
    /// composed step).
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exact percentiles over a bounded reservoir.
///
/// Keeps up to `cap` most-recent samples (ring buffer); p50/p95/p99 queries
/// sort a snapshot. At serving rates of ~1e3-1e5 samples this is exact
/// enough and allocation-stable.
#[derive(Debug)]
pub struct LatencyHistogram {
    cap: usize,
    inner: Mutex<HistInner>,
}

#[derive(Debug)]
struct HistInner {
    samples: Vec<u64>, // nanos, ring buffer
    next: usize,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> Self {
        LatencyHistogram {
            cap: cap.max(16),
            inner: Mutex::new(HistInner { samples: Vec::new(), next: 0, count: 0, sum_ns: 0, max_ns: 0 }),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let mut g = self.inner.lock().unwrap();
        if g.samples.len() < self.cap {
            g.samples.push(ns);
        } else {
            let idx = g.next;
            g.samples[idx] = ns;
            g.next = (g.next + 1) % self.cap;
        }
        g.count += 1;
        g.sum_ns += ns as u128;
        g.max_ns = g.max_ns.max(ns);
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let g = self.inner.lock().unwrap();
        let mut v = g.samples.clone();
        v.sort_unstable();
        let pct = |p: f64| -> Duration {
            if v.is_empty() {
                return Duration::ZERO;
            }
            let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
            Duration::from_nanos(v[idx])
        };
        LatencySnapshot {
            count: g.count,
            mean: if g.count > 0 {
                Duration::from_nanos((g.sum_ns / g.count as u128) as u64)
            } else {
                Duration::ZERO
            },
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: Duration::from_nanos(g.max_ns),
        }
    }
}

/// Point-in-time percentile view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl LatencySnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.2?} p50={:.2?} p95={:.2?} p99={:.2?} max={:.2?}",
            self.count, self.mean, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Unitless value histogram (e.g. the controller's chosen t0 per bundle):
/// bounded most-recent reservoir like [`LatencyHistogram`], but over f64
/// samples instead of durations.
#[derive(Debug)]
pub struct ValueHistogram {
    cap: usize,
    inner: Mutex<ValueInner>,
}

#[derive(Debug)]
struct ValueInner {
    samples: Vec<f64>, // ring buffer
    next: usize,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl ValueHistogram {
    pub fn new(cap: usize) -> Self {
        ValueHistogram {
            cap: cap.max(16),
            inner: Mutex::new(ValueInner {
                samples: Vec::new(),
                next: 0,
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }),
        }
    }

    pub fn record(&self, v: f64) {
        let mut g = self.inner.lock().unwrap();
        if g.samples.len() < self.cap {
            g.samples.push(v);
        } else {
            let idx = g.next;
            g.samples[idx] = v;
            g.next = (g.next + 1) % self.cap;
        }
        g.count += 1;
        g.sum += v;
        g.min = g.min.min(v);
        g.max = g.max.max(v);
    }

    pub fn snapshot(&self) -> ValueSnapshot {
        let g = self.inner.lock().unwrap();
        let mut v = g.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pct = |p: f64| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v[((p / 100.0) * (v.len() - 1) as f64).round() as usize]
        };
        ValueSnapshot {
            count: g.count,
            mean: if g.count > 0 { g.sum / g.count as f64 } else { 0.0 },
            p50: pct(50.0),
            p95: pct(95.0),
            min: if g.count > 0 { g.min } else { 0.0 },
            max: if g.count > 0 { g.max } else { 0.0 },
        }
    }
}

/// Point-in-time view of a [`ValueHistogram`], with percentile summaries
/// (p50/p95 over the retained reservoir) like its latency counterpart.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ValueSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl ValueSnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3} p50={:.3} p95={:.3} min={:.3} max={:.3}",
            self.count, self.mean, self.p50, self.p95, self.min, self.max
        )
    }
}

/// Metrics for the replicated executor fleet ([`crate::fleet`]). Owned by
/// the `FleetHandle` rather than [`ServingMetrics`] because the fleet is
/// constructed before the serving service exists (and is useful without
/// one, e.g. under `wsfm selfcheck`); the CLI prints
/// [`FleetMetrics::summary`] alongside the serving report.
#[derive(Debug)]
pub struct FleetMetrics {
    /// Executor calls currently running on each replica (index = replica
    /// id). The router picks the healthy replica with the lowest value.
    pub replica_inflight: Vec<Gauge>,
    /// Calls routed to each replica over the fleet's lifetime.
    pub replica_dispatched: Vec<Counter>,
    /// Replicas marked unhealthy after their engine thread died (or a
    /// watchdog timeout quarantined them).
    pub replica_unhealthy: Counter,
    /// Calls re-routed to another replica after a dead one was observed.
    pub fleet_reroutes: Counter,
    /// Quarantined replicas brought back by the health loop (fresh engine
    /// + re-preload + passing probe).
    pub replica_respawns: Counter,
    /// Respawn attempts that failed (spawn error or failed probe); the
    /// circuit breaker retires a replica after `max_respawns` consecutive
    /// ones.
    pub respawn_failures: Counter,
    /// Calls that tripped the engine-call watchdog (`EngineTimeout`).
    pub engine_timeouts: Counter,
    /// Completed all-or-nothing artifact swaps ([`swap_artifacts`]:
    /// every replica now serves the new manifest).
    ///
    /// [`swap_artifacts`]: ../fleet/struct.FleetHandle.html#method.swap_artifacts
    pub artifact_swaps: Counter,
    /// Artifact swaps abandoned before publication (a replacement failed
    /// to build, preload, or probe — the old fleet kept serving).
    pub artifact_swap_rollbacks: Counter,
}

impl FleetMetrics {
    pub fn new(replicas: usize) -> Self {
        FleetMetrics {
            replica_inflight: (0..replicas).map(|_| Gauge::default()).collect(),
            replica_dispatched: (0..replicas).map(|_| Counter::default()).collect(),
            replica_unhealthy: Counter::default(),
            fleet_reroutes: Counter::default(),
            replica_respawns: Counter::default(),
            respawn_failures: Counter::default(),
            engine_timeouts: Counter::default(),
            artifact_swaps: Counter::default(),
            artifact_swap_rollbacks: Counter::default(),
        }
    }

    /// One-line rendering for the serve/selfcheck summary.
    pub fn summary(&self) -> String {
        let join = |it: Vec<String>| it.join(",");
        format!(
            "replicas={} replica_inflight=[{}] replica_dispatched=[{}] replica_unhealthy={} fleet_reroutes={} replica_respawns={} respawn_failures={} engine_timeouts={} artifact_swaps={} artifact_swap_rollbacks={}",
            self.replica_inflight.len(),
            join(self.replica_inflight.iter().map(|g| g.get().to_string()).collect()),
            join(self.replica_dispatched.iter().map(|c| c.get().to_string()).collect()),
            self.replica_unhealthy.get(),
            self.fleet_reroutes.get(),
            self.replica_respawns.get(),
            self.respawn_failures.get(),
            self.engine_timeouts.get(),
            self.artifact_swaps.get(),
            self.artifact_swap_rollbacks.get()
        )
    }
}

/// Throughput meter: events per second over the meter's lifetime.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    events: Counter,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), events: Counter::default() }
    }
    pub fn record(&self, n: u64) {
        self.events.add(n);
    }
    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.events.get() as f64 / secs
    }
    pub fn total(&self) -> u64 {
        self.events.get()
    }
}

/// The serving metrics bundle shared by the coordinator.
#[derive(Debug)]
pub struct ServingMetrics {
    pub requests_admitted: Counter,
    pub requests_rejected: Counter,
    pub requests_completed: Counter,
    pub batches_executed: Counter,
    pub denoiser_calls: Counter,
    pub draft_calls: Counter,
    /// Draft models actually resolved (cache misses); compare against
    /// `draft_calls` to see the scheduler's draft-model cache working.
    pub draft_models_resolved: Counter,
    pub padded_rows: Counter,
    /// Bundles dispatched into the pipeline and not yet completed.
    pub inflight_bundles: Gauge,
    /// Per-bundle t0 the warm-start controller actually ran with
    /// (`control`): equals the requested t0 in `static` mode, the
    /// draft-quality-derived grid value in `prior`/`scored` modes.
    pub chosen_t0: ValueHistogram,
    /// Denoiser evaluations saved vs. the guarantee-floor budget
    /// (`guaranteed_nfe(steps_cold, t0_min)`), summed per executed chunk.
    /// Always 0 in `static` controller mode with the cascade off; a gated
    /// cascade's early exits land here too.
    pub nfe_saved: Counter,
    /// Chunks whose cascade quality gate passed before the final ladder
    /// stage ([`crate::cascade`], `gated` mode).
    pub cascade_early_exits: Counter,
    /// NFE of each executed cascade stage (the per-stage NFE histogram;
    /// only cascade modes record here).
    pub cascade_stage_nfe: ValueHistogram,
    /// Wall-clock of each mid-cascade quality-gate evaluation.
    pub gate_eval: LatencyHistogram,
    /// Flushed bundle → DRAFT-stage pickup wait (pipeline only).
    pub draft_queue_wait: LatencyHistogram,
    /// How far past its deadline a deadline-flushed bundle was dispatched.
    /// Only deadline-or-later dispatches are recorded here; a bundle that
    /// flushes *before* its deadline (size-triggered) lands in
    /// `early_flushes`/`flush_early` instead — a negative lag would
    /// otherwise clamp to a garbage 0 sample through the unsigned
    /// conversion.
    pub flush_lag: LatencyHistogram,
    /// Bundles dispatched before their flush deadline (size-triggered).
    pub early_flushes: Counter,
    /// Responses served from draft tokens after REFINE failed
    /// (`degraded: true` on the wire; counted per request, not per
    /// bundle).
    pub degraded_responses: Counter,
    /// How far *ahead* of its deadline an early-flushed bundle was
    /// dispatched (the headroom the size trigger bought).
    pub flush_early: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub batch_exec: LatencyHistogram,
    pub request_latency: LatencyHistogram,
    pub samples: Throughput,
    /// Useful rows advanced per composed engine step (the step-level
    /// batch composer's merge width; empty when the composer is off).
    pub rows_per_step: ValueHistogram,
    /// Mean row occupancy of the latest composed step's dispatches, in
    /// percent of the dispatch row budget (`composer.max_rows`, else the
    /// family's largest compiled batch; >100 = tiled over several
    /// compiled batches).
    pub batch_occupancy: Gauge,
    /// Codec hellos received on the wire ([`crate::server::codec`]).
    pub wire_hellos: Counter,
    /// Connections that switched off the default codec after a hello.
    pub wire_codec_switches: Counter,
    /// Undecodable inbound wire messages (malformed JSON lines, bad
    /// binary frames) answered with a typed error.
    pub wire_malformed: Counter,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            requests_admitted: Counter::default(),
            requests_rejected: Counter::default(),
            requests_completed: Counter::default(),
            batches_executed: Counter::default(),
            denoiser_calls: Counter::default(),
            draft_calls: Counter::default(),
            draft_models_resolved: Counter::default(),
            padded_rows: Counter::default(),
            inflight_bundles: Gauge::default(),
            chosen_t0: ValueHistogram::new(4096),
            nfe_saved: Counter::default(),
            cascade_early_exits: Counter::default(),
            cascade_stage_nfe: ValueHistogram::new(4096),
            gate_eval: LatencyHistogram::new(4096),
            draft_queue_wait: LatencyHistogram::new(4096),
            flush_lag: LatencyHistogram::new(4096),
            early_flushes: Counter::default(),
            degraded_responses: Counter::default(),
            flush_early: LatencyHistogram::new(4096),
            queue_wait: LatencyHistogram::new(4096),
            batch_exec: LatencyHistogram::new(4096),
            request_latency: LatencyHistogram::new(4096),
            samples: Throughput::new(),
            rows_per_step: ValueHistogram::new(4096),
            batch_occupancy: Gauge::default(),
            wire_hellos: Counter::default(),
            wire_codec_switches: Counter::default(),
            wire_malformed: Counter::default(),
        }
    }
}

impl ServingMetrics {
    pub fn report(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} batches={} denoiser_calls={} draft_calls={} draft_models_resolved={} padded_rows={} inflight_bundles={} nfe_saved={} cascade_early_exits={} early_flushes={} degraded={} batch_occupancy={} wire_hellos={} wire_codec_switches={} wire_malformed={} samples/s={:.2}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}\n  {}",
            self.requests_admitted.get(),
            self.requests_rejected.get(),
            self.requests_completed.get(),
            self.batches_executed.get(),
            self.denoiser_calls.get(),
            self.draft_calls.get(),
            self.draft_models_resolved.get(),
            self.padded_rows.get(),
            self.inflight_bundles.get(),
            self.nfe_saved.get(),
            self.cascade_early_exits.get(),
            self.early_flushes.get(),
            self.degraded_responses.get(),
            self.batch_occupancy.get(),
            self.wire_hellos.get(),
            self.wire_codec_switches.get(),
            self.wire_malformed.get(),
            self.samples.per_second(),
            self.chosen_t0.snapshot().report("chosen_t0"),
            self.rows_per_step.snapshot().report("rows_per_step"),
            self.cascade_stage_nfe.snapshot().report("cascade_stage_nfe"),
            self.gate_eval.snapshot().report("gate_eval"),
            self.queue_wait.snapshot().report("queue_wait"),
            self.draft_queue_wait.snapshot().report("draft_queue_wait"),
            self.flush_lag.snapshot().report("flush_lag"),
            self.flush_early.snapshot().report("flush_early"),
            self.batch_exec.snapshot().report("batch_exec"),
            self.request_latency.snapshot().report("request_latency"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(100));
        assert!((s.p50.as_micros() as i64 - 50).abs() <= 2, "{:?}", s.p50);
    }

    #[test]
    fn histogram_ring_buffer_wraps() {
        let h = LatencyHistogram::new(16);
        for i in 0..100u64 {
            h.record(Duration::from_nanos(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // Only recent 16 retained; p50 should be among the high values.
        assert!(s.p50 >= Duration::from_nanos(84));
    }

    #[test]
    fn empty_histogram_snapshot() {
        let s = LatencyHistogram::new(64).snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.record(10);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(t.total(), 10);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn gauge_goes_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        assert_eq!(g.get(), 0);
        g.set(42);
        assert_eq!(g.get(), 42);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn serving_metrics_report_contains_fields() {
        let m = ServingMetrics::default();
        m.requests_admitted.inc();
        m.inflight_bundles.inc();
        let r = m.report();
        assert!(r.contains("admitted=1"));
        assert!(r.contains("inflight_bundles=1"));
        assert!(r.contains("draft_queue_wait"));
        assert!(r.contains("flush_lag"));
        assert!(r.contains("flush_early"));
        assert!(r.contains("nfe_saved=0"));
        assert!(r.contains("cascade_early_exits=0"));
        assert!(r.contains("cascade_stage_nfe"));
        assert!(r.contains("gate_eval"));
        assert!(r.contains("early_flushes=0"));
        assert!(r.contains("chosen_t0"));
        assert!(r.contains("request_latency"));
        assert!(r.contains("rows_per_step"));
        assert!(r.contains("batch_occupancy=0"));
        assert!(r.contains("wire_hellos=0"));
        assert!(r.contains("wire_codec_switches=0"));
        assert!(r.contains("wire_malformed=0"));
        m.degraded_responses.inc();
        m.batch_occupancy.set(87);
        let r = m.report();
        assert!(r.contains("degraded=1"));
        assert!(r.contains("batch_occupancy=87"));
    }

    #[test]
    fn value_histogram_tracks_stats() {
        let h = ValueHistogram::new(64);
        for v in [0.5, 0.8, 0.8, 0.95, 0.35] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert!((s.min - 0.35).abs() < 1e-12);
        assert!((s.max - 0.95).abs() < 1e-12);
        assert!((s.mean - 0.68).abs() < 1e-9);
        assert!(s.p50 >= s.min && s.p50 <= s.max);
        assert!(s.p95 >= s.p50 && s.p95 <= s.max, "percentiles must be ordered");
        assert_eq!(s.p50, 0.8);
        assert_eq!(s.p95, 0.95);
        let rep = s.report("chosen_t0");
        assert!(rep.contains("n=5") && rep.contains("p95="), "{rep}");
    }

    #[test]
    fn value_histogram_percentiles_over_uniform_ramp() {
        let h = ValueHistogram::new(1024);
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert!((s.p50 - 50.0).abs() <= 2.0, "{}", s.p50);
        assert!((s.p95 - 95.0).abs() <= 2.0, "{}", s.p95);
        // Empty snapshot keeps both at zero.
        let e = ValueHistogram::new(16).snapshot();
        assert_eq!((e.p50, e.p95), (0.0, 0.0));
    }

    #[test]
    fn fleet_metrics_summary_tracks_per_replica_state() {
        let m = FleetMetrics::new(3);
        m.replica_inflight[1].inc();
        m.replica_dispatched[0].add(4);
        m.replica_dispatched[1].inc();
        m.replica_unhealthy.inc();
        m.fleet_reroutes.add(2);
        let s = m.summary();
        assert!(s.contains("replicas=3"), "{s}");
        assert!(s.contains("replica_inflight=[0,1,0]"), "{s}");
        assert!(s.contains("replica_dispatched=[4,1,0]"), "{s}");
        assert!(s.contains("replica_unhealthy=1"), "{s}");
        assert!(s.contains("fleet_reroutes=2"), "{s}");
        m.replica_respawns.inc();
        m.respawn_failures.add(3);
        m.engine_timeouts.add(2);
        let s = m.summary();
        assert!(s.contains("replica_respawns=1"), "{s}");
        assert!(s.contains("respawn_failures=3"), "{s}");
        assert!(s.contains("engine_timeouts=2"), "{s}");
    }

    #[test]
    fn value_histogram_empty_and_wrapping() {
        let h = ValueHistogram::new(16);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
        for i in 0..100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max, 99.0);
        // Ring retains the most recent 16; p50 among the high values.
        assert!(s.p50 >= 84.0);
    }
}
